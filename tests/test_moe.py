"""MoE dispatch correctness: the sort-based grouped-GEMM dispatch must
match a dense all-experts reference when capacity is sufficient."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig
from repro.models.layers import LayerCtx
from repro.models.moe import capacity, init_moe, moe_forward

CTX = LayerCtx(abft=ABFTConfig.off())


def _cfg(n_experts=8, k=2, shared=0, cap=8.0):
    base = get_config("qwen2-moe-a2.7b")
    return scaled_down(
        base, n_experts=n_experts, experts_per_token=k,
        n_shared_experts=shared, moe_d_ff=16, d_model=32)


def _dense_reference(x, p, cfg):
    """All experts compute all tokens; combine with normalized top-k."""
    B, L, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_i = jax.lax.top_k(probs, cfg.experts_per_token)
    topk_w = topk_w / topk_w.sum(-1, keepdims=True)
    up = jnp.einsum("td,edf->tef", xf, p["w_up"])
    gate = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("tef,efd->ted", h, p["w_down"])  # (T, E, D)
    y = jnp.zeros_like(xf)
    for slot in range(cfg.experts_per_token):
        sel = jnp.take_along_axis(
            out, topk_i[:, slot][:, None, None], axis=1)[:, 0]
        y = y + sel * topk_w[:, slot][:, None]
    return y.reshape(B, L, D)


@pytest.mark.parametrize("seed", [0, 1])
def test_dispatch_matches_dense_reference(seed):
    cfg = dataclasses.replace(_cfg(), capacity_factor=8.0)  # no drops
    rng = np.random.default_rng(seed)
    p = init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, flag, aux = moe_forward(x, p, cfg, CTX)
    y_ref = _dense_reference(x, p, cfg)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    assert not bool(flag)
    assert float(aux) > 0


def test_capacity_drops_are_bounded():
    """With capacity_factor 1.0 and adversarially-identical tokens, drops
    happen but the residual path keeps outputs finite."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=1.0)
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.ones((2, 16, cfg.d_model), jnp.float32)  # all tokens identical
    y, flag, aux = moe_forward(x, p, cfg, CTX)
    assert not bool(jnp.any(jnp.isnan(y)))
    # identical tokens all route to the same experts -> capacity binds
    C = capacity(cfg, 32)
    assert C < 32 * cfg.experts_per_token / cfg.n_experts * 8


def test_shared_experts_add_dense_path():
    cfg = dataclasses.replace(_cfg(shared=2), capacity_factor=8.0)
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((1, 4, cfg.d_model)),
        jnp.float32)
    y, flag, aux = moe_forward(x, p, cfg, CTX)
    y_routed = _dense_reference(x, p, cfg)
    # shared path contributes beyond the routed reference
    assert float(jnp.max(jnp.abs(y - y_routed))) > 1e-4


def test_grouped_dispatch_group_invariance():
    """dp_size-grouped dispatch equals ungrouped when tokens divide."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=8.0)
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(3).standard_normal((4, 8, cfg.d_model)),
        jnp.float32)
    y1, _, _ = moe_forward(x, p, cfg, CTX)
    # hints with dp_size=4 but no mesh: constrain() would need a mesh, so
    # emulate grouping by reshaping batch (the dispatch path is identical)
    y2, _, _ = moe_forward(
        x.reshape(8, 4, cfg.d_model), p, cfg, CTX)
    np.testing.assert_allclose(
        np.asarray(y1).reshape(-1), np.asarray(y2).reshape(-1),
        rtol=2e-3, atol=2e-3)
