"""Chunked-prefill scheduler: stall-free mixed prefill+decode steps with
per-step intensity-guided ABFT re-selection.

Coverage:

  * equivalence — greedy streams from the chunked engine are
    byte-identical to the unchunked engine for dense, paged,
    paged+prefix-sharing, and MLA caches, including odd chunk sizes that
    split prompts at non-block, non-bucket boundaries (rotary offsets,
    causal q_offset, and scatter starts are all computed from the true
    logical position — any off-by-chunk bug shows up as divergence);
  * fault isolation — a fault injected mid-chunk retries ONLY that chunk
    (the step's decode call and earlier chunks are not re-executed), and
    a persistent chunk fault evicts only that chunk batch's requests
    while resident decodes keep their streams;
  * scheduling — a stream of long prompts cannot stall a resident decode
    beyond the token budget: decode tokens pack first, so every active
    stream advances every step;
  * selection trace — EngineStats records a per-step (intensity, scheme)
    trace in which mixed steps select a different ABFT scheme than
    decode-only steps (the paper's §5.3 decision re-made per step);
  * compile bounding — chunk batches bucket rows and lengths, so a whole
    varied run compiles O(log2(slots) x chunk/8) _prefill_chunk variants,
    asserted via the jit cache size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.core.hardware import HardwareSpec
from repro.models import ModelFault, build_model
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)

# Hardware where the per-step selection genuinely flips: a weak VPU makes
# fused block ABFT expensive once the step carries enough tokens, while
# the fixed-op overhead keeps global ABFT losing on thin decode-only
# steps.  With the scaled test model's (k=64, n=128) f32 projection this
# selects block_1s for m <= 16 and global for m >= 32.
FLIP_HW = HardwareSpec(
    name="flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)

MIX = [(5, 4), (23, 5), (11, 3), (30, 4)]     # (prompt_len, budget)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    cfg = scaled_down(get_config("deepseek-v3-671b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2), dtype=jnp.float32)
    return cfg, model, params


def _reqs(spec=MIX):
    return [Request(uid=i, prompt=np.arange(1, 1 + L, dtype=np.int32),
                    max_new_tokens=n)
            for i, (L, n) in enumerate(spec)]


def _engine(model, params, *, slots=2, max_len=64, **kw):
    return ServeEngine(model, params, slots=slots, max_len=max_len,
                       abft=ABFT, dtype=jnp.float32, **kw)


@pytest.fixture(scope="module")
def unchunked_streams(small_model):
    """Reference greedy streams from the admit-time-prefill engine."""
    _, model, params = small_model
    return _engine(model, params).run(_reqs())


# ================================================= equivalence

def test_chunked_matches_unchunked_dense(small_model, unchunked_streams):
    _, model, params = small_model
    eng = _engine(model, params, chunk_tokens=8)
    assert eng.run(_reqs()) == unchunked_streams
    assert eng.stats.prefill_chunks > len(MIX)   # prompts really chunked
    assert eng.stats.hard_faults == 0


def test_chunked_matches_unchunked_paged_odd_chunk(small_model,
                                                   unchunked_streams):
    """chunk_tokens=5 splits every prompt at non-block, non-bucket
    boundaries — scatter starts, rotary offsets and causal q_offset all
    land mid-block."""
    _, model, params = small_model
    eng = _engine(model, params, cache_kind="paged", chunk_tokens=5)
    assert eng.run(_reqs()) == unchunked_streams
    assert eng.stats.hard_faults == 0


def test_chunked_matches_unchunked_prefix_sharing(small_model):
    """Chunking composes with refcounted prefix sharing: the cursor
    starts at the matched prefix and only the unshared remainder is
    chunked."""
    _, model, params = small_model
    tpl = np.arange(1, 37, dtype=np.int32)
    spec = [
        Request(uid=i,
                prompt=np.concatenate(
                    [tpl, (100 + 7 * i + np.arange(1 + i % 3,
                                                   dtype=np.int32)) % 250]),
                max_new_tokens=4 + i % 3)
        for i in range(6)
    ]

    def clone():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in spec]

    ref = _engine(model, params, slots=3, cache_kind="paged").run(clone())
    eng = _engine(model, params, slots=3, cache_kind="paged",
                  prefix_sharing=True, chunk_tokens=8)
    assert eng.run(clone()) == ref
    assert eng.stats.prefix_tokens_shared > 0    # sharing really engaged


def test_chunked_matches_unchunked_mla(mla_model):
    _, model, params = mla_model
    spec = [(7, 4), (21, 5)]
    ref = _engine(model, params).run(_reqs(spec))
    eng = _engine(model, params, cache_kind="paged", chunk_tokens=8)
    assert eng.run(_reqs(spec)) == ref


# ================================================= fault isolation

def test_chunk_fault_retries_only_that_chunk(small_model):
    """A fault landing in a mid-prompt chunk of a MIXED step retries the
    chunk alone: the co-scheduled decode call is not re-executed (decode
    retries stay zero) and both streams match the clean run."""
    _, model, params = small_model
    short = (5, 8)
    long = (28, 3)

    def serve(eng, **kw):
        resident = _reqs([short])[0]
        eng.admit([resident])
        while eng._prefill_cursors:
            eng.step()                    # resident now decoding
        late = Request(uid=1, prompt=np.arange(1, 1 + long[0],
                                               dtype=np.int32),
                       max_new_tokens=long[1])
        out = eng.run([late], **kw)
        return resident.generated, out[1]

    clean = serve(_engine(model, params, chunk_tokens=8))
    eng = _engine(model, params, chunk_tokens=8,
                  policy=RecoveryPolicy(max_retries=1))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    faulted = serve(eng, admit_fault_at=(1, fault))
    assert faulted == clean
    assert eng.stats.faults_detected == 1
    assert eng.stats.chunk_retries == 1
    assert eng.stats.retries == 1         # no decode retry piggybacked
    assert eng.stats.hard_faults == 0


def test_chunk_hard_fault_evicts_only_chunk_batch(small_model):
    """Persistent chunk fault (no retry budget): the chunking request is
    evicted with a recorded error; the resident decode stream and later
    admissions are unaffected."""
    _, model, params = small_model
    resident = _reqs([(5, 10)])[0]
    victim = Request(uid=1, prompt=np.arange(1, 29, dtype=np.int32),
                     max_new_tokens=4)
    later = Request(uid=2, prompt=np.arange(1, 8, dtype=np.int32),
                    max_new_tokens=3)

    ref_eng = _engine(model, params, chunk_tokens=8)
    ref_res = _reqs([(5, 10)])[0]
    ref_eng.admit([ref_res])
    while ref_eng._prefill_cursors:
        ref_eng.step()
    ref = ref_eng.run([Request(uid=2, prompt=later.prompt.copy(),
                               max_new_tokens=3)])

    eng = _engine(model, params, chunk_tokens=8,
                  policy=RecoveryPolicy(max_retries=0))
    eng.admit([resident])
    while eng._prefill_cursors:
        eng.step()
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    out = eng.run([victim, later], admit_fault_at=(1, fault))
    assert victim.error == "hard_fault:prefill"
    assert eng.stats.hard_faults == 1 and eng.stats.evictions == 1
    assert resident.generated == ref_res.generated
    assert out[2] == ref[2]
    assert all(c.req.uid != 1
               for c in eng._prefill_cursors.values())   # cursor gone


def test_decode_fault_in_chunked_engine_recovers(small_model):
    """A step fault landing on a decode-only step of the chunked engine
    routes to the decode call (no chunk is scheduled): recovery retries
    the decode, never a chunk, and streams match the clean run."""
    _, model, params = small_model
    spec = [(5, 6), (9, 6)]
    clean = _engine(model, params, chunk_tokens=8).run(_reqs(spec))
    eng = _engine(model, params, chunk_tokens=8,
                  policy=RecoveryPolicy(max_retries=1))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    out = eng.run(_reqs(spec), fault_at=(3, fault))
    assert out == clean
    assert eng.stats.faults_detected == 1
    assert eng.stats.retries == 1
    assert eng.stats.chunk_retries == 0   # fault hit the decode call only
    assert eng.stats.hard_faults == 0


# ================================================= scheduling

def test_long_prompt_stream_cannot_starve_decode(small_model):
    """Decode tokens pack FIRST: while a stream of long prompts chunks
    through the budget, the resident stream emits exactly one token per
    step until its own budget ends — its inter-token latency in steps is
    1, never stretched by pending prefill work."""
    _, model, params = small_model
    C = 8
    eng = _engine(model, params, slots=2, chunk_tokens=C)
    resident = _reqs([(4, 14)])[0]
    eng.admit([resident])
    while eng._prefill_cursors:
        eng.step()
    assert eng.active                      # resident decoding

    pending = [Request(uid=10 + i, prompt=np.arange(1, 31, dtype=np.int32),
                       max_new_tokens=2) for i in range(3)]
    overlap = 0
    while not resident.done:
        if pending and eng.free_slots():
            eng.admit(pending)
        overlap += bool(eng._prefill_cursors)
        n = len(resident.generated)
        eng.step()
        assert len(resident.generated) == n + 1   # decode never skipped
    assert overlap >= 5     # the backlog really was chunking alongside
    while pending or eng.active or eng._prefill_cursors:
        if pending and eng.free_slots():
            eng.admit(pending)
        eng.step()

    # the budget rule held on every step: prefill filled only what the
    # decode tokens left over
    for e in eng.stats.selection_trace:
        assert e["prefill"] <= max(0, C - e["decode"])
    assert eng.stats.mixed_steps > 0


def test_selection_trace_mixed_vs_decode_only(small_model):
    """The per-step trace shows the intensity-guided selector choosing
    DIFFERENT schemes for mixed and decode-only compositions: chunk-
    carrying steps cross into the compute-bound regime (global ABFT),
    decode-only steps stay memory-bound (fused block ABFT)."""
    _, model, params = small_model
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                      hardware=FLIP_HW)
    eng = ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                      dtype=jnp.float32, chunk_tokens=48)
    resident = _reqs([(4, 12)])[0]
    eng.admit([resident])
    while eng._prefill_cursors:
        eng.step()
    pending = [Request(uid=10 + i, prompt=np.arange(1, 48, dtype=np.int32),
                       max_new_tokens=2) for i in range(2)]
    while pending or eng.active or eng._prefill_cursors:
        if pending and eng.free_slots():
            eng.admit(pending)
        eng.step()

    tr = eng.stats.selection_trace
    mixed = [e for e in tr if e["decode"] and e["prefill"]]
    dec = [e for e in tr if e["decode"] and not e["prefill"]]
    assert mixed and dec
    assert eng.stats.mixed_steps == len(mixed)
    # every decode-only step is memory-bound -> fused block ABFT
    assert {e["scheme"] for e in dec} == {Scheme.BLOCK_1S.value}
    # budget-saturated mixed steps cross the regime -> global ABFT
    big_mixed = [e for e in mixed if e["decode"] + e["prefill"] >= 32]
    assert big_mixed
    assert {e["scheme"] for e in big_mixed} == {Scheme.GLOBAL.value}
    assert (min(e["intensity"] for e in big_mixed)
            > max(e["intensity"] for e in dec))


# ================================================= compile bounding

def test_prefill_chunk_compile_count_bounded(small_model):
    """Row counts bucket to powers of two (capped at slots) and chunk
    lengths to multiples of 8, so a run over many distinct prompt
    lengths compiles at most |row buckets| x |length buckets| variants
    of the jitted chunk step."""
    _, model, params = small_model
    slots, C = 3, 16
    eng = _engine(model, params, slots=slots, max_len=64,
                  cache_kind="paged", chunk_tokens=C)
    lens = [5, 9, 13, 17, 21, 25, 29, 3, 7, 30, 11, 19]
    reqs = [Request(uid=i, prompt=np.arange(1, 1 + L, dtype=np.int32),
                    max_new_tokens=1 + i % 3)
            for i, L in enumerate(lens)]
    eng.run(reqs)
    assert eng.stats.prefill_chunks >= len(lens)
    row_buckets = {1, 2, 3}                  # _pad_rows over 3 slots
    len_buckets = {8, 16}                    # _pad_len up to chunk=16
    bound = len(row_buckets) * len(len_buckets)
    assert eng._prefill_chunk._cache_size() <= bound
    assert eng._prefill_chunk._cache_size() >= 1


# ================================================= gating & edges

def test_chunked_rejects_unsupported_model():
    cfg = scaled_down(get_config("jamba-v0.1-52b"))
    model = build_model(cfg)
    assert not model.supports_chunked_prefill
    with pytest.raises(ValueError, match="chunk_tokens"):
        ServeEngine(model, None, slots=2, max_len=64, chunk_tokens=8)


def test_chunked_rejects_bad_budget(small_model):
    _, model, params = small_model
    with pytest.raises(ValueError, match="chunk_tokens"):
        _engine(model, params, chunk_tokens=0)


def test_budget_met_at_final_chunk_frees_slot(small_model):
    """max_new_tokens=1 satisfied by the final chunk's sampled token: the
    request finishes without ever occupying a decode slot; n=0 finishes
    at admission."""
    _, model, params = small_model
    ref = _engine(model, params).run(_reqs([(20, 1)]))
    eng = _engine(model, params, chunk_tokens=8)
    one = _reqs([(20, 1)])[0]
    zero = Request(uid=1, prompt=np.arange(1, 6, dtype=np.int32),
                   max_new_tokens=0)
    out = eng.run([one, zero])
    assert out[0] == ref[0] and len(out[0]) == 1
    assert zero.done and zero.generated == []
    assert not eng.active and not eng._prefill_cursors
    assert eng.free_slots() == [0, 1]
