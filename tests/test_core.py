"""Unit + property tests for the core ABFT library (checksums, selector,
intensity model, protected_matmul dispatch)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    ABFTConfig,
    FaultSpec,
    FixedPolicy,
    GemmDims,
    NVIDIA_T4,
    Scheme,
    SelectorConfig,
    TPU_V5E,
    aggregate_intensity,
    overhead_pct,
    precompute_weight_checksums,
    protected_matmul,
    select_scheme,
    selection_report,
)
from repro.core.checksums import global_row_check, global_scalar_check
from repro.core.faults import inject_output_fault, flip_bit


# ---------------------------------------------------------------- intensity

def test_arithmetic_intensity_matches_paper_formula():
    # paper §3.1: AI = FLOPs / bytes;  FP16 square GEMM of size s:
    # 2 s^3 / (2 * 3 s^2) = s / 3
    d = GemmDims(m=2048, k=2048, n=2048, dtype_bytes=2, out_dtype_bytes=2)
    assert d.arithmetic_intensity == pytest.approx(2048 / 3)


def test_paper_fig12_crossover_square_sizes():
    """Paper Fig. 12: sizes with AI below the device CMR favor the fused
    (thread/block-level) scheme; above it, global ABFT."""
    for s in (32, 64, 128, 256, 512):
        d = GemmDims(m=s, k=s, n=s)
        if d.arithmetic_intensity < TPU_V5E.cmr:
            sel = select_scheme(d, TPU_V5E)
            assert sel.scheme == Scheme.BLOCK_1S, (s, sel)
    for s in (2048, 4096):
        d = GemmDims(m=s, k=s, n=s)
        assert d.arithmetic_intensity > TPU_V5E.cmr
        sel = select_scheme(d, TPU_V5E)
        assert sel.scheme == Scheme.GLOBAL, (s, sel)


def test_dlrm_like_aggregate_intensity():
    """Paper §3.2: DLRM MLPs at batch 1 have aggregate AI ~ 7 (fp16)."""
    # MLP-Bottom: 13 -> 512 -> 256 -> 64 (batch 1)
    layers = [
        GemmDims(m=1, k=13, n=512),
        GemmDims(m=1, k=512, n=256),
        GemmDims(m=1, k=256, n=64),
    ]
    ai = aggregate_intensity(layers)
    assert 0.5 < ai < 3  # thin GEMMs: bandwidth-bound by orders of magnitude
    # and at batch 256 the AI rises by ~2 orders (paper: 7 -> 70-109)
    layers_b = [
        GemmDims(m=256, k=13, n=512),
        GemmDims(m=256, k=512, n=256),
        GemmDims(m=256, k=256, n=64),
    ]
    assert aggregate_intensity(layers_b) > 20 * ai


def test_overhead_model_orderings():
    """Qualitative orderings from the paper, under the v5e roofline model."""
    thin = GemmDims(m=16, k=4096, n=4096)     # bandwidth-bound
    fat = GemmDims(m=8192, k=8192, n=8192)    # compute-bound
    # bandwidth-bound: fused block ABFT beats global
    assert overhead_pct(Scheme.BLOCK_1S, thin, TPU_V5E) < overhead_pct(
        Scheme.GLOBAL, thin, TPU_V5E)
    # compute-bound: global beats replication by a wide margin
    assert overhead_pct(Scheme.GLOBAL, fat, TPU_V5E) < overhead_pct(
        Scheme.REPLICA, fat, TPU_V5E)
    # replication doubles compute-bound time (paper §6.5 spike)
    assert overhead_pct(Scheme.REPLICA, fat, TPU_V5E) > 80.0


def test_t4_cmr_matches_paper():
    assert NVIDIA_T4.cmr == pytest.approx(203, rel=0.01)


# ---------------------------------------------------------------- selector

def test_selection_report_structure():
    rows = selection_report(
        {"up": GemmDims(m=16, k=2048, n=8192),
         "down": GemmDims(m=16384, k=8192, n=2048)})
    assert rows[0]["scheme"] == "block_1s"        # thin -> fused
    assert rows[1]["scheme"] == "global"          # fat -> global
    assert rows[0]["bound"] == "bandwidth"
    assert rows[1]["bound"] == "compute"


def test_fixed_mode_override():
    cfg = SelectorConfig(mode="fixed", fixed_scheme=Scheme.REPLICA)
    sel = select_scheme(GemmDims(m=4096, k=4096, n=4096), config=cfg)
    assert sel.scheme == Scheme.REPLICA


def test_profile_table_override():
    d = GemmDims(m=64, k=64, n=64)
    sel = select_scheme(
        d, config=SelectorConfig(mode="profile"),
        profile_table={d: Scheme.GLOBAL})
    assert sel.scheme == Scheme.GLOBAL


# ------------------------------------------------------------ global checks

def test_global_row_check_clean_and_faulty(rng):
    x = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    ws = precompute_weight_checksums(w)
    y = jnp.matmul(x, w)
    chk = global_row_check(x, ws.w_sum, ws.w_abs_sum, y)
    assert not bool(chk.flag)
    y_bad = inject_output_fault(y, FaultSpec.value(10, 10, 25.0))
    chk = global_row_check(x, ws.w_sum, ws.w_abs_sum, y_bad)
    assert bool(chk.flag)
    # row location: residual argmax identifies the faulty row
    assert int(jnp.argmax(chk.residual - chk.threshold)) == 10


def test_global_scalar_check(rng):
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    ws = precompute_weight_checksums(w)
    y = jnp.matmul(x, w)
    assert not bool(global_scalar_check(x, ws.w_sum, ws.w_abs_sum, y).flag)
    y_bad = inject_output_fault(y, FaultSpec.value(0, 0, 100.0))
    assert bool(global_scalar_check(x, ws.w_sum, ws.w_abs_sum, y_bad).flag)


def test_global_check_bf16_quantization_term(rng):
    """bf16 outputs must not false-positive from downcast rounding."""
    x = jnp.asarray(rng.standard_normal((128, 512)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.bfloat16)
    ws = precompute_weight_checksums(w)
    y = jnp.matmul(
        x, w, preferred_element_type=jnp.float32).astype(jnp.bfloat16)
    chk = global_row_check(x, ws.w_sum, ws.w_abs_sum, y)
    assert not bool(chk.flag)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 256),
    n=st.integers(1, 128),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_global_check_no_false_positive(m, k, n, scale, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)) * scale, jnp.float32)
    w = jnp.asarray(r.standard_normal((k, n)) * scale, jnp.float32)
    ws = precompute_weight_checksums(w)
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    assert not bool(global_row_check(x, ws.w_sum, ws.w_abs_sum, y).flag)


# --------------------------------------------------------- protected_matmul

@pytest.mark.parametrize("scheme", [
    Scheme.NONE, Scheme.GLOBAL, Scheme.BLOCK_1S, Scheme.BLOCK_2S,
    Scheme.REPLICA, Scheme.AUTO,
])
def test_protected_matmul_all_schemes(rng, scheme):
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    if scheme == Scheme.AUTO:
        # AUTO denotes the default policy — not a deprecated surface
        cfg = ABFTConfig(scheme=scheme)
    else:
        with pytest.warns(DeprecationWarning, match="ProtectionPolicy"):
            cfg = ABFTConfig(scheme=scheme)
    y, chk = protected_matmul(x, w, cfg, out_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.matmul(x, w)), rtol=1e-4)
    assert not bool(chk.flag)


@pytest.mark.parametrize("scheme", [Scheme.GLOBAL, Scheme.BLOCK_1S])
def test_protected_matmul_detects_fault(rng, scheme):
    x = jnp.asarray(rng.standard_normal((32, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    y, chk = protected_matmul(
        x, w, ABFTConfig.from_policy(FixedPolicy(scheme)),
        out_dtype=jnp.float32, fault=FaultSpec.value(5, 6, 50.0))
    assert bool(chk.flag)


def test_abft_off_is_clean_dot(rng):
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y, chk = protected_matmul(x, w, ABFTConfig.off(), out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)
    assert not bool(chk.flag)


def test_flip_bit_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((4, 4)), jnp.float32)
    b = jnp.asarray(30, jnp.int32)
    assert bool(jnp.all(flip_bit(flip_bit(x, b), b) == x))
