"""Compatibility shim for ``hypothesis``.

CI images for this repo have no network access, so ``hypothesis`` may be
absent.  Property tests must still *collect and run*: when the real
library is installed we re-export it verbatim; otherwise we provide a
minimal example-based fallback that draws a deterministic set of examples
from the same strategy expressions (``st.integers`` / ``st.sampled_from``)
and runs the test body once per example.

Usage (in test modules):

    from _hypothesis_compat import given, settings, st

which replaces ``from hypothesis import given, settings, strategies as st``.
"""

from __future__ import annotations

import functools

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib as _zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 10  # examples per test when hypothesis is absent

    class _Strategy:
        """A strategy that can only draw concrete examples."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    def given(**strategies):
        """Example-based stand-in: run the test over a deterministic set of
        draws (seeded per test name, so failures reproduce)."""

        def decorate(fn):
            # NOTE: no functools.wraps — the wrapper must expose a ZERO-arg
            # signature or pytest would resolve the drawn names as fixtures
            def wrapper():
                n = getattr(fn, "_max_examples", _FALLBACK_EXAMPLES)
                # crc32, not hash(): str hashing is salted per process and
                # would draw different examples on every run
                rng = _np.random.default_rng(
                    _zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._inner = fn
            return wrapper

        return decorate

    def settings(max_examples=None, **_ignored):
        """Record max_examples for the fallback ``given``; ignore the rest
        (deadline etc. have no meaning without hypothesis)."""

        def decorate(fn):
            if max_examples is not None:
                # cap fallback cost: property sweeps are bounded either way
                inner = getattr(fn, "_inner", fn)
                inner._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
            return fn

        return decorate
