"""Sharded serving: per-shard protection-plan divergence and mesh
stream equivalence (ISSUE 8 acceptance surface).

Host-side (always runs, one device):
  * ``model_parallel=k`` divides the plan's GEMM dims and — on a
    crafted HardwareSpec whose CMR sits between the TP=1 and TP=4
    arithmetic intensities — SELECTS A DIFFERENT SCHEME per shard:
    the paper's intensity-guided decision re-made for post-sharding
    shapes.
  * plan JSON round-trips ``model_parallel``; ``plan_row`` telemetry
    instants export the per-shard selections.
  * a ``MeshExecutor`` over a 1-wide mesh is byte-identical to the
    local executor.

Multi-device (``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
skipped when the host exposes fewer devices — the sharded-smoke CI job
runs them):
  * greedy streams are byte-identical between mesh=1 and mesh=k for
    k in {2, 4} — dense, paged, chunked + prefix-shared, and under
    injected prefill/decode faults with retry and hard-fault eviction.

bf16 everywhere: per-device partial GEMMs accumulate in f32 and round
below bf16 output precision, so TP's psum reordering cannot perturb
the streams (full-f32 models can differ in the last ulp)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core.faults import FaultSpec
from repro.core.hardware import HardwareSpec
from repro.core.protected import ABFTConfig
from repro.core.schemes import Scheme
from repro.models import ModelFault, build_model
from repro.obs import EngineTelemetry
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine
from repro.serve.executor import LocalExecutor, MeshExecutor

N_DEV = len(jax.devices())

# CMR = 24 FLOPs/byte sits between the smoke model's TP=4 intensities
# (all <= 21.3) and its TP=1 mlp/lm_head intensities (25.6 / 28.4): the
# full-model mlp/lm_head shapes are compute-bound, every 4-way shard is
# bandwidth-bound.  The slow VPU + cheap fixed ops tilt the overhead
# model so global ABFT's dispatch cost amortizes over the full-width
# GEMMs but not over the 4x-narrower shards — the crafted point where
# the intensity-guided decision lands differently per shard width.
SHARD_HW = HardwareSpec(
    name="shard-flip", peak_flops=2.4e13, vpu_flops=1e11, hbm_bw=1e12,
    ici_bw=1e11, hbm_bytes=1 << 34, vmem_bytes=1 << 24,
    fixed_op_overhead_s=1e-7)


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    return cfg, model, params


def _reqs(cfg, n=6, seed=0, new_tokens=5):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        size=rng.integers(4, 20)).astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


# ------------------------------------------------- per-shard plan (host)
class TestShardedPlan:
    def test_tp_divides_gemm_dims(self, setup):
        cfg, model, _ = setup
        p1 = model.protection_plan(hw=SHARD_HW, phase="serve",
                                   n_tokens=64, model_parallel=1)
        p4 = model.protection_plan(hw=SHARD_HW, phase="serve",
                                   n_tokens=64, model_parallel=4)
        r1 = {r["layer"]: r for r in p1.report_rows()}
        r4 = {r["layer"]: r for r in p4.report_rows()}
        assert r4["attn.q"]["n"] * 4 == r1["attn.q"]["n"]     # column ||
        assert r4["attn.o"]["k"] * 4 == r1["attn.o"]["k"]     # row ||
        assert r4["mlp.up"]["n"] * 4 == r1["mlp.up"]["n"]
        assert r4["lm_head"]["n"] * 4 == r1["lm_head"]["n"]
        for site in r1:
            assert r4[site]["ai"] <= r1[site]["ai"]

    def test_scheme_diverges_between_shard_widths(self, setup):
        """THE acceptance assertion: on SHARD_HW, TP=4 selects a
        different ABFT scheme than TP=1 for at least one layer."""
        cfg, model, _ = setup
        p1 = model.protection_plan(hw=SHARD_HW, phase="serve",
                                   n_tokens=64, model_parallel=1)
        p4 = model.protection_plan(hw=SHARD_HW, phase="serve",
                                   n_tokens=64, model_parallel=4)
        r1 = {r["layer"]: r for r in p1.report_rows()}
        r4 = {r["layer"]: r for r in p4.report_rows()}
        diverged = [s for s in r1
                    if r1[s]["scheme"] != r4[s]["scheme"]]
        assert diverged                       # >= 1 layer flips scheme
        for s in diverged:
            assert (r1[s]["scheme"], r1[s]["bound"]) == \
                ("global", "compute")
            assert (r4[s]["scheme"], r4[s]["bound"]) == \
                ("block_1s", "bandwidth")
        # and narrow shards keep schemes where both sit in one regime
        assert r1["attn.k"]["scheme"] == r4["attn.k"]["scheme"]

    def test_plan_json_roundtrips_model_parallel(self, setup):
        from repro.core.policy import ProtectionPlan
        cfg, model, _ = setup
        p4 = model.protection_plan(hw=SHARD_HW, phase="serve",
                                   n_tokens=64, model_parallel=4)
        assert p4.model_parallel == 4
        rt = ProtectionPlan.from_json(p4.to_json())
        assert rt.model_parallel == 4
        assert [r["scheme"] for r in rt.report_rows()] == \
            [r["scheme"] for r in p4.report_rows()]

    def test_engine_plan_rows_in_telemetry(self, setup):
        cfg, model, params = setup
        tel = EngineTelemetry(trace=True)
        abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                          hardware=SHARD_HW)
        eng = ServeEngine(model, params, slots=2, max_len=32, abft=abft,
                          dtype=jnp.bfloat16, telemetry=tel)
        rows = [e for e in tel.tracer.events if e["name"] == "plan_row"]
        assert len(rows) == len(eng.plan.report_rows())
        for e in rows:
            assert e["args"]["model_parallel"] == 1
            assert "scheme" in e["args"] and "ai" in e["args"]


# ------------------------------------------------------- executor layer
class TestExecutors:
    def test_mesh_executor_rejects_meshless_axis(self, setup):
        cfg, model, params = setup
        from jax.sharding import Mesh
        m = Mesh(np.array(jax.devices()[:1]), ("x",))
        with pytest.raises(ValueError, match="model"):
            MeshExecutor(model, params, mesh=m, dtype=jnp.bfloat16)

    def test_mesh1_executor_matches_local(self, setup):
        cfg, model, params = setup
        local = LocalExecutor(model, params, dtype=jnp.bfloat16)
        sharded = MeshExecutor(model, params, mesh=1, dtype=jnp.bfloat16)
        assert sharded.model_parallel == 1
        assert local.protection_plan(ABFTConfig(), slots=4).to_json() == \
            sharded.protection_plan(ABFTConfig(), slots=4).to_json()

    def test_engine_mesh1_streams_match_local(self, setup):
        cfg, model, params = setup
        ref = ServeEngine(model, params, slots=3, max_len=64,
                          dtype=jnp.bfloat16).run(_reqs(cfg))
        got = ServeEngine(model, params, slots=3, max_len=64,
                          dtype=jnp.bfloat16, mesh=1).run(_reqs(cfg))
        assert got == ref


# ------------------------------------------------ mesh stream equality
@pytest.mark.parametrize("k", [2, 4])
class TestMeshEquivalence:
    def _skip(self, k):
        if N_DEV < k:
            pytest.skip(f"needs {k} devices, have {N_DEV} (set XLA_FLAGS="
                        f"--xla_force_host_platform_device_count=8)")

    def test_dense_streams_byte_identical(self, setup, k):
        self._skip(k)
        cfg, model, params = setup
        ref = ServeEngine(model, params, slots=3, max_len=64,
                          dtype=jnp.bfloat16).run(_reqs(cfg))
        eng = ServeEngine(model, params, slots=3, max_len=64,
                          dtype=jnp.bfloat16, mesh=k)
        assert eng.model_parallel == k
        assert eng.run(_reqs(cfg)) == ref

    def test_paged_chunked_prefix_streams_byte_identical(self, setup, k):
        self._skip(k)
        cfg, model, params = setup
        kw = dict(slots=3, max_len=64, dtype=jnp.bfloat16,
                  cache_kind="paged", block_size=8, prefix_sharing=True,
                  chunk_tokens=12)
        # shared prefixes across requests so COW + the prefix index
        # engage on both engines
        reqs = _reqs(cfg, n=6, seed=3)
        for r in reqs[3:]:
            r.prompt = np.concatenate(
                [reqs[0].prompt[:12], r.prompt]).astype(np.int32)
        ref_eng = ServeEngine(model, params, **kw)
        ref = ref_eng.run([Request(r.uid, r.prompt.copy(),
                                   r.max_new_tokens) for r in reqs])
        eng = ServeEngine(model, params, mesh=k, **kw)
        got = eng.run([Request(r.uid, r.prompt.copy(), r.max_new_tokens)
                       for r in reqs])
        assert got == ref
        assert eng.stats.prefix_tokens_shared == \
            ref_eng.stats.prefix_tokens_shared > 0
        assert eng.stats.prefill_chunks == ref_eng.stats.prefill_chunks
        assert eng.pool.blocks_used == 0        # drained clean

    def test_streams_match_under_faults_with_retry(self, setup, k):
        self._skip(k)
        cfg, model, params = setup
        fault = ModelFault.at(0, "mlp_down", FaultSpec.value(0, 1, 1e5))
        kw = dict(slots=3, max_len=64, dtype=jnp.bfloat16,
                  cache_kind="paged", block_size=8)
        outs, engines = [], []
        for mesh in (None, k):
            eng = ServeEngine(model, params, mesh=mesh, **kw)
            outs.append(eng.run(_reqs(cfg), fault_at=(2, fault),
                                admit_fault_at=(1, fault)))
            engines.append(eng)
        assert outs[1] == outs[0]
        for eng in engines:
            assert eng.stats.faults_detected >= 2    # decode AND prefill
            assert eng.stats.retries >= 2
            assert eng.stats.hard_faults == 0        # recovery succeeded

    def test_hard_fault_eviction_matches(self, setup, k):
        self._skip(k)
        cfg, model, params = setup
        fault = ModelFault.at(0, "mlp_down", FaultSpec.value(0, 1, 1e5))
        kw = dict(slots=2, max_len=64, dtype=jnp.bfloat16,
                  policy=RecoveryPolicy(max_retries=0,
                                        evict_on_hard_fault=True))
        outs, engines = [], []
        for mesh in (None, k):
            eng = ServeEngine(model, params, mesh=mesh, **kw)
            reqs = _reqs(cfg, n=4, seed=5)
            outs.append((eng.run(reqs, fault_at=(1, fault)),
                         {r.uid: r.error for r in reqs}))
            engines.append(eng)
        assert outs[1] == outs[0]
        for eng in engines:
            assert eng.stats.hard_faults == 1
            assert eng.stats.evictions >= 1


# ----------------------------------------------- sharded telemetry plan
@pytest.mark.skipif(N_DEV < 2, reason="needs 2 devices")
def test_sharded_plan_rows_in_telemetry(setup):
    cfg, model, params = setup
    tel = EngineTelemetry(trace=True)
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                      hardware=SHARD_HW)
    eng = ServeEngine(model, params, slots=2, max_len=32, abft=abft,
                      dtype=jnp.bfloat16, mesh=2, telemetry=tel)
    rows = [e for e in tel.tracer.events if e["name"] == "plan_row"]
    assert rows
    for e in rows:
        assert e["args"]["model_parallel"] == 2
    # the exported rows ARE the per-shard plan: dims match the TP=2 plan
    assert {e["args"]["layer"] for e in rows} == \
        {r["layer"] for r in eng.plan.report_rows()}


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-x", "-q"]))
