"""Tests for the protection-coverage auditor (src/repro/analysis/):
jaxpr walking + FLOP accounting, marker classification, the full
per-config audits, the plan <-> trace crosscheck, serialized-plan
static validation, and the ABFTConfig deprecation surface."""

import json
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.audit import (
    KNOWN_GAP_NOTES,
    audit_config,
    classify,
    resolve_arch,
)
from repro.analysis.crosscheck import crosscheck_plan
from repro.analysis.jaxpr_walk import flop_ops
from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core import ABFTConfig, FixedPolicy, Scheme, protected_matmul
from repro.core.policy import PlanValidationError, validate_plan_payload
from repro.models import build_model

# ----------------------------------------------------------- jaxpr walker


def test_unmarked_dot_detected_with_path_and_flops():
    """A deliberately unmarked dot_general next to a protected one is
    classified unprotected, with its path and exact FLOP count."""
    abft = ABFTConfig(use_pallas=False)

    def fn(x, w1, w2):
        y, _ = protected_matmul(x, w1, abft, out_dtype=jnp.float32,
                                site="toy.protected")
        return y @ w2                      # the drift this auditor catches

    x = jnp.zeros((4, 16))
    w1 = jnp.zeros((16, 32))
    w2 = jnp.zeros((32, 8))
    ops = classify(flop_ops(jax.make_jaxpr(fn)(x, w1, w2), entry="toy"))

    bad = [c for c in ops if c.status == "unprotected"]
    assert len(bad) == 1
    assert bad[0].op.primitive == "dot_general"
    assert bad[0].op.flops == 2.0 * 4 * 32 * 8
    assert bad[0].op.path.startswith("toy/")
    good = [c for c in ops if c.status == "protected"]
    assert {c.site for c in good} == {"toy.protected"}
    assert all(c.scheme for c in good)


def test_scan_multiplier_restores_layer_repeats():
    """A scanned GEMM body traces once; the walker multiplies FLOPs by
    the trip count and records it in ``repeats``."""
    w = jnp.zeros((16, 16))

    def body(x, _):
        return x @ w, ()

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    ops = flop_ops(jax.make_jaxpr(fn)(jnp.zeros((4, 16))), entry="t")
    dots = [o for o in ops if o.primitive == "dot_general"]
    assert len(dots) == 1
    assert dots[0].repeats == 5
    assert dots[0].flops == 5 * 2.0 * 4 * 16 * 16
    assert "scan[x5]" in dots[0].path


# ------------------------------------------------------------- full audits


def test_llama_mixed_audit_full_coverage():
    """The acceptance gate: llama3.2-1b at --phase mixed passes
    --fail-under 1.0 with a bijective plan and a consistent flash
    allowlist (alias spelling exercises resolve_arch)."""
    rep = audit_config("llama3_2_1b", phase="mixed")
    assert rep.protected_fraction == 1.0
    assert set(rep.phases) == {"prefill", "decode", "mixed"}
    assert all(not p.unprotected_ops for p in rep.phases.values())
    assert rep.crosscheck.bijective
    assert rep.flash_consistent is True
    # attention score/PV contractions are allowlisted, not silently absent
    assert rep.phases["mixed"].allowlisted_flops > 0


def test_whisper_conv_stem_is_known_unprotected():
    """The conv frontend shows up as an explicit, annotated gap — not as
    a silent pass and not as an audit failure."""
    rep = audit_config("whisper_tiny", phase="prefill", check_flash=False)
    assert rep.protected_fraction == 1.0
    gaps = rep.phases["prefill"].known_unprotected
    assert gaps.get("conv_stem", 0) > 0
    assert "5a" in KNOWN_GAP_NOTES["conv_stem"]
    payload = rep.to_json()
    note = (payload["phases"]["prefill"]["known_unprotected"]
            ["conv_stem"]["note"])
    assert "5a" in note and "conv" in note


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_plan_trace_bijection_every_config(arch):
    """Every registered config's compiled ProtectionPlan and its traced
    prefill+decode union agree site-for-site."""
    from repro.analysis.audit import (
        _audit_abft,
        _zero_params,
        trace_decode,
        trace_prefill,
    )

    model = build_model(scaled_down(get_config(arch)))
    params = _zero_params(model, jnp.float32)
    abft = _audit_abft()
    ops = (trace_prefill(model, params, abft)
           + trace_decode(model, params, abft))
    xc = crosscheck_plan(model.protection_plan(), ops, model=arch)
    assert xc.bijective, xc.report()
    assert len(xc.matched) >= 5


def test_crosscheck_catches_plan_drift():
    """Dropping a plan entry / renaming a site produces diff-style
    plan-only / trace-only lines, not a silent pass."""
    import dataclasses as dc

    from repro.analysis.audit import (
        _audit_abft,
        _zero_params,
        trace_decode,
    )

    model = build_model(scaled_down(get_config("llama3.2-1b")))
    params = _zero_params(model, jnp.float32)
    ops = trace_decode(model, params, _audit_abft())
    plan = model.protection_plan()

    dropped = dc.replace(plan, entries=plan.entries[1:])
    xc = crosscheck_plan(dropped, ops, model="llama")
    assert not xc.bijective
    assert xc.trace_only == (plan.entries[0].layer.name,)
    assert plan.entries[0].layer.name in xc.report()

    e0 = plan.entries[0]
    renamed = dc.replace(plan, entries=(
        dc.replace(e0, layer=dc.replace(e0.layer, name="ghost.site")),
    ) + plan.entries[1:])
    xc = crosscheck_plan(renamed, ops, model="llama")
    assert "ghost.site" in xc.plan_only
    assert "plan-only" in xc.report()


def test_resolve_arch_aliases_and_errors():
    assert resolve_arch("llama3.2-1b") == "llama3.2-1b"
    assert resolve_arch("llama3_2_1b") == "llama3.2-1b"
    assert resolve_arch("whisper_tiny") == "whisper-tiny"
    with pytest.raises(KeyError, match="unknown arch"):
        resolve_arch("gpt-5")


# ------------------------------------------------- plan static validation


def _plan_payload():
    model = build_model(scaled_down(get_config("llama3.2-1b")))
    return json.loads(model.protection_plan().to_json())


def test_plan_json_roundtrip_validates():
    from repro.core.policy import ProtectionPlan

    d = _plan_payload()
    validate_plan_payload(d)               # no problems
    plan = ProtectionPlan.from_json(json.dumps(d))
    assert plan.entries


def test_plan_unknown_scheme_rejected():
    d = _plan_payload()
    d["layers"][0]["scheme"] = "tmr_voting"
    with pytest.raises(PlanValidationError) as ei:
        validate_plan_payload(d)
    msg = str(ei.value)
    assert "unknown scheme 'tmr_voting'" in msg
    assert "registered:" in msg            # actionable: lists valid names
    assert d["layers"][0]["name"] in msg


def test_plan_stale_dims_rejected():
    d = _plan_payload()
    d["layers"][1]["dims"]["k"] = 0
    d["layers"][2]["dims"]["n"] = "4096"
    with pytest.raises(PlanValidationError, match="2 problems"):
        validate_plan_payload(d)


def test_plan_duplicate_layer_rejected():
    d = _plan_payload()
    d["layers"].append(dict(d["layers"][0]))
    with pytest.raises(PlanValidationError) as ei:
        validate_plan_payload(d)
    assert "duplicate layer name" in str(ei.value)
    assert "first at layers[0]" in str(ei.value)


def test_plan_policy_scheme_names_validated():
    d = _plan_payload()
    d["policy"] = {"kind": "fixed", "scheme": "parity_cache"}
    with pytest.raises(PlanValidationError,
                       match="policy.scheme: unknown scheme"):
        validate_plan_payload(d)


# --------------------------------------------------- deprecation surface


def test_abftconfig_legacy_scheme_warns():
    with pytest.warns(DeprecationWarning, match="ProtectionPolicy"):
        ABFTConfig(scheme=Scheme.GLOBAL)


def test_abftconfig_modern_surfaces_stay_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ABFTConfig()                               # default AUTO
        ABFTConfig(use_pallas=False, flash_attention=True)
        ABFTConfig.from_policy(FixedPolicy(Scheme.GLOBAL))
        ABFTConfig.off()


# ----------------------------------------------------------------- the CLI


def test_audit_cli_single_config(tmp_path):
    from repro.launch.audit import main

    out = tmp_path / "audit.json"
    rc = main(["--config", "llama3_2_1b", "--phase", "decode",
               "--fail-under", "1.0", "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["schema"] == "repro/audit_coverage/v1"
    rep = payload["configs"]["llama3.2-1b"]
    assert rep["protected_fraction"] == 1.0
    assert rep["crosscheck"]["bijective"]
