"""TP head padding (perf feature): the padded model must be mathematically
identical to the logical one — padded wo rows are zero, so padded-head
attention garbage never reaches the residual stream."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig
from repro.models import LayerCtx, build_model
from repro.models.attention import eff_counts, init_gqa

CTX = LayerCtx(abft=ABFTConfig.off())


def _models(arch="qwen1.5-32b", pad=6, pad_kv=6, **over):
    base = scaled_down(get_config(arch), n_heads=5, n_kv_heads=5,
                       head_dim=16, **over)
    padded = dataclasses.replace(base, pad_heads_to=pad,
                                 pad_kv_heads_to=pad_kv)
    return base, padded


def test_eff_counts():
    base, padded = _models()
    assert eff_counts(base) == (5, 5)
    assert eff_counts(padded) == (6, 6)


def test_padded_params_embed_logical_weights():
    base, padded = _models()
    p = init_gqa(padded, jax.random.PRNGKey(0), jnp.float32)
    hd = padded.resolved_head_dim
    assert p["wq"].shape == (padded.d_model, 6 * hd)
    # padded head slots are zero
    w4 = np.asarray(p["wq"]).reshape(padded.d_model, 6, hd)
    assert np.all(w4[:, 5:, :] == 0)
    wo4 = np.asarray(p["wo"]).reshape(6, hd, padded.d_model)
    assert np.all(wo4[5:, :, :] == 0)


def test_forward_exact_equivalence():
    """Same logical weights, padded vs unpadded: identical logits."""
    base, padded = _models()
    mb = build_model(base)
    mp = build_model(padded)
    params_b = mb.init_params(jax.random.PRNGKey(7), dtype=jnp.float32)
    params_p = mp.init_params(jax.random.PRNGKey(7), dtype=jnp.float32)

    # init draws identical randoms for the logical part; verify the padded
    # params contain the logical weights in the kv-major layout
    def fix(tree_b, tree_p):
        # graft logical weights into the padded param tree
        def graft(pb, pp):
            if pb.shape == pp.shape:
                return pb
            # head-padded weight (possibly segment-stacked): embed the
            # logical block into the padded layout along the head axis
            hd = base.resolved_head_dim
            z = jnp.zeros_like(pp)
            diff = [i for i in range(pb.ndim)
                    if pb.shape[i] != pp.shape[i]]
            assert len(diff) == 1, (pb.shape, pp.shape)
            ax = diff[0]
            H = pb.shape[ax] // hd
            Hp = pp.shape[ax] // hd
            lead = pb.shape[:ax]
            tail = pb.shape[ax + 1:]
            w = pb.reshape(lead + (H, hd) + tail)
            zr = z.reshape(lead + (Hp, hd) + tail)
            idx = tuple([slice(None)] * len(lead) + [slice(0, H)])
            return zr.at[idx].set(w).reshape(pp.shape)

        return jax.tree_util.tree_map(graft, tree_b, tree_p)

    params_p = fix(params_b, params_p)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 17}
    out_b = mb.forward(params_b, batch, CTX)
    out_p = mp.forward(params_p, batch, CTX)
    np.testing.assert_allclose(
        np.asarray(out_b.logits), np.asarray(out_p.logits),
        rtol=1e-5, atol=1e-5)


def test_padded_decode_cache_shape():
    base, padded = _models()
    m = build_model(padded)
    cache = m.init_cache(2, 8, dtype=jnp.float32)
    k = cache[0]["pos0"]["attn"]["k"]
    assert k.shape[-2] == 6   # padded KV heads in the cache


def test_gqa_group_padding():
    """GQA: pad groups per kv head (kv-major layout preserved)."""
    base = scaled_down(get_config("llama3.2-1b"), n_heads=4, n_kv_heads=2,
                       head_dim=8)
    padded = dataclasses.replace(base, pad_heads_to=6, pad_kv_heads_to=2)
    assert eff_counts(padded) == (6, 2)
    p = init_gqa(padded, jax.random.PRNGKey(0), jnp.float32)
    hd = 8
    w = np.asarray(p["wq"]).reshape(padded.d_model, 2, 3, hd)
    assert np.all(w[:, :, 2:, :] == 0)      # padded group slots zero
    assert np.any(w[:, :, :2, :] != 0)
