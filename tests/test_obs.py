"""Serving telemetry subsystem (repro/obs): metrics registry export
invariants, trace-JSON validity, fault-rate monitor math, engine
integration (mirrored counters exact, byte-identical streams, fault
spans), stride-decimation alignment, heartbeat gauges, and the launch
driver's --metrics-out/--trace-out artifacts.
"""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.core.hardware import HardwareSpec
from repro.models import ModelFault, build_model
from repro.obs import (
    ENGINE_COUNTERS,
    CardinalityError,
    EngineTelemetry,
    FaultRateMonitor,
    MetricsRegistry,
    RegistrationError,
    Tracer,
    check_events,
)
from repro.runtime.heartbeat import HeartbeatMonitor
from repro.serve.engine import EngineStats, Request, ServeEngine

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)

# same spec as tests/test_chunked_prefill.py: selection flips between
# block_1s (decode-only, m <= 16) and global (mixed, m >= 32) on the
# scaled test model
FLIP_HW = HardwareSpec(
    name="flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


def _reqs(spec):
    return [Request(uid=i, prompt=np.arange(1, 1 + L, dtype=np.int32),
                    max_new_tokens=n)
            for i, (L, n) in enumerate(spec)]


# ==================================================== metrics registry

class TestMetrics:
    def test_counter_inc_and_negative_raises(self):
        c = MetricsRegistry().counter("c_total")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_counter_inc_to_monotonic(self):
        c = MetricsRegistry().counter("c_total")
        c.inc_to(7)
        c.inc_to(7)                      # equal is fine
        assert c.value == 7
        with pytest.raises(ValueError):
            c.inc_to(6)

    def test_gauge_set_inc_dec(self):
        g = MetricsRegistry().gauge("g")
        g.set(4)
        g.inc()
        g.dec(2)
        assert g.value == 3.0

    def test_label_validation(self):
        r = MetricsRegistry()
        c = r.counter("lc_total", labels=("scheme",))
        with pytest.raises(ValueError):
            c.labels(wrong="x")
        with pytest.raises(ValueError):
            c.labels()                   # missing declared label
        with pytest.raises(ValueError):
            c.inc()                      # label-less access on a family
        with pytest.raises(ValueError):
            r.counter("bad name")
        with pytest.raises(ValueError):
            r.counter("h_total", labels=("le",))

    def test_cardinality_cap(self):
        c = MetricsRegistry().counter(
            "uid_total", labels=("uid",), max_series=4)
        for i in range(4):
            c.labels(uid=i).inc()
        c.labels(uid=0).inc()            # existing series: still fine
        with pytest.raises(CardinalityError):
            c.labels(uid=99)

    def test_registry_idempotent_and_conflict(self):
        r = MetricsRegistry()
        a = r.counter("x_total", labels=("k",))
        assert r.counter("x_total", labels=("k",)) is a
        with pytest.raises(RegistrationError):
            r.gauge("x_total")
        with pytest.raises(RegistrationError):
            r.counter("x_total", labels=("other",))
        h = r.histogram("lat", buckets=(1.0, 2.0))
        assert r.histogram("lat", buckets=(1.0, 2.0)) is h
        with pytest.raises(RegistrationError):
            r.histogram("lat", buckets=(1.0, 2.0, 3.0))

    def test_histogram_invariants(self):
        h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.1, 0.5, 2.0, 99.0):
            h.observe(v)
        cum = h._default().cumulative()
        assert [c for _, c in cum] == [2, 3, 4, 5]
        assert cum[-1][0] == math.inf
        assert cum[-1][1] == h.count == 5   # +Inf count == count
        assert h.sum == pytest.approx(101.65)
        counts = [c for _, c in cum]
        assert counts == sorted(counts)     # cumulative never decreases
        with pytest.raises(ValueError):
            MetricsRegistry().histogram("bad", buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            MetricsRegistry().histogram("bad", buckets=(math.inf,))

    def test_snapshot_is_json_ready(self):
        r = MetricsRegistry()
        r.counter("c_total", "help c").inc(3)
        r.histogram("lat", buckets=(1.0,)).observe(0.5)
        g = r.gauge("g", labels=("w",))
        g.labels(w="a").set(1)
        snap = json.loads(r.to_json())
        assert snap["c_total"]["type"] == "counter"
        assert snap["c_total"]["series"][0]["value"] == 3
        assert snap["g"]["series"][0]["labels"] == {"w": "a"}
        buckets = snap["lat"]["series"][0]["buckets"]
        assert buckets[-1][0] == "+Inf"
        assert buckets[-1][1] == snap["lat"]["series"][0]["count"] == 1

    def test_prometheus_exposition(self):
        r = MetricsRegistry()
        c = r.counter("req_total", "requests served",
                      labels=("scheme",))
        c.labels(scheme='glo"bal\\x\n').inc(2)
        h = r.histogram("lat_seconds", "latency", buckets=(0.5, 1.0))
        h.observe(0.3)
        h.observe(5.0)
        text = r.render_prometheus()
        lines = text.splitlines()
        assert "# HELP req_total requests served" in lines
        assert "# TYPE req_total counter" in lines
        # label escaping: backslash, quote, newline
        assert 'req_total{scheme="glo\\"bal\\\\x\\n"} 2' in lines
        assert "# TYPE lat_seconds histogram" in lines
        assert 'lat_seconds_bucket{le="0.5"} 1' in lines
        assert 'lat_seconds_bucket{le="1"} 1' in lines
        assert 'lat_seconds_bucket{le="+Inf"} 2' in lines
        assert "lat_seconds_sum 5.3" in lines
        assert "lat_seconds_count 2" in lines
        assert text.endswith("\n")

    def test_remove_series(self):
        g = MetricsRegistry().gauge("g", labels=("w",))
        g.labels(w="a").set(1)
        g.remove(w="a")
        assert list(g.series()) == []


# ============================================================= tracing

class TestTrace:
    def test_spans_nest_and_validate(self):
        t = [0]

        def clock():
            t[0] += 1000
            return t[0]

        tr = Tracer(clock=clock)
        with tr.span("outer", {"a": 1}):
            with tr.span("inner") as sp:
                sp.set_args(b=2)
        tr.instant("blip", {"k": "v"})
        evs = tr.events
        assert [e["name"] for e in evs] == ["inner", "outer", "blip"]
        assert evs[0]["ph"] == "X" and evs[0]["args"] == {"b": 2}
        assert evs[2]["ph"] == "i" and evs[2]["s"] == "t"
        assert check_events(evs) == []
        doc = tr.to_dict()
        assert doc["traceEvents"] == evs
        assert doc["otherData"]["dropped_events"] == 0

    def test_disabled_tracer_is_noop(self):
        tr = Tracer(enabled=False)
        s1 = tr.span("a")
        s2 = tr.span("b")
        assert s1 is s2                  # shared null span, no alloc
        with s1 as sp:
            sp.fence(object())           # must not touch jax
            sp.set_args(x=1)
        tr.instant("i")
        assert tr.events == [] and tr.dropped == 0

    def test_max_events_and_dropped(self):
        tr = Tracer(max_events=2)
        for i in range(5):
            tr.instant(f"e{i}")
        assert len(tr.events) == 2 and tr.dropped == 3
        assert tr.to_dict()["otherData"]["dropped_events"] == 3

    def test_sink_sees_dropped_events_too(self):
        seen = []
        tr = Tracer(max_events=1, sink=seen.append)
        tr.instant("a")
        tr.instant("b")
        assert [e["name"] for e in seen] == ["a", "b"]

    def test_check_events_catches_problems(self):
        bad_phase = [{"name": "x", "ph": "Q", "ts": 0}]
        assert check_events(bad_phase)
        neg = [{"name": "x", "ph": "X", "ts": 1.0, "dur": -2.0}]
        assert check_events(neg)
        overlap = [
            {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0},
            {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0},
        ]
        assert any("overlap" in p for p in check_events(overlap))
        # same intervals on distinct tids: fine
        overlap[1]["tid"] = 1
        assert check_events(overlap) == []


# ==================================================== fault-rate monitor

class TestFaultRate:
    def test_windowed_rates(self):
        m = FaultRateMonitor(window=4)
        for _ in range(3):
            m.observe(steps=1, tokens=2)
        m.observe(steps=1, tokens=2, detections=1, retries=1)
        assert m.window_detection_rate == pytest.approx(0.25)
        assert m.window_detection_rate_per_token == pytest.approx(0.125)
        assert m.window_retry_rate == pytest.approx(0.25)
        assert m.window_hard_fault_rate == 0.0
        # window slides: the faulty observation ages out after 4 more
        for _ in range(4):
            m.observe(steps=1, tokens=2)
        assert m.window_detection_rate == 0.0
        assert m.detections == 1         # lifetime total survives

    def test_ewma(self):
        m = FaultRateMonitor(window=8, alpha=0.5)
        m.observe(steps=1, detections=1)
        assert m.ewma_detections == pytest.approx(0.5)
        m.observe(steps=1)
        assert m.ewma_detections == pytest.approx(0.25)

    def test_snapshot_keys(self):
        m = FaultRateMonitor(window=2)
        m.observe(steps=1, tokens=3, hard_faults=1)
        snap = m.snapshot()
        for k in ("window", "window_detection_rate",
                  "window_detection_rate_per_token", "window_retry_rate",
                  "window_hard_fault_rate", "ewma_detections_per_step",
                  "total_steps", "total_detections"):
            assert k in snap
        assert snap["window_hard_fault_rate"] == 1.0
        assert snap["total_tokens"] == 3
        json.dumps(snap)

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultRateMonitor(window=0)
        with pytest.raises(ValueError):
            FaultRateMonitor(alpha=0.0)

    def test_empty_window_snapshot(self):
        """No observations yet: every rate is 0.0 (not NaN / division
        error) and the snapshot is still JSON-complete."""
        m = FaultRateMonitor(window=4)
        assert m.window_detection_rate == 0.0
        assert m.window_detection_rate_per_token == 0.0
        assert m.window_retry_rate == 0.0
        assert m.window_hard_fault_rate == 0.0
        snap = m.snapshot()
        assert snap["window_filled"] == 0
        assert snap["window_steps"] == 0
        assert snap["total_steps"] == 0
        json.dumps(snap)

    def test_window_of_one_tracks_last_observation_only(self):
        m = FaultRateMonitor(window=1)
        m.observe(steps=1, tokens=2, detections=1)
        assert m.window_detection_rate == 1.0
        m.observe(steps=1, tokens=2)
        # the faulty observation fell out of the 1-deep window …
        assert m.window_detection_rate == 0.0
        assert m.snapshot()["window_filled"] == 1
        # … but the lifetime total keeps it
        assert m.detections == 1

    def test_reset_rebaselines_keeping_lifetime_totals(self):
        m = FaultRateMonitor(window=4, alpha=0.5)
        for _ in range(3):
            m.observe(steps=1, tokens=2, detections=1, retries=1,
                      hard_faults=1)
        assert m.window_detection_rate == 1.0
        assert m.ewma_detections > 0
        m.reset()
        # responsive signals cleared …
        assert m.window_detection_rate == 0.0
        assert m.window_retry_rate == 0.0
        assert m.window_hard_fault_rate == 0.0
        assert m.ewma_detections == 0.0
        assert m.ewma_retries == 0.0
        assert m.ewma_hard_faults == 0.0
        assert m.observations == 0
        assert m.snapshot()["window_filled"] == 0
        # … lifetime audit trail survives
        assert m.steps == 3
        assert m.detections == 3
        assert m.retries == 3
        assert m.hard_faults == 3
        # and the monitor keeps working after the re-baseline
        m.observe(steps=1, detections=1)
        assert m.window_detection_rate == 1.0
        assert m.detections == 4


# ============================================ stride-decimation alignment

def test_selection_trace_decimation_keeps_step_alignment():
    """Regression for the [::2] decimation bug: after ANY number of
    halving rounds, entry k of the trace must be the observation
    numbered (k+1)*stride — i.e. the recorded step ids are exactly the
    multiples of the current stride.  [::2] kept the odd multiples of
    the old stride, which the doubled stride can never produce, so
    alignment broke on the second round."""
    stats = EngineStats()
    stats.MAX_OCCUPANCY_SAMPLES = 8
    n = 70                               # > 3 halving rounds (stride 8)
    for step in range(1, n + 1):
        stats.steps = step
        stats.observe_selection(1, 0, 0.5, "block_1s")
    assert stats.selection_stride == 8
    assert stats.selection_count == n
    for k, entry in enumerate(stats.selection_trace):
        assert entry["step"] == (k + 1) * stats.selection_stride


def test_blocks_used_decimation_keeps_alignment():
    stats = EngineStats()
    stats.MAX_OCCUPANCY_SAMPLES = 8
    n = 70
    for i in range(1, n + 1):
        stats.observe_blocks_used(i)     # observation i records value i
    assert stats.blocks_used_stride == 8
    for k, v in enumerate(stats.blocks_used_samples):
        assert v == (k + 1) * stats.blocks_used_stride
    assert stats.blocks_used_peak == n
    assert stats.blocks_used_count == n


# ==================================================== engine integration

class TestEngineTelemetry:
    def test_counters_match_and_streams_identical(self, small_model):
        """Mirrored counters equal EngineStats exactly after a run, and
        the greedy token streams are byte-identical with telemetry
        (tracing + fencing) enabled or disabled."""
        _, model, params = small_model
        spec = [(5, 6), (9, 4), (3, 5), (7, 3)]

        def run(telemetry):
            eng = ServeEngine(model, params, slots=2, max_len=64,
                              abft=ABFT, dtype=jnp.float32,
                              telemetry=telemetry)
            reqs = _reqs(spec)
            eng.run(reqs)
            return eng, reqs

        eng0, reqs0 = run(None)
        tel = EngineTelemetry(trace=True)
        eng1, reqs1 = run(tel)
        assert [r.generated for r in reqs1] == \
            [r.generated for r in reqs0]
        assert tel.counters_match(eng1.stats)
        snap = tel.registry.snapshot()
        for name, attr in ENGINE_COUNTERS.items():
            assert snap[name]["series"][0]["value"] == \
                getattr(eng1.stats, attr)
        assert check_events(tel.tracer.events) == []
        names = {e["name"] for e in tel.tracer.events}
        assert {"admit", "prefill", "decode_step", "abft_check"} <= names

    def test_fault_injection_telemetry(self, small_model):
        """An injected transient fault shows up on every surface: the
        FaultRateMonitor's windowed detection rate, an abft_retry span,
        and a fault_detected instant — and the recovered stream still
        matches the clean run."""
        _, model, params = small_model
        spec = [(5, 8), (7, 8)]

        def run(telemetry, fault_at):
            eng = ServeEngine(model, params, slots=2, max_len=64,
                              abft=ABFT, dtype=jnp.float32,
                              telemetry=telemetry)
            reqs = _reqs(spec)
            eng.run(reqs, fault_at=fault_at)
            return eng, reqs

        _, clean = run(None, None)
        tel = EngineTelemetry(trace=True, fault_window=16)
        fault = (3, ModelFault.at(0, "mlp_down",
                                  FaultSpec.value(0, 1, 1e5)))
        eng, reqs = run(tel, fault)
        assert [r.generated for r in reqs] == \
            [r.generated for r in clean]
        assert eng.stats.faults_detected >= 1
        assert tel.counters_match(eng.stats)
        assert tel.faults.detections == eng.stats.faults_detected
        assert tel.faults.window_detection_rate > 0.0
        assert tel.faults.ewma_detections > 0.0
        names = [e["name"] for e in tel.tracer.events]
        assert "abft_retry" in names
        assert "fault_detected" in names
        assert check_events(tel.tracer.events) == []
        # the windowed-rate gauges were published at sync time
        g = tel.registry.get("abft_detection_rate_window")
        assert g.value == pytest.approx(tel.faults.window_detection_rate)

    def test_scheme_flip_instants(self, small_model):
        """Chunked serving on FLIP_HW crosses the intensity regime
        between mixed and decode-only steps; every crossing emits a
        scheme_flip instant carrying the selection context and bumps
        the mirrored serve_scheme_flips_total counter."""
        _, model, params = small_model
        abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                          hardware=FLIP_HW)
        tel = EngineTelemetry(trace=True)
        eng = ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                          dtype=jnp.float32, chunk_tokens=48,
                          telemetry=tel)
        resident = _reqs([(4, 12)])[0]
        eng.admit([resident])
        while eng._prefill_cursors:
            eng.step()
        pending = [Request(uid=10 + i,
                           prompt=np.arange(1, 48, dtype=np.int32),
                           max_new_tokens=2) for i in range(2)]
        while pending or eng.active or eng._prefill_cursors:
            if pending and eng.free_slots():
                eng.admit(pending)
            eng.step()

        flips = [e for e in tel.tracer.events
                 if e["name"] == "scheme_flip"]
        assert eng.stats.scheme_flips >= 2      # enters AND leaves global
        assert len(flips) == eng.stats.scheme_flips
        for f in flips:
            assert f["ph"] == "i"
            assert set(f["args"]) == {"intensity", "scheme", "decode",
                                      "prefill", "model_parallel"}
            assert f["args"]["model_parallel"] == 1
            assert f["args"]["scheme"] in (Scheme.GLOBAL.value,
                                           Scheme.BLOCK_1S.value)
        assert {f["args"]["scheme"] for f in flips} == \
            {Scheme.GLOBAL.value, Scheme.BLOCK_1S.value}
        assert tel.counters_match(eng.stats)
        names = {e["name"] for e in tel.tracer.events}
        assert "prefill_chunk" in names
        assert check_events(tel.tracer.events) == []

    def test_step_latency_histogram_fills(self, small_model):
        _, model, params = small_model
        tel = EngineTelemetry()
        eng = ServeEngine(model, params, slots=2, max_len=64, abft=ABFT,
                          dtype=jnp.float32, telemetry=tel)
        eng.run(_reqs([(4, 4), (6, 3)]))
        assert tel.step_latency.count == eng.stats.steps
        cum = tel.step_latency._default().cumulative()
        assert cum[-1][1] == tel.step_latency.count


# ======================================================= heartbeat gauges

class TestHeartbeatGauges:
    def test_liveness_and_staleness(self):
        now = [0.0]
        reg = MetricsRegistry()
        mon = HeartbeatMonitor(["w0", "w1"], timeout_s=10.0,
                               clock=lambda: now[0], registry=reg)
        alive = reg.get("worker_alive")
        stale = reg.get("worker_heartbeat_staleness_seconds")
        assert alive.labels(worker="w0").value == 1
        now[0] = 6.0
        mon.beat("w0")
        now[0] = 11.0
        assert mon.check() == ["w1"]
        assert alive.labels(worker="w0").value == 1
        assert alive.labels(worker="w1").value == 0
        assert stale.labels(worker="w0").value == pytest.approx(5.0)
        assert stale.labels(worker="w1").value == pytest.approx(11.0)
        # late beat revives the worker and the gauge follows
        mon.beat("w1")
        assert alive.labels(worker="w1").value == 1
        mon.remove("w1")
        assert all(lab["worker"] != "w1" for lab, _ in alive.series())
        mon.add("w2")
        assert alive.labels(worker="w2").value == 1
        # prometheus rendering covers the labeled gauges
        assert 'worker_alive{worker="w0"} 1' in reg.render_prometheus()

    def test_no_registry_is_fine(self):
        mon = HeartbeatMonitor(["a"], timeout_s=1.0, clock=lambda: 0.0)
        mon.beat("a")
        assert mon.check() == []


# ===================================================== launch driver e2e

def test_launch_serve_writes_valid_artifacts(tmp_path):
    """--metrics-out / --trace-out produce artifacts that pass the CI
    telemetry schema gate (mirrored counters equal the final engine
    stats; the trace is Perfetto-valid)."""
    import sys

    from repro.launch.serve import main

    sys.path.insert(0, "benchmarks")
    try:
        from check_telemetry_schema import check
    finally:
        sys.path.pop(0)

    m = tmp_path / "m.json"
    t = tmp_path / "t.json"
    rc = main(["--scale", "smoke", "--requests", "3", "--new-tokens",
               "4", "--slots", "2", "--max-len", "64",
               "--inject-faults",
               "--metrics-out", str(m), "--trace-out", str(t)])
    assert rc == 0
    metrics = json.loads(m.read_text())
    trace = json.loads(t.read_text())
    assert check(metrics, trace) == []
    assert metrics["counters_match_stats"] is True
    assert metrics["engine_stats"]["abft_faults_detected_total"] >= 1
    assert metrics["faultrate"]["total_detections"] >= 1
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"admit", "decode_step", "abft_retry",
            "fault_detected"} <= names
