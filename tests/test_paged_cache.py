"""Paged KV-cache subsystem tests: BlockPool accounting, sentinel-safe
device scatter/gather, the block-table-indexed fused-ABFT decode kernel,
and end-to-end paged-vs-dense engine equivalence (greedy decode is
deterministic, so any paging bug shows up as a token divergence).

Block sizes in the equivalence tests divide ``max_len`` so the paged
attention shapes equal the dense ones — token streams must then match
EXACTLY, with and without injected faults."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.models import ModelFault, build_model
from repro.models.layers import decode_attention
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine
from repro.serve.paged_cache import (
    BlockPool,
    PoolExhausted,
    blocks_for,
    paged_gather,
    paged_scatter_decode,
    paged_scatter_prefill,
    pytree_bytes,
)

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    """deepseek-style MLA: the paged latent pool path."""
    cfg = scaled_down(get_config("deepseek-v3-671b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2), dtype=jnp.float32)
    return cfg, model, params


@pytest.fixture(scope="module")
def hybrid_model():
    """jamba: mamba + attention interleave — covers the per-slot SSM
    state riding alongside the paged attention pool."""
    cfg = scaled_down(get_config("jamba-v0.1-52b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1), dtype=jnp.float32)
    return cfg, model, params


def _engine(model, params, slots=2, max_len=64, **kw):
    return ServeEngine(model, params, slots=slots, max_len=max_len,
                       abft=ABFT, dtype=jnp.float32, **kw)


def _req(uid, length, n=5):
    return Request(uid=uid,
                   prompt=np.arange(1, 1 + length, dtype=np.int32),
                   max_new_tokens=n)


# ================================================================ BlockPool

def test_pool_alloc_free_accounting():
    bp = BlockPool(num_blocks=8, block_size=4, slots=3, table_width=4)
    assert bp.blocks_free == 8 and bp.blocks_used == 0
    assert bp.try_alloc(0, 9)            # 3 blocks
    assert bp.slot_blocks(0) == 3 and bp.capacity_tokens(0) == 12
    assert bp.try_alloc(1, 4)            # 1 block
    assert bp.blocks_used == 4
    # grow within the already-covered capacity is a no-op
    assert bp.try_grow(0, 12) and bp.slot_blocks(0) == 3
    assert bp.try_grow(0, 13) and bp.slot_blocks(0) == 4
    assert len(bp.free_slot(0)) == 4     # unshared: all physically freed
    assert bp.blocks_used == 1 and bp.blocks_free == 7
    assert bp.free_slot(0) == []         # idempotent
    bp.reset()
    assert bp.blocks_used == 0 and (bp.tables == bp.sentinel).all()


def test_pool_exhaustion_is_all_or_nothing():
    bp = BlockPool(num_blocks=3, block_size=4, slots=2, table_width=4)
    assert bp.try_alloc(0, 8)            # 2 of 3 blocks
    before = bp.tables.copy()
    assert not bp.try_alloc(1, 9)        # needs 3, only 1 free
    assert bp.blocks_used == 2           # nothing leaked
    np.testing.assert_array_equal(bp.tables, before)
    with pytest.raises(PoolExhausted):
        bp.alloc(1, 9)
    # table width also bounds growth (logical max_len)
    assert not bp.try_grow(0, 17)        # 5 blocks > width 4


def test_pool_free_list_reuse_after_eviction():
    """Freed blocks go back to the head of the free list: an evicted
    request's blocks are the next ones handed out."""
    bp = BlockPool(num_blocks=6, block_size=4, slots=3, table_width=3)
    assert bp.try_alloc(0, 12)
    victim_blocks = list(bp.tables[0, :3])
    assert bp.try_alloc(1, 4)
    bp.free_slot(0)                      # eviction
    assert bp.try_alloc(2, 12)
    assert list(bp.tables[2, :3]) == victim_blocks   # immediate reuse
    assert blocks_for(0, 4) == 0 and blocks_for(5, 4) == 2


# ================================================================ device ops

def test_scatter_gather_roundtrip_and_sentinel_drop():
    bp = BlockPool(num_blocks=5, block_size=4, slots=2, table_width=3)
    pool = jnp.zeros((5, 4, 2), jnp.float32)
    lens = np.array([6, 3], np.int32)
    for s in range(2):
        assert bp.try_alloc(s, int(lens[s]))
    new = jnp.arange(2 * 8 * 2, dtype=jnp.float32).reshape(2, 8, 2) + 1.0
    pool = paged_scatter_prefill(
        pool, new, bp.device_tables(), jnp.asarray(lens))
    g = paged_gather(pool, bp.device_tables())      # (2, 12, 2)
    for s in range(2):
        np.testing.assert_array_equal(
            np.asarray(g[s, : lens[s]]), np.asarray(new[s, : lens[s]]))
        # beyond the valid length everything reads as zero (dropped
        # padding writes, sentinel fill)
        assert not np.asarray(g[s, lens[s]:]).any()

    # decode scatter: slot 0 appends at pos 6; a freed slot's write drops
    bp.free_slot(1)
    step = jnp.full((2, 2), 7.0)
    pool2 = paged_scatter_decode(
        pool, step, bp.device_tables(), jnp.asarray([6, 3], jnp.int32))
    g2 = paged_gather(pool2, bp.device_tables())
    np.testing.assert_array_equal(np.asarray(g2[0, 6]), [7.0, 7.0])
    assert float(jnp.sum(pool2)) == pytest.approx(
        float(jnp.sum(pool)) + 14.0)    # only slot 0's write landed


def test_paged_flash_decode_matches_reference():
    from repro.kernels.flash_ops import flash_decode_paged

    rng = np.random.default_rng(0)
    B, H, KV, D, BS, W, NB = 3, 4, 2, 16, 8, 4, 9
    bp = BlockPool(NB, BS, B, W)
    lens = np.array([5, 17, 24], np.int32)
    for s in range(B):
        assert bp.try_alloc(s, int(lens[s]))
    k_new = jnp.asarray(rng.standard_normal((B, 24, KV, D)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, 24, KV, D)), jnp.float32)
    tables = bp.device_tables()
    pool_k = paged_scatter_prefill(
        jnp.zeros((NB, BS, KV, D), jnp.float32), k_new, tables,
        jnp.asarray(lens))
    pool_v = paged_scatter_prefill(
        jnp.zeros((NB, BS, KV, D), jnp.float32), v_new, tables,
        jnp.asarray(lens))
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)

    ref = decode_attention(
        q, paged_gather(pool_k, tables), paged_gather(pool_v, tables),
        jnp.asarray(lens))
    out, chk = flash_decode_paged(q, pool_k, pool_v, tables,
                                  jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert not bool(chk.flag)            # clean run: no ABFT detection


def test_paged_flash_decode_check_ignores_alien_blocks():
    """Sentinel table tails are clamped onto real (alien) blocks and
    reused blocks keep stale KV; the ABFT score check must be blind to
    them — otherwise their magnitudes inflate the detection threshold
    and real faults in short sequences slip through."""
    from repro.kernels.flash_ops import flash_decode_paged

    rng = np.random.default_rng(1)
    B, H, KV, D, BS, W, NB = 1, 2, 2, 8, 8, 4, 4
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((NB, BS, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((NB, BS, KV, D)), jnp.float32)
    # slot owns only block 0, length 5; table tail is sentinel (=NB),
    # which the wrapper clamps onto block NB-1
    tables = jnp.asarray([[0, NB, NB, NB]], jnp.int32)
    lens = jnp.asarray([5], jnp.int32)
    # blow up the alien block the clamp lands on
    k_hot = k.at[NB - 1].set(1e6)
    v_hot = v.at[NB - 1].set(1e6)

    out, chk = flash_decode_paged(q, k, v, tables, lens)
    out_hot, chk_hot = flash_decode_paged(q, k_hot, v_hot, tables, lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_hot))
    # the detection threshold must not widen because of alien data
    np.testing.assert_allclose(np.asarray(chk.threshold),
                               np.asarray(chk_hot.threshold), rtol=1e-6)
    assert not bool(chk_hot.flag)


# ================================================================ engine

def _mixed_reqs(n=5):
    return [_req(0, 5, n), _req(1, 11, n), _req(2, 23, n)]


def test_paged_engine_matches_dense_mixed_lengths(small_model):
    _, model, params = small_model
    dense = _engine(model, params).run(_mixed_reqs())
    paged_eng = _engine(model, params, cache_kind="paged", block_size=16)
    paged = paged_eng.run(_mixed_reqs())
    assert dense == paged
    # all blocks returned once traffic drains
    assert paged_eng.pool.blocks_used == 0
    assert paged_eng.stats.hard_faults == 0


def test_paged_engine_matches_dense_under_fault_recovery(small_model):
    """A decode-step fault is detected and recovered by recompute from the
    held pre-step pool; streams still match dense exactly."""
    _, model, params = small_model
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    dense = _engine(model, params).run(_mixed_reqs(6), fault_at=(2, fault))
    eng = _engine(model, params, cache_kind="paged", block_size=16)
    paged = eng.run(_mixed_reqs(6), fault_at=(2, fault))
    assert eng.stats.faults_detected >= 1 and eng.stats.retries >= 1
    assert eng.stats.hard_faults == 0
    assert dense == paged


def test_paged_admission_fault_retries_from_pre_admission_pool(small_model):
    _, model, params = small_model
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    dense = _engine(model, params).run(
        [_req(0, 5, 4)], admit_fault_at=(0, fault))
    eng = _engine(model, params, cache_kind="paged", block_size=8,
                  policy=RecoveryPolicy(max_retries=1))
    paged = eng.run([_req(0, 5, 4)], admit_fault_at=(0, fault))
    assert eng.stats.faults_detected == 1 and eng.stats.hard_faults == 0
    assert dense == paged


def test_hard_fault_eviction_frees_blocks_for_reuse(small_model):
    """Persistent decode fault: the victim's blocks return to the free
    list and the NEXT request is served out of the reused blocks."""
    _, model, params = small_model
    eng = _engine(model, params, slots=1, cache_kind="paged", block_size=8,
                  policy=RecoveryPolicy(max_retries=0))
    victim, later = _req(0, 5, 6), _req(1, 8, 3)
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run([victim, later], fault_at=(1, fault))
    assert victim.error == "hard_fault:decode"
    assert eng.stats.hard_faults == 1
    assert eng.pool.blocks_used == 0     # everything came back
    assert results[1] == _engine(model, params, slots=1).run(
        [_req(1, 8, 3)])[1]


def test_pool_exhaustion_rejects_admission_with_error(small_model):
    """A request that can NEVER fit the pool is rejected with a recorded
    error (no crash, no livelock) and the rest of the traffic is
    served."""
    _, model, params = small_model
    eng = _engine(model, params, cache_kind="paged", block_size=16,
                  num_blocks=2)           # 32 cache tokens total
    big, small = _req(0, 40, 3), _req(1, 9, 3)
    results = eng.run([big, small])
    assert big.error == "oom:block_pool" and big.generated == []
    # pre-prefill screening is a REJECTION, not an eviction: the request
    # never held a slot or cache state (accounting-split satellite)
    assert eng.stats.rejections >= 1
    assert eng.stats.evictions == 0
    assert results[1] == _engine(model, params).run([_req(1, 9, 3)])[1]


def test_transient_pool_pressure_defers_instead_of_rejecting(small_model):
    """A request that fits the pool but not RIGHT NOW (blocks held by
    in-flight requests) is deferred, not rejected: it completes without
    error once decode frees blocks, matching the dense engine."""
    _, model, params = small_model
    # 3 blocks of 16: req 0 holds 2, req 1 needs 2 -> deferred until
    # req 0 finishes, then served out of the freed blocks
    eng = _engine(model, params, cache_kind="paged", block_size=16,
                  num_blocks=3)
    a, b = _req(0, 30, 3), _req(1, 20, 3)
    results = eng.run([a, b])
    assert a.error is None and b.error is None
    assert len(results[0]) == 3 and len(results[1]) == 3
    dense = _engine(model, params).run([_req(0, 30, 3), _req(1, 20, 3)])
    assert results == dense
    assert eng.pool.blocks_used == 0


def test_pool_exhaustion_mid_decode_evicts_with_error(small_model):
    """Growth across a block boundary can also exhaust the pool: the slot
    that cannot grow is evicted with a recorded error; the engine and the
    remaining slot keep serving."""
    _, model, params = small_model
    # 3 blocks of 8: two 8-token prompts fill 2 blocks; the single spare
    # goes to slot 0 at its first boundary crossing, slot 1 then starves
    eng = _engine(model, params, cache_kind="paged", block_size=8,
                  num_blocks=3)
    a, b = _req(0, 8, 6), _req(1, 8, 6)
    eng.run([a, b])
    assert {a.error, b.error} == {None, "oom:kv_blocks"}
    ok = a if a.error is None else b
    assert len(ok.generated) == 6
    assert eng.pool.blocks_used == 0


def test_paged_mla_latent_matches_dense(mla_model):
    """deepseek MLA: the paged latent pool (kv_lora + rope dims) must
    reproduce the dense streams for mixed-length traffic."""
    _, model, params = mla_model
    def reqs():
        return [_req(0, 5, 4), _req(1, 14, 4)]
    dense = _engine(model, params, max_len=32).run(reqs())
    paged = _engine(model, params, max_len=32, cache_kind="paged",
                    block_size=8).run(reqs())
    assert dense == paged


def test_paged_hybrid_ssm_attention_matches_dense(hybrid_model):
    """jamba: the paged pool carries the attention layers while mamba
    conv/SSD state stays per-slot — streams must still match dense."""
    _, model, params = hybrid_model
    def reqs():
        return [_req(0, 4, 4), _req(1, 13, 4)]
    dense = _engine(model, params, max_len=32).run(reqs())
    paged = _engine(model, params, max_len=32, cache_kind="paged",
                    block_size=8).run(reqs())
    assert dense == paged


def test_cache_stats_reports_paged_savings(small_model):
    """The acceptance metric: a working-set-sized pool allocates fewer
    cache bytes than slots x max_len while serving identical streams."""
    _, model, params = small_model
    dense_eng = _engine(model, params, slots=4)
    paged_eng = _engine(model, params, slots=4, cache_kind="paged",
                        block_size=16, num_blocks=4)  # 64 of 256 tokens
    d, p = dense_eng.cache_stats(), paged_eng.cache_stats()
    assert d["kind"] == "dense" and p["kind"] == "paged"
    assert p["bytes_total"] == d["bytes_total"] // 4
    assert p["tokens_capacity"] == 64 and d["tokens_capacity"] == 256
    # skewed traffic: one long, three short — fits in 4 blocks
    def reqs():
        return [_req(0, 30, 3), _req(1, 4, 3), _req(2, 5, 3)]
    assert dense_eng.run(reqs()) == paged_eng.run(reqs())
    assert p["bytes_total"] == pytree_bytes(paged_eng.cache)
    # mid-run occupancy was visible through the pool, all freed at drain
    assert paged_eng.pool.blocks_used == 0
    assert paged_eng.stats.tokens == 9


# ================================================================ sampling

def test_sampling_default_greedy_unchanged(small_model):
    """temperature=0 (default) must reproduce the greedy streams bit for
    bit — the sampler satellite may not disturb existing behavior."""
    _, model, params = small_model
    base = _engine(model, params).run(_mixed_reqs(4))
    with_seed = _engine(model, params, seed=123).run(_mixed_reqs(4))
    assert base == with_seed


def test_sampling_per_slot_keys_reproducible(small_model):
    _, model, params = small_model
    kw = dict(temperature=1.3, top_k=8, seed=11)
    r1 = _engine(model, params, **kw).run(_mixed_reqs(4))
    r2 = _engine(model, params, **kw).run(_mixed_reqs(4))
    assert r1 == r2                      # same per-slot key streams
    r3 = _engine(model, params, temperature=1.3, top_k=8, seed=12).run(
        _mixed_reqs(4))
    assert r1 != r3                      # seed actually reaches sampling
    # paged engine consumes the identical per-slot key sequence
    r4 = _engine(model, params, cache_kind="paged", block_size=16,
                 **kw).run(_mixed_reqs(4))
    assert r1 == r4
    for toks in r1.values():
        assert all(0 <= t < 256 for t in toks)


def test_sampling_top_k_larger_than_vocab_is_no_cutoff(small_model):
    """An oversized --top-k means "no cutoff", never a crash inside the
    jitted step (vocab here is 256)."""
    _, model, params = small_model
    r = _engine(model, params, temperature=1.0, top_k=10_000,
                seed=3).run([_req(0, 6, 3)])
    assert len(r[0]) == 3 and all(0 <= t < 256 for t in r[0])


def test_sampling_keys_independent_of_other_slot_activity(small_model):
    """A slot's key stream advances only on its OWN accepted steps: a
    request admitted into a slot that sat idle while another slot decoded
    samples exactly what it would have sampled admitted immediately."""
    _, model, params = small_model
    kw = dict(temperature=1.3, top_k=8, seed=11)

    late = _engine(model, params, **kw)
    assert len(late.admit([_req(0, 6, 8)])) == 1   # slot 0 decodes...
    for _ in range(3):
        late.step()                             # ...slot 1 sits idle
    a_late = _req(1, 9, 4)
    assert len(late.admit([a_late])) == 1       # lands on slot 1
    while late.active:
        late.step()

    early = _engine(model, params, **kw)
    a_early = _req(1, 9, 4)
    assert len(early.admit([_req(0, 6, 8), a_early])) == 2
    while early.active:
        early.step()

    assert a_late.generated == a_early.generated
