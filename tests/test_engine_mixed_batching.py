"""Mixed-length continuous batching: the regression suite for the seed
engine's scalar-``max(pos)`` KV-corruption bug.

Every test here compares a continuous-batched run against each request
served alone in a fresh single-request engine — greedy decode is
deterministic, so any cross-slot cache contamination shows up as a token
divergence.  Per-slot cursors are asserted directly mid-flight."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.models import ModelFault, build_model
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


def _engine(model, params, slots=2, policy=RecoveryPolicy()):
    return ServeEngine(model, params, slots=slots, max_len=64, abft=ABFT,
                       dtype=jnp.float32, policy=policy)


def _req(uid, length, n=5):
    return Request(uid=uid,
                   prompt=np.arange(1, 1 + length, dtype=np.int32),
                   max_new_tokens=n)


def _solo(model, params, uid, length, n=5):
    return _engine(model, params, slots=1).run([_req(uid, length, n)])[uid]


# ------------------------------------------------- the core regression

def test_mixed_length_two_requests_match_solo(small_model):
    """Two requests with different prompt lengths share the batch from
    step one; per-slot cursors must stay per-request (the seed engine
    collapsed them to max(pos) and corrupted both caches)."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    r0, r1 = _req(0, 5), _req(1, 11)
    assert len(eng.admit([r0, r1])) == 2
    # per-slot cursors reflect each request's own prompt length
    assert eng.pos[0] == 5 and eng.pos[1] == 11

    steps = 0
    while eng.active:
        eng.step()
        steps += 1
        # cursors advance in lockstep but stay per-slot (never max-merged)
        if eng.active:
            assert eng.pos[0] == 5 + steps and eng.pos[1] == 11 + steps

    assert r0.generated == _solo(model, params, 0, 5)
    assert r1.generated == _solo(model, params, 1, 11)


def test_staggered_admission_matches_solo(small_model):
    """Requests admitted mid-flight land on a fresh cursor while resident
    requests keep decoding at theirs."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    reqs = [_req(0, 4, n=3), _req(1, 9, n=6), _req(2, 7, n=5)]
    results = eng.run(list(reqs))
    for r in reqs:
        assert results[r.uid] == _solo(
            model, params, r.uid, len(r.prompt), r.max_new_tokens), (
            f"request {r.uid} diverged from its solo run")
    assert eng.stats.hard_faults == 0


def test_mixed_length_with_fault_recovery_no_contamination(small_model):
    """A decode-step fault is detected and recovered by recompute; both
    mixed-length streams still match their solo runs (no cross-slot
    contamination through the retry path)."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    reqs = [_req(0, 5, n=6), _req(1, 11, n=6)]
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run(list(reqs), fault_at=(2, fault))
    assert eng.stats.faults_detected >= 1
    assert eng.stats.retries >= 1
    assert eng.stats.hard_faults == 0
    assert results[0] == _solo(model, params, 0, 5, 6)
    assert results[1] == _solo(model, params, 1, 11, 6)


# ------------------------------------------------- budget semantics

def test_max_new_tokens_budget_exact(small_model):
    """max_new_tokens=N yields exactly N generated tokens, counting the
    prefill-sampled one; N=1 completes at admission without ever
    occupying a slot (the seed decoded one extra token)."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    one = _req(0, 6, n=1)
    assert len(eng.admit([one])) == 1
    assert one.done and len(one.generated) == 1
    assert not eng.active        # budget met at prefill: slot stays free

    for n in (2, 4):
        eng2 = _engine(model, params, slots=2)
        results = eng2.run([_req(0, 6, n=n)])
        assert len(results[0]) == n


def test_prompt_near_max_len_non_multiple_of_8(small_model):
    """The prefill pad bucket must clamp to max_len: a prompt of 27 in a
    30-deep cache buckets to Lpad=32 and would otherwise scatter out of
    bounds."""
    _, model, params = small_model
    eng = ServeEngine(model, params, slots=1, max_len=30, abft=ABFT,
                      dtype=jnp.float32)
    req = _req(0, 27, n=2)
    results = eng.run([req])
    assert req.error is None and len(results[0]) == 2


def test_zero_budget_request_generates_nothing(small_model):
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    zero = _req(0, 5, n=0)
    assert len(eng.admit([zero])) == 1
    assert zero.done and zero.generated == [] and not eng.active


def test_prompt_too_long_rejected_with_error(small_model):
    _, model, params = small_model
    eng = _engine(model, params, slots=2)
    big = _req(0, 60, n=10)       # 60 + 9 > max_len=64
    ok = _req(1, 5, n=3)
    results = eng.run([big, ok])
    assert big.error == "prompt_too_long"
    # the accounting split: pre-prefill screening counts as a REJECTION
    # (the request never held cache state), never as an eviction
    assert eng.stats.rejections == 1
    assert eng.stats.evictions == 0
    assert results[1] == _solo(model, params, 1, 5, 3)


# ------------------------------------------------- recovery policy

def test_admission_hard_fault_evicts_instead_of_livelock(small_model):
    """A persistently-faulting admission must not spin forever on the head
    request: with the retry budget exhausted the batch is evicted with a
    recorded error and the remaining traffic is served."""
    _, model, params = small_model
    eng = _engine(model, params, slots=1,
                  policy=RecoveryPolicy(max_retries=0))
    bad = _req(0, 5, n=3)
    good = _req(1, 7, n=3)
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run([bad, good], admit_fault_at=(0, fault))
    assert bad.error == "hard_fault:prefill"
    assert eng.stats.hard_faults == 1
    assert eng.stats.evictions >= 1      # resident loss IS an eviction...
    assert eng.stats.rejections == 0     # ...and never a rejection
    assert results[1] == _solo(model, params, 1, 7, 3)


def test_prefill_soft_fault_retries_from_fresh_cache(small_model):
    """One admission fault with a retry budget: the clean retry restarts
    from the pre-admission cache, so the admitted stream equals a clean
    run (a retry on the corrupted attempt's cache would diverge)."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2,
                  policy=RecoveryPolicy(max_retries=1))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run([_req(0, 5, n=4)], admit_fault_at=(0, fault))
    assert eng.stats.faults_detected == 1
    assert eng.stats.retries == 1
    assert eng.stats.hard_faults == 0
    assert results[0] == _solo(model, params, 0, 5, 4)


def test_decode_hard_fault_evicts_and_engine_survives(small_model):
    """Persistent decode fault: actives are evicted with errors instead of
    an engine-wide RuntimeError, and later requests are still served."""
    _, model, params = small_model
    eng = _engine(model, params, slots=1,
                  policy=RecoveryPolicy(max_retries=0))
    victim = _req(0, 5, n=6)
    later = _req(1, 8, n=3)
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run([victim, later], fault_at=(1, fault))
    assert victim.error == "hard_fault:decode"
    assert eng.stats.hard_faults == 1
    assert results[1] == _solo(model, params, 1, 8, 3)

    # legacy behavior stays reachable
    eng2 = _engine(model, params, slots=1,
                   policy=RecoveryPolicy(max_retries=0,
                                         evict_on_hard_fault=False))
    with pytest.raises(RuntimeError):
        eng2.run([_req(0, 5, n=6)], fault_at=(1, fault))
