"""ProtectionPolicy API tests: scheme registry, policy/legacy golden
equivalence, the AI==CMR boundary predicate, explicit first-layer flags,
ProtectionPlan JSON round-trip, chunk-budget autotuning, and engine
facade equivalence (ABFTConfig streams == ProtectionPolicy streams)."""

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import (
    ABFTConfig,
    FaultSpec,
    FixedPolicy,
    GemmDims,
    IntensityGuidedPolicy,
    LayerSpec,
    NVIDIA_T4,
    ProfileGuidedPolicy,
    ProtectionPlan,
    Scheme,
    SchemeSpec,
    SelectorConfig,
    StepShape,
    TPU_V5E,
    compute_bound_ai,
    default_registry,
    is_compute_bound,
    overhead_pct,
    protected_matmul,
    scheme_cost,
    select_scheme,
    selection_report,
)
from repro.core.checksums import CheckResult
from repro.core.hardware import HardwareSpec
from repro.core.policy import SchemeRegistry, policy_from_json
from repro.core.schemes import SchemeCost, cost_none
from repro.models import ModelFault, build_model
from repro.serve.engine import Request, ServeEngine

# hardware with a CMR the scaled test model's f32 step geometry can
# actually clear (see test_chunked_prefill.FLIP_HW): the autotuner has a
# real crossing to find instead of saturating at the cap
FLIP_HW = HardwareSpec(
    name="flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)


# ---------------------------------------------------------------- registry

def test_registry_duplicate_registration_rejected():
    reg = SchemeRegistry()
    reg.register(SchemeSpec("custom", cost_none))
    with pytest.raises(ValueError, match="already registered"):
        reg.register(SchemeSpec("custom", cost_none))
    reg.register(SchemeSpec("custom", cost_none), override=True)  # explicit


def test_registry_unknown_scheme_rejected():
    reg = SchemeRegistry()
    with pytest.raises(KeyError, match="unknown scheme 'nope'"):
        reg.get("nope")
    with pytest.raises(KeyError, match="unknown scheme"):
        FixedPolicy("nope").select(GemmDims(m=8, k=8, n=8), TPU_V5E)


def test_registry_builtins_and_auto_candidates():
    reg = default_registry()
    assert set(reg.names()) >= {"none", "global", "block_1s", "block_2s",
                                "replica"}
    # one-sided dominates (paper §6.5): only global + block_1s are auto
    assert reg.auto_candidates() == ("block_1s", "global")


def test_registered_scheme_is_a_registration_not_a_core_edit(rng):
    """An FT-CNN-style plug-in scheme: registering (cost, executor) makes
    it flow through scheme_cost AND protected_matmul with no edit to
    schemes.py / protected.py."""
    reg = default_registry()
    name = "test_plugin_echo"
    if name not in reg:
        def _cost(dims, blocks, first_layer):
            return SchemeCost(0.0, float(dims.m), 0.0, 1)

        def _exec(x, w, cfg, *, wsums, out_dtype, fault):
            y = jnp.matmul(x, w).astype(out_dtype)
            return y, CheckResult.clean()

        reg.register(SchemeSpec(name, _cost, executor=_exec))
    c = scheme_cost(name, GemmDims(m=32, k=16, n=8))
    assert (c.flops_vpu, c.fixed_ops) == (32.0, 1)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y, chk = protected_matmul(
        x, w, ABFTConfig.from_policy(FixedPolicy(name)),
        out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5)
    assert not bool(chk.flag)
    assert overhead_pct(name, GemmDims(m=32, k=16, n=8), TPU_V5E) > 0


def test_registry_mutation_invalidates_cached_selections():
    """Re-registering a scheme with a different cost model must not
    serve stale memoized selections (register/unregister clear the
    analytic-selection cache)."""
    reg = default_registry()
    name = "test_cheap_then_pricey"
    dims = GemmDims(m=16, k=64, n=64)
    zero = SchemeCost(0.0, 0.0, 0.0, 0)
    pricey = SchemeCost(1e18, 1e18, 1e18, 64)
    reg.register(SchemeSpec(name, lambda d, b, f: zero,
                            auto_eligible=True))
    try:
        pol = IntensityGuidedPolicy()
        assert pol.select(dims, TPU_V5E).scheme_name == name
        reg.register(SchemeSpec(name, lambda d, b, f: pricey,
                                auto_eligible=True), override=True)
        assert pol.select(dims, TPU_V5E).scheme_name != name
    finally:
        reg.unregister(name)
    assert IntensityGuidedPolicy().select(dims, TPU_V5E).scheme_name in (
        "block_1s", "global")


def test_availability_predicate_sees_the_active_config(rng):
    """A kernel-gated auto-eligible scheme is offered to selection only
    on backends whose ABFTConfig satisfies its predicate — resolve()
    threads the config through to auto_candidates()."""
    reg = default_registry()
    name = "test_pallas_gated"
    seen = []

    def _avail(cfg):
        seen.append(cfg)
        return cfg is not None and cfg.use_pallas

    def _must_not_run(*a, **k):
        raise AssertionError("gated executor must not run on this backend")

    reg.register(SchemeSpec(name, cost_none, executor=_must_not_run,
                            available=_avail, auto_eligible=True))
    try:
        cfg_no = ABFTConfig(use_pallas=False)
        assert name not in reg.auto_candidates(cfg_no)
        assert name in reg.auto_candidates(
            ABFTConfig(use_pallas=True))
        # backend unknown (plan building / legacy select_scheme): refused
        assert name not in reg.auto_candidates(None)
        x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
        seen.clear()
        protected_matmul(x, w, cfg_no, out_dtype=jnp.float32)
        assert seen and all(c is cfg_no for c in seen)
    finally:
        reg.unregister(name)
    with pytest.raises(KeyError):
        reg.get(name)


# --------------------------------------------------- golden equivalence

def _legacy_select(dims, hw, first_layer):
    """The pre-redesign _select_analytic, verbatim: candidate with the
    min modeled overhead, ties broken by scheme value."""
    candidates = (Scheme.GLOBAL, Scheme.BLOCK_1S)
    overheads = {
        s: overhead_pct(s, dims, hw, first_layer=first_layer)
        for s in candidates
    }
    best = min(candidates, key=lambda s: (overheads[s], s.value))
    return best, {s.value: overheads[s] for s in candidates}


def test_golden_equivalence_policy_vs_legacy_grid():
    """New-policy selections match the legacy selector across a
    (m, k, n, batch) x hardware x first_layer grid — schemes AND modeled
    overheads."""
    policy = IntensityGuidedPolicy()
    grid = itertools.product(
        (1, 8, 64, 512, 2048),          # m
        (64, 1024, 8192),               # k
        (64, 4096),                     # n
        (1, 4),                         # batch
        (TPU_V5E, NVIDIA_T4),
        (False, True),                  # first_layer
    )
    for m, k, n, b, hw, first in grid:
        dims = GemmDims(m=m, k=k, n=n, batch=b)
        want_scheme, want_over = _legacy_select(dims, hw, first)
        sel = policy.select(dims, hw, first_layer=first)
        assert sel.scheme == want_scheme, (dims, hw.name, first)
        assert sel.modeled_overhead_pct == pytest.approx(want_over)
        # and the legacy select_scheme entry point agrees too
        legacy = select_scheme(dims, hw, first_layer=first)
        assert legacy.scheme == want_scheme


def test_fixed_and_profile_policies_match_selector_modes():
    d = GemmDims(m=4096, k=4096, n=4096)
    assert FixedPolicy(Scheme.REPLICA).select(d).scheme == Scheme.REPLICA
    assert select_scheme(
        d, config=SelectorConfig(mode="fixed", fixed_scheme=Scheme.REPLICA)
    ).scheme == Scheme.REPLICA
    small = GemmDims(m=64, k=64, n=64)
    pol = ProfileGuidedPolicy(table={small: Scheme.GLOBAL})
    hit = pol.select(small)
    assert hit.scheme == Scheme.GLOBAL
    assert hit.reason == "empirical profile table"
    # unprofiled shape: analytic fallback, identical to the pure policy
    miss = pol.select(d, TPU_V5E)
    assert miss.scheme == IntensityGuidedPolicy().select(d, TPU_V5E).scheme


# ---------------------------------------------------- AI == CMR boundary

def test_boundary_ai_equals_cmr_is_bandwidth_everywhere():
    """Regression (the old selector printed '>=' while is_compute_bound
    used '>'): at AI exactly == CMR every surface agrees on
    bandwidth-bound."""
    dims = GemmDims(m=256, k=256, n=256)
    hw = dataclasses.replace(
        TPU_V5E, peak_flops=dims.arithmetic_intensity, hbm_bw=1.0)
    assert hw.cmr == dims.arithmetic_intensity          # exact boundary
    assert not is_compute_bound(dims, hw)
    assert not compute_bound_ai(dims.arithmetic_intensity, hw)
    sel = IntensityGuidedPolicy().select(dims, hw)
    assert "<=" in sel.reason and ">" not in sel.reason.split("->")[0]
    rows = selection_report({"boundary": dims}, hw)
    assert rows[0]["bound"] == "bandwidth"
    # one epsilon above the boundary flips every surface together
    hw_lo = dataclasses.replace(hw, peak_flops=hw.peak_flops * (1 - 1e-9))
    assert is_compute_bound(dims, hw_lo)
    assert selection_report({"boundary": dims}, hw_lo)[0]["bound"] == \
        "compute"


# ------------------------------------------------- explicit first flag

def test_layer_spec_first_flag_is_explicit_not_positional():
    """The plan honors LayerSpec.first wherever it sits — the old
    enumeration heuristic flagged whichever entry came first."""
    thin = GemmDims(m=16, k=4096, n=4096)
    specs = [
        LayerSpec("a", thin, first=False),
        LayerSpec("b", thin, first=True),
    ]
    plan = ProtectionPlan.build(specs, TPU_V5E, IntensityGuidedPolicy())
    over = {e.layer.name: e.selection.modeled_overhead_pct["global"]
            for e in plan.entries}
    # the first-flagged layer pays global ABFT's extra read of A
    assert over["b"] > over["a"]
    rows = plan.report_rows()
    assert [r["first"] for r in rows] == [False, True]
    # legacy mapping input: first entry flagged, matching old behavior
    rows = selection_report({"x": thin, "y": thin})
    assert [r["first"] for r in rows] == [True, False]


def test_model_layer_specs_flag_true_first_mixer():
    from repro.models.counting import layer_specs

    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    specs = layer_specs(cfg, 32)
    flags = {s.name: s.first for s in specs}
    assert flags["attn.q"] and not any(
        v for k, v in flags.items() if k != "attn.q")
    # hybrid whose stack opens with a mamba block flags ssm.in_z instead
    jcfg = scaled_down(get_config("jamba-v0.1-52b"))
    jflags = {s.name: s.first for s in layer_specs(jcfg, 32)}
    assert jflags["ssm.in_z"] and not jflags.get("attn.q", False)


# ------------------------------------------------------- plan round-trip

def test_plan_json_roundtrip_identical_selections():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    plan = ProtectionPlan.for_model(
        cfg, hw=FLIP_HW, policy=IntensityGuidedPolicy(), phase="serve",
        n_tokens=4, dtype_bytes=4)
    plan2 = ProtectionPlan.from_json(plan.to_json())
    assert plan2.hardware == plan.hardware
    assert plan2.policy == plan.policy
    assert [e.layer for e in plan2.entries] == [e.layer for e in plan.entries]
    for e, e2 in zip(plan.entries, plan2.entries):
        assert e2.selection.scheme_name == e.selection.scheme_name
    # identical per-step schemes after reload — the artifact contract
    for decode, prefill in itertools.product((0, 1, 4), (0, 8, 40, 200)):
        if decode + prefill == 0:
            continue
        assert (plan2.for_step(decode, prefill).scheme_name
                == plan.for_step(decode, prefill).scheme_name)
    assert plan2.tune_chunk_budget(lo=8, hi=512) == \
        plan.tune_chunk_budget(lo=8, hi=512)


def test_plan_roundtrip_fixed_and_profile_policies():
    step = StepShape(d_model=64, d_ff=128, dtype_bytes=4)
    small = GemmDims(m=8, k=64, n=128, dtype_bytes=4)
    for pol in (
        FixedPolicy(Scheme.GLOBAL),
        ProfileGuidedPolicy(table={small: Scheme.GLOBAL}),
    ):
        plan = ProtectionPlan.build(
            {"l0": small}, FLIP_HW, pol, step_shape=step)
        plan2 = ProtectionPlan.from_json(plan.to_json())
        assert plan2.policy == plan.policy
        assert plan2.for_step(8).scheme_name == plan.for_step(8).scheme_name


def test_policy_json_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown policy kind"):
        policy_from_json({"kind": "martian"})


# -------------------------------------------------- chunk-budget tuning

def _flip_plan():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    return ProtectionPlan.for_model(
        cfg, hw=FLIP_HW, policy=IntensityGuidedPolicy(), phase="serve",
        n_tokens=4, dtype_bytes=4)


def test_tune_chunk_budget_smallest_clearing_budget():
    """tput_margin=None: the bare roofline crossing — the smallest
    quantized budget whose mixed-step AI strictly clears the CMR."""
    plan = _flip_plan()
    b = plan.tune_chunk_budget(lo=8, hi=768, tput_margin=None)
    assert b % 8 == 0
    assert compute_bound_ai(plan.step_intensity(b), plan.hardware)
    assert not compute_bound_ai(plan.step_intensity(b - 8), plan.hardware)
    # floor tracks occupancy: below the crossing the smallest clearing
    # budget is unchanged; above it the budget rides decode + quantum
    for decode in (0, 4, 16):
        assert plan.tune_chunk_budget(decode_tokens=decode, lo=8, hi=768,
                                      tput_margin=None) == b
    assert plan.tune_chunk_budget(decode_tokens=200, lo=8, hi=768,
                                  tput_margin=None) == 208


def test_tune_chunk_budget_throughput_margin():
    """Default margin: the budget still clears the CMR but walks past
    the knee until modeled per-token time is within 10% of the cap's —
    so no fixed budget under the cap can beat it by more than 10%."""
    plan = _flip_plan()
    crossing = plan.tune_chunk_budget(lo=8, hi=768, tput_margin=None)
    b = plan.tune_chunk_budget(lo=8, hi=768)
    assert b >= crossing and b % 8 == 0
    assert compute_bound_ai(plan.step_intensity(b), plan.hardware)
    per_tok = plan.modeled_step_time(b) / b
    cap_tok = plan.modeled_step_time(768) / 768
    assert per_tok <= 1.1 * cap_tok
    # every fixed budget in [crossing, cap]: auto within 10% modeled tput
    for fixed in range(crossing, 769, 8):
        fixed_tok = plan.modeled_step_time(fixed) / fixed
        assert (1 / per_tok) / (1 / fixed_tok) >= 1 / 1.1 - 1e-9


def test_tune_chunk_budget_caps_when_cmr_unattainable():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    plan = ProtectionPlan.for_model(
        cfg, hw=TPU_V5E, policy=IntensityGuidedPolicy(), n_tokens=4,
        dtype_bytes=4)
    # v5e CMR (~241) is unreachable for the 64x128 step geometry: the
    # tuner returns the cap (max-intensity budget), never loops forever
    assert plan.tune_chunk_budget(lo=8, hi=256) == 256


# ---------------------------------------------------- engine integration

MIX = [(5, 4), (23, 5), (11, 3), (30, 4)]     # (prompt_len, budget)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


def _mk_requests():
    return [
        Request(uid=i, prompt=(1 + np.arange(L, dtype=np.int32) % 50),
                max_new_tokens=b)
        for i, (L, b) in enumerate(MIX)
    ]


def _run(model, params, abft, *, fault_at=None, **kw):
    eng = ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                      dtype=jnp.float32, **kw)
    res = eng.run(_mk_requests(), fault_at=fault_at)
    return eng, res


def test_facade_equivalence_streams(small_model):
    """Acceptance: engine streams under ABFTConfig(...) are byte-identical
    to the same run under the equivalent ProtectionPolicy — dense, paged,
    and chunked, faults included."""
    _, model, params = small_model
    legacy = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
    policy = ABFTConfig.from_policy(IntensityGuidedPolicy(),
                                    use_pallas=False)
    fault = (2, ModelFault.at(0, "mlp_down", FaultSpec.value(0, 1, 1e5)))
    for kw in (
        {},
        {"cache_kind": "paged", "block_size": 16},
        {"chunk_tokens": 16},
        {"cache_kind": "paged", "block_size": 16, "chunk_tokens": 16},
    ):
        e1, r1 = _run(model, params, legacy, fault_at=fault, **kw)
        e2, r2 = _run(model, params, policy, fault_at=fault, **kw)
        assert r1 == r2, kw
        assert e1.stats.faults_detected == e2.stats.faults_detected
        assert e1.stats.selection_trace == e2.stats.selection_trace


def test_engine_auto_chunk_budget(small_model):
    """chunk_tokens='auto': the tuned budget clears the CMR, streams stay
    byte-identical to the same budget passed explicitly (and to the
    unchunked engine), and the trace shows compute-bound mixed steps."""
    _, model, params = small_model
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                      hardware=FLIP_HW)
    e_auto, r_auto = _run(model, params, abft, chunk_tokens="auto")
    assert e_auto.chunk_auto
    budget = e_auto.chunk_tokens
    assert budget == e_auto.plan.tune_chunk_budget(lo=8, hi=64)
    assert compute_bound_ai(e_auto.plan.step_intensity(budget), FLIP_HW)
    e_fixed, r_fixed = _run(model, params, abft, chunk_tokens=budget)
    assert r_auto == r_fixed
    _, r_plain = _run(model, params, abft)
    assert r_auto == r_plain
    # full mixed steps carried `budget` tokens -> compute-bound -> global
    mixed = [t for t in e_auto.stats.selection_trace
             if t["decode"] and t["prefill"]]
    full = [t for t in mixed if t["decode"] + t["prefill"] == budget]
    assert all(t["scheme"] == "global" for t in full)


def test_engine_auto_budget_retunes_with_occupancy(small_model):
    """With a tiny CMR the smallest clearing budget IS the occupancy
    floor, so the budget tracks resident decode tokens — slots filling
    up re-tunes it upward, slots draining re-tunes it back (the ROADMAP
    're-tune as slot occupancy drifts' behavior)."""
    _, model, params = small_model
    tiny_cmr = dataclasses.replace(FLIP_HW, peak_flops=5e8)   # CMR = 0.5
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                      hardware=tiny_cmr)
    eng = ServeEngine(model, params, slots=4, max_len=64, abft=abft,
                      dtype=jnp.float32, chunk_tokens="auto")
    assert eng.chunk_tokens == 8                   # floor at 0 occupancy
    reqs = [Request(uid=i, prompt=(1 + np.arange(3, dtype=np.int32)),
                    max_new_tokens=6) for i in range(4)]
    eng.run(reqs)
    # once slots were occupied the floor rose past 8 -> budget re-tuned
    assert eng.stats.chunk_budget_retunes >= 1
    assert eng.chunk_tokens > 8


def test_engine_rejects_bogus_chunk_tokens(small_model):
    _, model, params = small_model
    with pytest.raises(ValueError, match="int or 'auto'"):
        ServeEngine(model, params, slots=2, max_len=64,
                    abft=ABFTConfig(use_pallas=False), dtype=jnp.float32,
                    chunk_tokens="fastest")
