"""Empirical profiler (paper §5.3 pre-deployment integration) tests."""

import jax.numpy as jnp

from repro.core import GemmDims, Scheme, SelectorConfig, select_scheme
from repro.core.profiler import build_profile_table, profile_layer


def test_profile_layer_returns_times():
    dims = GemmDims(m=16, k=64, n=32)
    times = profile_layer(dims, dtype=jnp.float32, use_pallas=False)
    assert set(times) == {Scheme.GLOBAL, Scheme.BLOCK_1S}
    assert all(t > 0 for t in times.values())


def test_profile_table_feeds_selector():
    dims = GemmDims(m=8, k=32, n=16)
    table = build_profile_table([dims], dtype=jnp.float32, use_pallas=False)
    assert dims in table
    sel = select_scheme(
        dims, config=SelectorConfig(mode="profile"), profile_table=table)
    assert sel.scheme == table[dims]
    assert sel.reason == "empirical profile table"
