"""Serve engine (continuous batching + ABFT recovery) and optimizer/data
substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import ModelFault, build_model
from repro.serve.engine import Request, ServeEngine
from repro.train import OptConfig, init_opt_state, lr_schedule, update
from repro.train.optimizer import (
    clip_by_global_norm,
    compress_with_feedback,
    global_norm,
)

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


# ---------------------------------------------------------------- serving

def test_engine_continuous_batching(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, slots=2, max_len=64, abft=ABFT,
                      dtype=jnp.float32)
    reqs = [
        Request(uid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                max_new_tokens=4)
        for i in range(4)  # 4 requests through 2 slots
    ]
    results = eng.run(reqs)
    assert set(results) == {0, 1, 2, 3}
    for uid, toks in results.items():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab_size for t in toks)
    assert eng.stats.tokens > 0
    assert eng.stats.hard_faults == 0


def test_engine_detects_and_recovers_from_fault(small_model):
    cfg, model, params = small_model
    eng = ServeEngine(model, params, slots=2, max_len=64, abft=ABFT,
                      dtype=jnp.float32)
    reqs = [Request(uid=0, prompt=np.arange(1, 8, dtype=np.int32),
                    max_new_tokens=6)]
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run(reqs, fault_at=(2, fault))
    assert eng.stats.faults_detected >= 1
    assert eng.stats.retries >= 1
    assert eng.stats.hard_faults == 0      # recovery succeeded
    assert len(results[0]) == 6

    # the recovered stream equals a clean run (deterministic greedy decode)
    eng2 = ServeEngine(model, params, slots=2, max_len=64, abft=ABFT,
                       dtype=jnp.float32)
    reqs2 = [Request(uid=0, prompt=np.arange(1, 8, dtype=np.int32),
                     max_new_tokens=6)]
    clean = eng2.run(reqs2)
    assert results[0] == clean[0]


# ---------------------------------------------------------------- optimizer

def test_adamw_reduces_quadratic_loss():
    w = jnp.asarray([3.0, -2.0])
    cfg = OptConfig(lr=0.1, weight_decay=0.0)
    state = init_opt_state({"w": w}, cfg)
    params = {"w": w}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_bf16_moments_roundtrip():
    cfg = OptConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
    p2, s2, _ = update(g, state, params, cfg)
    assert s2.mu["w"].dtype == jnp.bfloat16
    assert not bool(jnp.any(jnp.isnan(p2["w"])))


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_compression_error_feedback_unbiased():
    """Error feedback: accumulated compressed updates converge to the true
    sum (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 1e-3
    err = jnp.zeros((64,), jnp.bfloat16)
    total = jnp.zeros((64,))
    for _ in range(32):
        deq, err = compress_with_feedback(g_true, err)
        total = total + deq
    # mean compressed update ~ true gradient (residual bounded)
    np.testing.assert_allclose(
        np.asarray(total / 32), np.asarray(g_true), atol=2e-4)


def test_lr_schedule_shape():
    assert float(lr_schedule(jnp.asarray(0), 1e-3, warmup=10)) == 0.0
    assert float(lr_schedule(jnp.asarray(10), 1e-3, warmup=10)) == pytest.approx(1e-3, rel=0.01)
    late = float(lr_schedule(jnp.asarray(10000), 1e-3, warmup=10,
                             total=10000))
    assert late == pytest.approx(1e-4, rel=0.05)


# ---------------------------------------------------------------- data

def test_synthetic_data_deterministic_and_sharded():
    cfg = DataConfig(global_batch=8, seq_len=16, vocab_size=100)
    src = SyntheticLM(cfg)
    b1 = src.batch(3)
    b2 = src.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # restartable
    b3 = src.batch(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding: different hosts, different slices; same global shape
    h0 = src.batch(3, host_id=0, n_hosts=2)
    h1 = src.batch(3, host_id=1, n_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    assert (b1["tokens"] < 100).all() and (b1["tokens"] >= 0).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_prefetcher_overlaps():
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=50)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_step=5)
    s, b = pf.next()
    assert s == 5 and b["tokens"].shape == (2, 8)
    s, b = pf.next()
    assert s == 6
    pf.close()


def test_memmap_corpus(tmp_path):
    from repro.data.pipeline import MemmapCorpus

    toks = np.arange(1000, dtype=np.int32) % 97
    f = tmp_path / "corpus.bin"
    toks.tofile(f)
    cfg = DataConfig(global_batch=4, seq_len=8, vocab_size=97)
    corpus = MemmapCorpus(str(f), cfg)
    b = corpus.batch(0)
    assert b["tokens"].shape == (4, 8)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
