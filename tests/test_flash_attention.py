"""Fused-ABFT flash attention kernel: interpret-mode validation against a
naive softmax-attention oracle + fault detection through the online
softmax rescaling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faults import FaultSpec
from repro.kernels.flash_ops import flash_attention

F32 = jnp.float32


def _naive(q, k, v, causal=True):
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32))
    s = s * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Lq, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhv->bqhv", p, v.astype(F32))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (1, 64, 2, 16, 2),      # (B, L, H, D, KV) — GQA
    (2, 96, 4, 32, 4),      # MHA, ragged-ish length
])
def test_matches_naive_attention(rng, shape, causal):
    B, L, H, D, KV = shape
    q = jnp.asarray(rng.standard_normal((B, L, H, D)), F32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, D)), F32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, D)), F32)
    o, chk = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    ref = _naive(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert not bool(chk.flag), (
        float(chk.residual[0]), float(chk.threshold[0]),
        float(chk.residual[1]), float(chk.threshold[1]))


def test_bf16_no_false_positive(rng):
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    o, chk = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    assert not bool(chk.flag)
    ref = _naive(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(ref), rtol=5e-2, atol=5e-2)


def test_fault_in_output_accumulator_detected(rng):
    """A corruption of the PV accumulator must trip the rescaled checksum
    (the invariant survives the online softmax)."""
    q = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), F32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), F32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), F32)
    o, chk = flash_attention(
        q, k, v, causal=True, bq=32, bk=32,
        fault=FaultSpec.value(row=10, col=3, delta=50.0))
    assert bool(chk.flag)


def test_clean_fault_disabled(rng):
    q = jnp.asarray(rng.standard_normal((1, 32, 1, 16)), F32)
    k = jnp.asarray(rng.standard_normal((1, 32, 1, 16)), F32)
    v = jnp.asarray(rng.standard_normal((1, 32, 1, 16)), F32)
    o, chk = flash_attention(q, k, v, fault=FaultSpec.none(), bq=16, bk=16)
    assert not bool(chk.flag)


def test_padded_lengths(rng):
    """Lq not a block multiple: causal padding path."""
    q = jnp.asarray(rng.standard_normal((1, 40, 2, 16)), F32)
    k = jnp.asarray(rng.standard_normal((1, 40, 2, 16)), F32)
    v = jnp.asarray(rng.standard_normal((1, 40, 2, 16)), F32)
    o, chk = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    ref = _naive(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert o.shape == (1, 40, 2, 16)
    assert not bool(chk.flag)


# ---------------------------------------------------------------- decode

def test_flash_decode_matches_decode_attention_ragged(rng):
    """The decode entry accepts a per-row length vector: each batch row
    attends only its own valid cache prefix (the serving engine's
    vectorized cursor contract)."""
    from repro.kernels.flash_ops import flash_decode
    from repro.models.layers import decode_attention

    B, S, H, KV, D = 3, 40, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), F32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), F32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), F32)
    lengths = jnp.asarray([7, 40, 21], jnp.int32)
    out, chk = flash_decode(q, k, v, lengths, bk=16)
    ref = decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert not bool(chk.flag)

    # changing one row's length must change ONLY that row's output
    out2, _ = flash_decode(q, k, v, lengths.at[0].set(3), bk=16)
    assert not np.allclose(np.asarray(out2[0]), np.asarray(out[0]))
    np.testing.assert_array_equal(np.asarray(out2[1:]), np.asarray(out[1:]))


def test_flash_decode_scalar_length_broadcasts(rng):
    from repro.kernels.flash_ops import flash_decode
    from repro.models.layers import decode_attention

    q = jnp.asarray(rng.standard_normal((2, 1, 2, 16)), F32)
    k = jnp.asarray(rng.standard_normal((2, 24, 2, 16)), F32)
    v = jnp.asarray(rng.standard_normal((2, 24, 2, 16)), F32)
    out, chk = flash_decode(q, k, v, jnp.asarray(13, jnp.int32), bk=8)
    ref = decode_attention(q, k, v, jnp.asarray(13, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert not bool(chk.flag)
