"""Integration: the fused-ABFT flash-attention backend is a drop-in for
the XLA chunked path inside a full model, and a real sharded train step
executes end-to-end on an 8-device host mesh (subprocess)."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.models import LayerCtx, ModelFault, build_model


def test_flash_backend_matches_chunked():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % 50}
    base = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
    out_x = model.forward(params, batch, LayerCtx(abft=base))
    import dataclasses

    flash = dataclasses.replace(base, flash_attention=True)
    out_f = model.forward(params, batch, LayerCtx(abft=flash))
    np.testing.assert_allclose(
        np.asarray(out_x.logits), np.asarray(out_f.logits),
        rtol=2e-3, atol=2e-3)
    assert not bool(out_f.flag)


def test_flash_backend_detects_projection_fault():
    """Layer-GEMM faults still flag with the flash backend active."""
    import dataclasses

    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    abft = dataclasses.replace(
        ABFTConfig(scheme=Scheme.AUTO, use_pallas=False),
        flash_attention=True)
    ctx = LayerCtx(
        abft=abft,
        fault=ModelFault.at(1, "attn_out", FaultSpec.value(0, 2, 1e4)))
    out = model.forward(params, batch, ctx)
    assert bool(out.flag)


_DIST_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, Scheme
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.models.layers import ShardingHints
from repro.train import OptConfig, TrainConfig, init_opt_state, make_train_step

cfg = scaled_down(get_config("qwen2-moe-a2.7b"), n_layers=2, n_experts=4,
                  d_model=64, vocab_size=128)
model = build_model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
hints = ShardingHints(dp=("data",), dp_size=2, moe_mode="ep")
abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
opt = init_opt_state(params, tcfg.opt)
p_spec = shd.param_specs(cfg, params, mesh)
o_spec = shd.opt_state_specs(cfg, opt, mesh)
p_sh = shd.make_sharding(mesh, p_spec)
o_sh = shd.make_sharding(mesh, o_spec)
params = jax.device_put(params, p_sh)
opt = jax.device_put(opt, o_sh)
batch = {
    "tokens": jnp.ones((4, 16), jnp.int32),
    "labels": jnp.ones((4, 16), jnp.int32),
}
b_sh = shd.make_sharding(mesh, {k: P(("data",), None) for k in batch})
batch = jax.device_put(batch, b_sh)
step = make_train_step(model, abft, tcfg, hints=hints)
with mesh:
    jstep = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None))
    losses = []
    for _ in range(3):
        params, opt, metrics = jstep(params, opt, batch)
        losses.append(float(metrics["loss"]))
print(json.dumps({
    "losses": losses,
    "n_devices": len(jax.devices()),
    "flag": bool(metrics["abft_flag"]),
}))
"""


def test_sharded_train_step_executes_on_8_devices():
    """Not just compile: a DP+TP+EP-sharded MoE train step RUNS on an
    8-device host mesh; loss decreases and no ABFT flags trip."""
    res = subprocess.run(
        [sys.executable, "-c", _DIST_TRAIN], capture_output=True, text=True,
        timeout=900, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert not out["flag"]
    assert all(np.isfinite(x) for x in out["losses"])
    assert out["losses"][-1] < out["losses"][0]
