"""Sharding-rule edge cases: ``sanitize_spec`` divisibility handling,
``param_specs`` over exotic param paths (mamba state-space ins/outs, MoE
expert stacks, stacked scan segments), ``cache_specs`` paged-pool vs
per-slot leaf classification (including the cross-attention KV leaves
that share the ``k``/``v`` names with the block pool), and the
canonical ``build_mesh``/``make_hints`` construction shared by the
serve executor and the train dry-run.

Everything here is host-side: specs are pure functions of (config,
shapes, mesh geometry), so a stub mesh object carrying ``shape`` and
``axis_names`` stands in for real multi-device meshes — the tests run
on a single CPU device in tier-1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, scaled_down
from repro.distributed.mesh import build_mesh, make_hints
from repro.distributed.sharding import (
    cache_specs,
    param_specs,
    sanitize_spec,
)
from repro.models import build_model
from repro.runtime.elastic import ElasticState, plan_remesh


class StubMesh:
    """Geometry-only mesh stand-in: sharding rules consult only
    ``shape`` and ``axis_names``."""

    def __init__(self, **shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


MESH4 = StubMesh(data=1, model=4)
MESH3 = StubMesh(data=1, model=3)


def _leaf(tree, *path):
    for p in path:
        tree = tree[p]
    return tree


# --------------------------------------------------------- sanitize_spec
class TestSanitizeSpec:
    def test_divisible_kept(self):
        assert sanitize_spec(P(None, "model"), (8, 16), MESH4) == \
            P(None, "model")

    def test_non_divisible_dropped(self):
        assert sanitize_spec(P(None, "model"), (8, 10), MESH4) == \
            P(None, None)

    def test_axis_larger_than_dim_dropped(self):
        # a dim SMALLER than the axis can never divide it (2 % 4 != 0)
        assert sanitize_spec(P("model", None), (2, 64), MESH4) == \
            P(None, None)

    def test_spec_longer_than_shape_trimmed(self):
        # ndim mismatch: a rank-3 rule applied to a rank-2 leaf (biases
        # falling under matmul rules) must trim, not crash
        assert sanitize_spec(P("model", None, None), (4, 8), MESH4) == \
            P("model", None)

    def test_spec_shorter_than_shape_ok(self):
        s = sanitize_spec(P("model"), (4, 8, 16), MESH4)
        assert s == P("model")      # trailing dims implicitly replicated

    def test_tuple_axes_product(self):
        mesh = StubMesh(data=2, model=4)
        # ("data","model") needs 8 | dim
        assert sanitize_spec(P(("data", "model"),), (16,), mesh) == \
            P(("data", "model"))
        assert sanitize_spec(P(("data", "model"),), (12,), mesh) == P(None)


# ----------------------------------------------------------- param_specs
@pytest.fixture(scope="module")
def llama_shapes():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    shapes = jax.eval_shape(
        lambda k: model.init_params(k, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    return cfg, shapes


class TestParamSpecs:
    def test_llama_attention_and_mlp(self, llama_shapes):
        cfg, shapes = llama_shapes
        specs = param_specs(cfg, shapes, MESH4)

        def find(name):
            out = []
            jax.tree_util.tree_map_with_path(
                lambda p, s: out.append((p, s))
                if str(p[-1].key) == name else None, specs)
            return out

        # stacked scan segments get a leading None; column-parallel on
        # the head/ffn dim, row-parallel back
        for _, s in find("wq"):
            assert s[-1] == "model" and s[0] is None
        for _, s in find("wo"):
            assert "model" in tuple(s)
        for _, s in find("up"):
            assert s[-1] == "model"
        for _, s in find("down"):
            assert "model" in tuple(s)[:-1] or "model" in tuple(s)
        for _, s in find("lm_head"):
            assert s == P(None, "model")

    def test_non_divisible_width_replicates(self, llama_shapes):
        cfg, shapes = llama_shapes
        # d_model=64, heads*hd=64: model=3 divides nothing — every spec
        # must fall back to replication instead of an invalid sharding
        specs = param_specs(cfg, shapes, MESH3)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert all(all(e is None for e in s) for s in leaves)

    def test_mamba_param_paths(self):
        cfg = scaled_down(get_config("mamba2-1.3b"), n_layers=2)
        model = build_model(cfg)
        shapes = jax.eval_shape(
            lambda k: model.init_params(k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        specs = param_specs(cfg, shapes, MESH4)
        found = {}
        jax.tree_util.tree_map_with_path(
            lambda p, s: found.setdefault(str(p[-1].key), s), specs)
        # ssm ins shard the inner dim over 'model' (when divisible),
        # out_proj shards its input dim; in_bc stays replicated
        for name in ("in_z", "in_x", "in_dt"):
            if name in found:
                assert tuple(found[name])[-1] in ("model", None)
        if "in_bc" in found:
            assert "model" not in tuple(found["in_bc"])
        if "out_proj" in found:
            sp = tuple(found["out_proj"])
            assert sp[-1] != "model"     # row-parallel: never the out dim

    def test_moe_expert_paths(self):
        cfg = scaled_down(get_config("qwen2-moe-a2.7b"), n_layers=2)
        model = build_model(cfg)
        shapes = jax.eval_shape(
            lambda k: model.init_params(k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        assert cfg.n_experts == 8
        specs4 = param_specs(cfg, shapes, MESH4)   # 8 % 4 == 0: EP
        specs3 = param_specs(cfg, shapes, MESH3)   # 8 % 3 != 0: TP
        found4, found3 = {}, {}
        jax.tree_util.tree_map_with_path(
            lambda p, s: found4.setdefault(str(p[-1].key), s), specs4)
        jax.tree_util.tree_map_with_path(
            lambda p, s: found3.setdefault(str(p[-1].key), s), specs3)
        assert "w_up" in found4
        # EP: the EXPERT dim carries 'model'; router always replicated
        assert tuple(found4["w_up"])[-3] == ("model",) or \
            tuple(found4["w_up"])[-3] == "model"
        assert "model" not in tuple(found4["router"])
        # TP fallback: the expert dim is NOT sharded (8 % 3 != 0); any
        # surviving entry targets the intra-expert ffn dim only
        sp3 = tuple(found3["w_up"])
        assert sp3[-3] in (None, "model") and sp3[-3] != ("model",)


# ----------------------------------------------------------- cache_specs
class TestCacheSpecs:
    @pytest.fixture(scope="class")
    def llama(self):
        cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
        return cfg, build_model(cfg)

    def _kv_leaves(self, cfg, cache, specs, *, subtree):
        out = []

        def walk(path, spec):
            ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
            parts = ps.split("/")
            if parts[-1] in ("k", "v") and subtree in parts:
                out.append((ps, spec))
        jax.tree_util.tree_map_with_path(walk, specs)
        return out

    def test_dense_kv_batch_sharded(self, llama):
        cfg, model = llama
        cache = jax.eval_shape(
            lambda: model.init_cache(4, 32, dtype=jnp.bfloat16))
        specs = cache_specs(cfg, cache, StubMesh(data=1, model=2), 4)
        kv = self._kv_leaves(cfg, cache, specs, subtree="attn")
        assert kv
        for ps, s in kv:
            # n_kv_heads=2 divides model=2: kv-head dim sharded, batch
            # dim carries the (trivial) data axes
            assert tuple(s)[-2] == "model"

    def test_paged_pool_geometry_replicated(self, llama):
        cfg, model = llama
        cache = jax.eval_shape(
            lambda: model.init_paged_cache(4, 16, 8, dtype=jnp.bfloat16))
        specs = cache_specs(cfg, cache, StubMesh(data=1, model=2), 4,
                            paged=True)
        kv = self._kv_leaves(cfg, cache, specs, subtree="attn")
        assert kv
        for ps, s in kv:
            t = tuple(s)
            # (num_blocks, block_size, KV, hd): pool dims replicated,
            # kv-head dim over 'model' — per-device KV shards behind one
            # logical block table
            assert t[0] is None and t[1] is None
            assert t[-2] == "model"

    def test_paged_kv_fallback_headdim(self, llama):
        cfg, model = llama
        cache = jax.eval_shape(
            lambda: model.init_paged_cache(4, 16, 8, dtype=jnp.bfloat16))
        # kv_heads=2 does not divide model=4: fall back to head_dim
        specs = cache_specs(cfg, cache, MESH4, 4, paged=True)
        kv = self._kv_leaves(cfg, cache, specs, subtree="attn")
        for ps, s in kv:
            t = tuple(s)
            assert t[-2] is None and t[-1] == "model"
        # 'replicate' fallback leaves the pool fully local per device
        specs = cache_specs(cfg, cache, MESH4, 4, paged=True,
                            kv_fallback="replicate")
        for ps, s in self._kv_leaves(cfg, cache, specs, subtree="attn"):
            assert "model" not in tuple(s)

    def test_cross_attention_kv_stays_per_slot_when_paged(self):
        # VLM cross-attention KV leaves are ALSO named k/v but live per
        # slot (leading dim is the slot, not a pool) — the paged rules
        # must not misclassify them as block-pool leaves
        cfg = scaled_down(get_config("llama-3.2-vision-11b"), n_layers=2)
        model = build_model(cfg)
        cache = jax.eval_shape(
            lambda: model.init_paged_cache(4, 16, 8, dtype=jnp.bfloat16))
        mesh = StubMesh(data=1, model=2)
        specs = cache_specs(cfg, cache, mesh, 4, paged=True)
        cross = self._kv_leaves(cfg, cache, specs, subtree="cross")
        assert cross
        dense_specs = cache_specs(cfg, cache, mesh, 4, paged=False)
        dense_cross = dict(self._kv_leaves(cfg, cache, dense_specs,
                                           subtree="cross"))
        for ps, s in cross:
            assert s == dense_cross[ps]   # paged flag changes nothing

    def test_mamba_state_per_slot(self):
        cfg = scaled_down(get_config("mamba2-1.3b"), n_layers=2)
        model = build_model(cfg)
        cache = jax.eval_shape(
            lambda: model.init_paged_cache(4, 16, 8, dtype=jnp.bfloat16))
        specs = cache_specs(cfg, cache, StubMesh(data=1, model=2), 4,
                            paged=True)
        names = {}
        jax.tree_util.tree_map_with_path(
            lambda p, s: names.setdefault(str(p[-1].key), tuple(s)), specs)
        for name in ("conv_x", "conv_bc", "ssm"):
            assert name in names        # per-slot state leaves survive


# ------------------------------------------------- build_mesh / make_hints
class TestBuildMesh:
    def test_single_device_mesh(self):
        mesh = build_mesh(model=1)
        assert mesh.shape["model"] == 1
        assert set(mesh.axis_names) == {"data", "model"}

    def test_model_lt_one_rejected(self):
        with pytest.raises(ValueError, match="model_parallel"):
            build_mesh(model=0)

    def test_too_few_devices_raises_not_clamps(self):
        n = len(jax.devices())
        with pytest.raises(RuntimeError, match="not enough devices"):
            build_mesh(model=n + 1)

    def test_overfull_shape_raises(self):
        n = len(jax.devices())
        with pytest.raises(RuntimeError, match="needs"):
            build_mesh(model=1, data=n + 1)

    def test_launch_wrapper_raises_on_insufficient_devices(self):
        from repro.launch.mesh import make_mesh_from_devices
        devs = list(jax.devices())
        with pytest.raises(RuntimeError):
            make_mesh_from_devices(devs, model_parallel=len(devs) + 1)

    def test_make_hints_moe_mode(self):
        cfg = scaled_down(get_config("qwen2-moe-a2.7b"), n_layers=2)
        assert make_hints(cfg, MESH4).moe_mode == "ep"     # 8 % 4 == 0
        assert make_hints(cfg, MESH3).moe_mode == "tp"     # 8 % 3 != 0
        dense = scaled_down(get_config("llama3.2-1b"), n_layers=2)
        h = make_hints(dense, StubMesh(data=2, model=2))
        assert h.dp == ("data",) and h.dp_size == 2


# ------------------------------------------------------- elastic validity
class TestElasticValidation:
    def test_plan_remesh_rejects_degenerate_width(self):
        with pytest.raises(ValueError, match="model_parallel"):
            plan_remesh(4, 0)

    def test_plan_remesh_rejects_unreachable(self):
        with pytest.raises(RuntimeError, match="not enough devices"):
            plan_remesh(3, 4)

    def test_on_failure_unreachable_mesh_is_an_error(self):
        st = ElasticState(model_parallel=4,
                          spares=["s0"],
                          active=["w0", "w1", "w2", "w3"])
        with pytest.raises(RuntimeError, match="cannot re-mesh"):
            st.on_failure(["w0", "w1"])   # 2 survivors + 1 spare < 4

    def test_on_failure_with_spares_recovers(self):
        st = ElasticState(model_parallel=2,
                          spares=["s0", "s1"],
                          active=["w0", "w1", "w2", "w3"])
        plan = st.on_failure(["w3"])
        assert plan.model == 2
        assert len(st.active) % 2 == 0


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-x", "-q"]))
