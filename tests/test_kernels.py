"""Per-kernel validation: Pallas fused-ABFT matmul vs the pure-jnp oracle.

Sweeps shapes/dtypes/modes in interpret mode (CPU) per the brief; every
case asserts (i) the GEMM output matches the oracle, (ii) residuals match
the oracle's chunk-ordered computation, (iii) clean runs never flag.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.faults import FaultSpec
from repro.core.schemes import BlockShape
from repro.kernels import abft_matmul
from repro.kernels.ref import abft_matmul_ref, matmul_ref

jax.config.update("jax_enable_x64", False)

SHAPES = [
    # (m, k, n) — mixed thin/fat/ragged
    (8, 8, 8),
    (16, 128, 64),
    (96, 200, 130),     # non-multiples force padding
    (1, 512, 512),      # decode-like thin GEMM
    (256, 64, 8),
    (130, 514, 258),    # every dim ragged
]
DTYPES = [jnp.float32, jnp.bfloat16]
MODES = ["1s", "2s", "replica"]


def _tol(dtype):
    # accumulation order differs between the k-chunked kernel and the
    # oracle's single einsum — allow a few ulps of headroom
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_kernel_matches_oracle(rng, shape, dtype, mode):
    m, k, n = shape
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    y, chk = abft_matmul(x, w, mode=mode, out_dtype=jnp.float32)
    y_ref = matmul_ref(x, w, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), **_tol(dtype))
    assert not bool(chk.flag), (
        f"false positive: max res/tau="
        f"{float(jnp.max(chk.residual / chk.threshold))}")


@pytest.mark.parametrize("mode", ["1s", "2s"])
def test_kernel_residual_matches_ref_blocked(rng, mode):
    """Residual/bound outputs equal the oracle's block-structured values."""
    m, k, n = 128, 256, 128
    bm, bk, bn = 64, 64, 64
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    y, chk = abft_matmul(
        x, w, mode=mode, blocks=BlockShape(bm=bm, bk=bk, bn=bn),
        out_dtype=jnp.float32)
    y_ref, res_ref, bnd_ref = abft_matmul_ref(
        x, w, mode=mode, bm=bm, bk=bk, bn=bn, out_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=5e-4, atol=5e-4)
    # bounds are sums of |a||b| — deterministic up to fp association
    np.testing.assert_allclose(
        np.asarray(chk.residual), np.asarray(res_ref), atol=1e-2)


@pytest.mark.parametrize("mode", MODES)
def test_fault_detected_and_located(rng, mode):
    m, k, n = 128, 256, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    row, col = 37, 101
    y, chk = abft_matmul(
        x, w, mode=mode, out_dtype=jnp.float32,
        fault=FaultSpec.value(row, col, 100.0))
    assert bool(chk.flag)
    # one-sided/replica residuals locate the faulty row within the block
    if mode != "2s":
        res = np.asarray(chk.residual)          # (gm, gn, bm)
        gm, gn, bm = res.shape
        hot = np.unravel_index(np.argmax(res), res.shape)
        assert hot[0] * bm + hot[2] == row


@pytest.mark.parametrize("bit", [31, 30, 28, 24])  # sign + exponent bits
def test_bitflip_detected(rng, bit):
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    y, chk = abft_matmul(
        x, w, mode="1s", out_dtype=jnp.float32,
        fault=FaultSpec.bitflip(10, 10, bit))
    # exponent-region flips change magnitude by >= 2x — always above tau
    assert bool(chk.flag)


def test_nan_corruption_flags(rng):
    """NaN in the accumulator must flag (NaN-safe compare)."""
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    y, chk = abft_matmul(
        x, w, mode="1s", out_dtype=jnp.float32,
        fault=FaultSpec.value(0, 0, float("nan")))
    assert bool(chk.flag)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    mode=st.sampled_from(MODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_no_false_positives(m, k, n, scale, mode, seed):
    """Invariant: a clean GEMM never flags, across shapes and scales."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)) * scale, jnp.float32)
    w = jnp.asarray(r.standard_normal((k, n)) * scale, jnp.float32)
    y, chk = abft_matmul(x, w, mode=mode, out_dtype=jnp.float32)
    assert not bool(chk.flag)
    y_ref = matmul_ref(x, w, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4 * scale * scale)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 64),
    k=st.integers(8, 128),
    n=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_large_fault_always_detected(m, k, n, seed):
    """Invariant: single faults well above the rounding bound are detected,
    at any output coordinate."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    row, col = int(r.integers(m)), int(r.integers(n))
    # magnitude >> tau ~ 16*eps*sqrt(k)*O(k*n): use 50x typical element
    delta = 50.0 * float(np.sqrt(k))
    y, chk = abft_matmul(
        x, w, mode="1s", out_dtype=jnp.float32,
        fault=FaultSpec.value(row, col, delta))
    assert bool(chk.flag)


def test_vmap_expert_batching(rng):
    """vmap over the kernel = per-expert protected GEMMs (MoE path)."""
    xe = jnp.asarray(rng.standard_normal((4, 16, 128)), jnp.float32)
    we = jnp.asarray(rng.standard_normal((4, 128, 64)), jnp.float32)
    yv, chkv = jax.vmap(
        lambda a, b: abft_matmul(a, b, mode="1s", out_dtype=jnp.float32)
    )(xe, we)
    y_ref = jnp.einsum("emk,ekn->emn", xe, we)
    np.testing.assert_allclose(np.asarray(yv), np.asarray(y_ref), rtol=1e-4)
    assert not bool(jnp.any(chkv.flag))


def test_block_clamping_thin_gemm(rng):
    """Thin GEMMs shrink blocks instead of padding to 256."""
    x = jnp.asarray(rng.standard_normal((2, 1024)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((1024, 8)), jnp.float32)
    y, chk = abft_matmul(x, w, mode="1s", out_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(matmul_ref(x, w, jnp.float32)),
        rtol=5e-4, atol=5e-4)
    assert not bool(chk.flag)
