"""Fault-campaign subsystem: dtype-aware fault targets, the seeded
``FaultModel`` process (deterministic replay, sticky permanents), the
``ErrorAdaptivePolicy`` hysteresis, and the serving engine's continuous
injection + shadow-stream classification end to end (ROADMAP 5b/5c).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import (
    ABFTConfig,
    ErrorAdaptivePolicy,
    FaultModel,
    FaultSpec,
    FixedPolicy,
    IntensityGuidedPolicy,
    Scheme,
    exponent_bit_range,
    random_fault,
)
from repro.core.policy import policy_from_json
from repro.models import ModelFault, build_model
from repro.obs import EngineTelemetry
from repro.serve.engine import Request, ServeEngine

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
GLOBAL = ABFTConfig.from_policy(FixedPolicy(Scheme.GLOBAL),
                                use_pallas=False)
# every campaign fault in this file uses a value delta far above the
# checksum tolerance, so detection verdicts are deterministic
MAG = 1e4


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


def _reqs(n=3, new_tokens=5):
    return [Request(uid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                    max_new_tokens=new_tokens) for i in range(n)]


def _engine(model, params, *, abft=ABFT, **kw) -> ServeEngine:
    return ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                       dtype=jnp.float32, **kw)


# ================================================ dtype-aware random_fault

class TestDtypeAwareRandomFault:
    def test_exponent_bit_ranges(self):
        assert exponent_bit_range(jnp.bfloat16) == (8, 15)
        assert exponent_bit_range(np.float32) == (23, 31)
        assert exponent_bit_range(np.float16) == (10, 15)

    def test_unknown_dtype_raises(self):
        with pytest.raises(ValueError):
            exponent_bit_range(np.int32)

    @pytest.mark.parametrize("dtype,lo,hi", [
        (jnp.bfloat16, 8, 15), (np.float32, 23, 31),
    ])
    def test_random_bit_flips_land_in_exponent(self, dtype, lo, hi):
        rng = np.random.default_rng(0)
        for _ in range(50):
            f = random_fault(rng, 4, 32, dtype=dtype)
            assert lo <= int(f.bit) < hi
            assert 0 <= int(f.row) < 4 and 0 <= int(f.col) < 32

    def test_magnitude_mode_is_a_value_fault(self):
        f = random_fault(np.random.default_rng(0), 2, 8, magnitude=MAG,
                         dtype=np.float32)
        assert int(f.bit) == -1 and float(f.delta) == MAG


# ======================================================== FaultModel

class TestFaultModel:
    def test_same_seed_replays_identical_schedule(self):
        kw = dict(transient_rate=0.4, permanent_rate=0.1,
                  permanent_duration=3, seed=7, layers=2, magnitude=MAG)
        a, b = FaultModel(**kw), FaultModel(**kw)
        for _ in range(40):
            a.poll()
            b.poll()
        assert a.schedule and a.schedule == b.schedule

    def test_reset_rewinds_to_seed(self):
        fm = FaultModel(transient_rate=0.5, seed=3, magnitude=MAG)
        first = [fm.poll() for _ in range(20)]
        sched = list(fm.schedule)
        fm.reset()
        second = [fm.poll() for _ in range(20)]
        assert fm.schedule == sched
        assert [f.describe() if f else None for f in first] == \
               [f.describe() if f else None for f in second]

    def test_sticky_permanent_lifecycle(self):
        fm = FaultModel(permanent_rate=1.0, permanent_duration=3, seed=0,
                        magnitude=MAG)
        first = fm.poll()
        assert first is not None and first.kind == "permanent"
        # the SAME fault persists for duration steps …
        second = fm.poll()
        assert second is first
        fm.poll()
        # … then expires; rate 1.0 immediately onsets a fresh one
        fresh = fm.poll()
        assert fresh is not None and fresh.onset_step == fm.step - 1
        assert fresh is not first

    def test_clear_sticky_is_the_repair_event(self):
        fm = FaultModel(permanent_rate=1.0, permanent_duration=1000,
                        seed=0, magnitude=MAG)
        assert fm.poll() is not None
        fm.clear_sticky()
        assert fm.sticky is None

    def test_rate_zero_never_fires(self):
        fm = FaultModel(transient_rate=0.0, permanent_rate=0.0, seed=0)
        assert all(fm.poll() is None for _ in range(50))
        assert fm.schedule == []

    def test_rate_validation(self):
        with pytest.raises(ValueError):
            FaultModel(transient_rate=1.5)
        with pytest.raises(ValueError):
            FaultModel(permanent_rate=-0.1)
        with pytest.raises(ValueError):
            FaultModel(permanent_duration=0)


# ================================================ ErrorAdaptivePolicy

def _snap(det=0.0, hard=0.0):
    return {"window_detection_rate": det, "window_hard_fault_rate": hard}


class TestErrorAdaptivePolicy:
    def test_escalates_on_detection_threshold(self):
        p = ErrorAdaptivePolicy(detection_threshold=0.1)
        assert not p.update(_snap(det=0.05))
        assert p.level == 0
        assert p.update(_snap(det=0.1))
        assert p.level == 1 and p.escalations == 1
        assert p.active is p.escalated

    def test_escalates_on_hard_fault_threshold(self):
        p = ErrorAdaptivePolicy(hard_fault_threshold=0.01)
        assert p.update(_snap(hard=0.02))
        assert p.level == 1

    def test_dead_band_does_not_flap(self):
        """Rates between clear_factor x threshold and threshold must
        hold the current level — in BOTH directions."""
        p = ErrorAdaptivePolicy(detection_threshold=0.1,
                                clear_factor=0.5, deescalate_after=2)
        dead_band = _snap(det=0.07)      # 0.05 < 0.07 < 0.1
        assert not p.update(dead_band)   # level 0 stays 0
        assert p.level == 0
        p.update(_snap(det=0.5))         # escalate
        assert p.level == 1
        for _ in range(10):
            assert not p.update(dead_band)   # level 1 stays 1
        assert p.level == 1
        assert p.escalations == 1 and p.deescalations == 0

    def test_deescalation_needs_consecutive_quiet_updates(self):
        p = ErrorAdaptivePolicy(detection_threshold=0.1,
                                clear_factor=0.5, deescalate_after=3)
        p.update(_snap(det=0.5))
        assert p.level == 1
        quiet = _snap(det=0.0)
        assert not p.update(quiet)
        assert not p.update(quiet)
        # a hot blip resets the quiet streak
        assert not p.update(_snap(det=0.5))
        assert not p.update(quiet)
        assert not p.update(quiet)
        assert p.update(quiet)           # third CONSECUTIVE quiet
        assert p.level == 0 and p.deescalations == 1

    def test_select_delegates_to_active_level(self):
        from repro.core.intensity import GemmDims

        p = ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                                escalated=FixedPolicy(Scheme.GLOBAL))
        dims = GemmDims(m=4, k=64, n=64)
        assert p.select(dims).scheme == \
            IntensityGuidedPolicy().select(dims).scheme
        p.update(_snap(det=1.0))
        assert p.select(dims).scheme == Scheme.GLOBAL

    def test_json_round_trip(self):
        p = ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                                detection_threshold=0.2,
                                shrink_chunk=0.5)
        q = policy_from_json(p.to_json())
        assert isinstance(q, ErrorAdaptivePolicy)
        assert q.detection_threshold == 0.2
        assert q.shrink_chunk == 0.5
        assert q.level == 0              # reconstructed at base level

    def test_validation(self):
        with pytest.raises(ValueError):
            ErrorAdaptivePolicy(clear_factor=0.0)
        with pytest.raises(ValueError):
            ErrorAdaptivePolicy(deescalate_after=0)
        with pytest.raises(ValueError):
            ErrorAdaptivePolicy(shrink_chunk=1.5)


# ==================================== engine campaign + classification

class TestEngineCampaign:
    def test_protected_campaign_zero_sdc_and_streams_clean(
            self, small_model):
        cfg, model, params = small_model
        clean = _engine(model, params).run(_reqs())
        fm = FaultModel(transient_rate=0.5, seed=0, layers=cfg.n_layers,
                        dtype=jnp.float32, magnitude=MAG)
        tel = EngineTelemetry()
        eng = _engine(model, params, fault_model=fm, telemetry=tel)
        out = eng.run(_reqs())
        s = eng.stats
        assert s.faults_injected > 0
        assert s.sdc_faults == 0
        assert s.faults_corrected + s.faults_uncorrected \
            + s.masked_faults == s.faults_injected
        assert out == clean              # recovery is transparent
        assert tel.counters_match(s)     # SDC counters are mirrored
        entry = s.injection_log[0]
        for k in ("source", "kind", "engine_step", "phase", "outcome"):
            assert k in entry
        assert entry["source"] == "campaign"

    def test_campaign_replays_bit_identically(self, small_model):
        cfg, model, params = small_model
        kw = dict(transient_rate=0.5, seed=0, layers=cfg.n_layers,
                  dtype=jnp.float32, magnitude=MAG)
        fm1, fm2 = FaultModel(**kw), FaultModel(**kw)
        e1 = _engine(model, params, fault_model=fm1)
        e2 = _engine(model, params, fault_model=fm2)
        o1, o2 = e1.run(_reqs()), e2.run(_reqs())
        assert fm1.schedule == fm2.schedule
        assert e1.stats.injection_log == e2.stats.injection_log
        assert o1 == o2

    def test_unprotected_campaign_shows_sdc(self, small_model):
        cfg, model, params = small_model
        fm = FaultModel(transient_rate=0.5, seed=0, layers=cfg.n_layers,
                        dtype=jnp.float32, magnitude=MAG)
        eng = _engine(model, params, abft=ABFTConfig.off(),
                      fault_model=fm)
        eng.run(_reqs())
        assert eng.stats.faults_injected > 0
        assert eng.stats.sdc_faults > 0
        assert eng.stats.faults_detected == 0

    def test_disabled_fault_model_streams_byte_identical(
            self, small_model):
        cfg, model, params = small_model
        clean = _engine(model, params).run(_reqs())
        eng = _engine(model, params,
                      fault_model=FaultModel(transient_rate=0.0, seed=0))
        assert eng.run(_reqs()) == clean
        assert eng.stats.faults_injected == 0
        assert eng.stats.injection_log == []

    def test_sticky_permanent_global_detects_unprotected_passes(
            self, small_model):
        """The arxiv 2205.12177 detection gap: a sticky faulty unit
        corrupts every step AND every retry.  Under global ABFT the
        retries keep failing -> detected hard fault (+ eviction);
        unprotected, the same campaign silently corrupts the streams."""
        cfg, model, params = small_model
        kw = dict(permanent_rate=1.0, permanent_duration=1000, seed=1,
                  layers=cfg.n_layers, dtype=jnp.float32, magnitude=MAG)
        protected = _engine(model, params, abft=GLOBAL,
                            fault_model=FaultModel(**kw))
        protected.run(_reqs())
        sp = protected.stats
        assert sp.faults_detected >= 1
        assert sp.faults_uncorrected >= 1   # sticky through retries
        assert sp.hard_faults >= 1
        assert sp.sdc_faults == 0           # detected, never silent

        clean = _engine(model, params, abft=ABFTConfig.off()).run(_reqs())
        bare = _engine(model, params, abft=ABFTConfig.off(),
                       fault_model=FaultModel(**kw))
        out = bare.run(_reqs())
        sb = bare.stats
        assert sb.faults_detected == 0      # nothing even noticed
        assert sb.hard_faults == 0
        assert sb.sdc_faults >= 1           # silently corrupted tokens
        assert out != clean


# ==================================== adaptive protection in the engine

class TestAdaptiveEngine:
    def test_escalates_under_elevated_rate_and_stays_correct(
            self, small_model):
        cfg, model, params = small_model
        clean = _engine(model, params).run(_reqs())
        pol = ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                                  detection_threshold=0.05,
                                  deescalate_after=4)
        tel = EngineTelemetry(trace=True)
        fm = FaultModel(transient_rate=0.6, seed=1, layers=cfg.n_layers,
                        dtype=jnp.float32, magnitude=MAG)
        eng = _engine(model, params,
                      abft=ABFTConfig.from_policy(pol, use_pallas=False),
                      fault_model=fm, telemetry=tel)
        out = eng.run(_reqs())
        assert eng.stats.protection_escalations >= 1
        assert eng.protection_level == pol.level
        assert eng.stats.sdc_faults == 0
        assert out == clean
        instants = [e for e in tel.tracer.events
                    if e.get("name") == "protection_escalation"]
        assert instants and \
            instants[0]["args"]["direction"] == "escalate"
        assert "window_detection_rate" in instants[0]["args"]

    def test_quiet_regime_matches_base_policy_byte_for_byte(
            self, small_model):
        cfg, model, params = small_model
        base_eng = _engine(model, params, abft=ABFTConfig.from_policy(
            IntensityGuidedPolicy(), use_pallas=False))
        base_out = base_eng.run(_reqs())
        pol = ErrorAdaptivePolicy(IntensityGuidedPolicy())
        ada = _engine(model, params,
                      abft=ABFTConfig.from_policy(pol, use_pallas=False))
        ada_out = ada.run(_reqs())
        assert ada_out == base_out
        assert ada.stats.protection_escalations == 0
        assert ada.protection_level == 0
        # identical per-layer scheme choices in the compiled plan
        assert [(r["layer"], r["scheme"])
                for r in ada.plan.report_rows()] == \
               [(r["layer"], r["scheme"])
                for r in base_eng.plan.report_rows()]

    def test_plan_rows_carry_protection_level(self, small_model):
        cfg, model, params = small_model
        pol = ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                                  detection_threshold=0.05)
        tel = EngineTelemetry(trace=True)
        fm = FaultModel(transient_rate=0.6, seed=1, layers=cfg.n_layers,
                        dtype=jnp.float32, magnitude=MAG)
        eng = _engine(model, params,
                      abft=ABFTConfig.from_policy(pol, use_pallas=False),
                      fault_model=fm, telemetry=tel)
        eng.run(_reqs())
        rows = [e for e in tel.tracer.events
                if e.get("name") == "plan_row"]
        levels = {e["args"].get("protection_level") for e in rows}
        assert {0, 1} <= levels          # pre- and post-escalation rows


# ==================================== fault_at landing ground truth

class TestFaultAtLanding:
    def test_run_records_where_the_armed_fault_landed(self, small_model):
        cfg, model, params = small_model
        eng = _engine(model, params)
        fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, MAG))
        eng.run(_reqs(n=1, new_tokens=6), fault_at=(2, fault))
        log = eng.stats.injection_log
        assert len(log) == 1
        entry = log[0]
        assert entry["source"] == "fault_at"
        assert entry["armed_step"] == 2
        assert entry["run_step"] == 2
        assert entry["phase"] in ("decode", "prefill", "prefill_chunk")
        assert entry["outcome"] == "corrected"
        assert eng.stats.faults_injected == 1
