import os
import signal
import threading

# Smoke tests and benches must see ONE device; only launch/dryrun.py sets
# the 512-device XLA flag (and it runs in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# Per-test wall-clock budget: a scheduler deadlock (engine loop waiting
# on a slot that never frees) should fail ONE test fast, not hang the
# whole CI workflow until the job-level timeout.  SIGALRM-based because
# the container has no pytest-timeout plugin; the first test in a
# session pays jit compilation, hence the generous default.
_TIMEOUT_S = int(os.environ.get("PYTEST_PER_TEST_TIMEOUT", "300"))


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    if (
        _TIMEOUT_S <= 0
        or not hasattr(signal, "SIGALRM")
        or threading.current_thread() is not threading.main_thread()
    ):
        return (yield)

    def _expired(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {_TIMEOUT_S}s per-test timeout "
            "(PYTEST_PER_TEST_TIMEOUT)")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(_TIMEOUT_S)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture
def rng():
    return np.random.default_rng(0xABF7)
