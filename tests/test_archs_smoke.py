"""Per-architecture smoke tests: reduced config of the same family, one
forward + one gradient step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core import ABFTConfig, Scheme
from repro.models import LayerCtx, build_model

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
CTX = LayerCtx(abft=ABFT)


def _batch(cfg, B=2, L=16, dtype=jnp.float32):
    batch = {"tokens": jnp.ones((B, L), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_input"] = (
            0.1 * jnp.ones((B, cfg.enc_seq_len, cfg.d_model), dtype))
    if cfg.vision_dim:
        batch["images"] = (
            0.1 * jnp.ones((B, cfg.n_image_tokens, cfg.vision_dim), dtype))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = scaled_down(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    B, L = 2, 16
    out = model.forward(params, _batch(cfg, B, L), CTX)
    assert out.logits.shape == (B, L, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(out.logits)))
    assert not bool(out.flag)  # clean run: no ABFT flag
    if cfg.mtp_depth:
        assert out.mtp_logits is not None
        assert out.mtp_logits.shape == (B, L, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_grad_step(arch):
    cfg = scaled_down(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    B, L = 2, 8
    batch = _batch(cfg, B, L)
    labels = jnp.ones((B, L), jnp.int32)

    def loss_fn(p):
        out = model.forward(p, batch, CTX)
        logp = jax.nn.log_softmax(out.logits.astype(jnp.float32), axis=-1)
        nll = -jnp.mean(
            jnp.take_along_axis(logp, labels[..., None], axis=-1))
        return nll + 0.01 * out.aux_loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no gradients produced"
    for g in leaves:
        assert not bool(jnp.any(jnp.isnan(g)))
    # gradient actually flows to the embedding
    gnorm = float(
        sum(jnp.sum(jnp.abs(g)) for g in leaves))
    assert gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(pos=L) after prefill matches forward() on L+1 tokens (up to
    MoE capacity effects for routed archs)."""
    cfg = scaled_down(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1), dtype=jnp.float32)
    B, L, S = 2, 12, 24
    batch = _batch(cfg, B, L)
    cache = model.init_cache(B, S, dtype=jnp.float32)
    logits, cache, flag = model.prefill(params, batch, cache, CTX)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(flag)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache, flag2 = model.decode(
        params, tok, cache, jnp.asarray(L, jnp.int32), CTX)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits2)))

    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], tok], 1))
    full = model.forward(params, batch2, CTX)
    tol = 0.05 if cfg.n_experts else 1e-3   # capacity effects for MoE
    np.testing.assert_allclose(
        np.asarray(full.logits[:, -1]), np.asarray(logits2[:, 0]),
        rtol=tol, atol=tol)


def test_exact_published_configs_registered():
    """The ten assigned architectures carry the exact published dims."""
    c = get_config("qwen3-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 40, 8, 17408, 151936)
    assert c.qk_norm
    c = get_config("stablelm-1.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2048, 32, 32, 5632, 100352)
    c = get_config("llama3.2-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (16, 2048, 32, 8, 8192, 128256)
    c = get_config("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (64, 5120, 40, 40, 27392, 152064)
    assert c.qkv_bias
    c = get_config("jamba-v0.1-52b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 8, 14336, 65536)
    assert (c.n_experts, c.experts_per_token) == (16, 2)
    assert (c.attn_every, c.moe_every) == (8, 2)
    c = get_config("whisper-tiny")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (4, 4, 384, 6, 1536, 51865)
    assert c.is_encoder_decoder
    c = get_config("mamba2-1.3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size, c.ssm_state) == (
        48, 2048, 0, 50280, 128)
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        61, 7168, 128, 129280)
    assert (c.n_experts, c.experts_per_token, c.moe_d_ff,
            c.n_shared_experts) == (256, 8, 2048, 1)
    assert c.attention == "mla" and c.mtp_depth == 1
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        24, 2048, 16, 151936)
    assert (c.n_experts, c.experts_per_token, c.moe_d_ff,
            c.n_shared_experts) == (60, 4, 1408, 4)
    c = get_config("llama-3.2-vision-11b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 14336, 128256)
    assert c.cross_attn_every == 5


def test_fault_injection_detected_in_model():
    """End-to-end: a fault injected into one layer's MLP GEMM flags."""
    from repro.core import FaultSpec
    from repro.models import ModelFault

    cfg = scaled_down(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 3, 1e4))
    ctx = LayerCtx(abft=ABFT, fault=fault)
    out = model.forward(params, _batch(cfg), ctx)
    assert bool(out.flag)
    # same graph, fault disabled -> clean
    ctx2 = LayerCtx(abft=ABFT, fault=ModelFault.none())
    out2 = model.forward(params, _batch(cfg), ctx2)
    assert not bool(out2.flag)
