"""Speculative decoding subsystem (serve/spec_decode.py + engine
verify core): ABFT-protected, intensity-adaptive verification.

Coverage:

  * equivalence — greedy streams from a speculative engine are
    byte-identical to the unsped engine for dense, paged,
    paged+prefix-sharing, chunked-prefill, and MLA caches, for both
    shipped proposers, with non-trivial acceptance actually exercised
    (draft quality affects throughput only — see the module invariant
    in spec_decode.py);
  * fault isolation — a fault landing in a verify step retries ONLY
    that draft window (``verify_retries``; the stream is unchanged), a
    persistent verify fault exhausts the retry budget and evicts with
    ``hard_fault:verify``;
  * acceptance rules — ``greedy_accept`` prefix semantics and the
    ``rejection_sample`` law (empirical distribution of each emitted
    token matches the target row distribution under fixed fold_in
    keys);
  * tuning — ``ProtectionPlan.tune_draft_len`` boundary/monotonicity
    properties, and ``draft_len="auto"`` wiring through the engine;
  * scheme selection — on a crafted HardwareSpec the per-step
    intensity-guided decision picks ``block_1s`` for plain decode but
    ``global`` for a K-scaled verify window, with matching
    ``scheme_flip`` telemetry instants;
  * adaptive protection — ``shrink_draft`` JSON round-trip and the
    engine tightening the draft window while escalated;
  * sharding — mesh=2 speculative streams match the unsped mesh=1
    baseline (bf16, multi-device only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.core.hardware import HardwareSpec
from repro.core.policy import ErrorAdaptivePolicy, policy_from_json
from repro.models import ModelFault, build_model
from repro.obs import EngineTelemetry
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine
from repro.serve.spec_decode import (
    NGramProposer,
    greedy_accept,
    make_proposer,
    rejection_sample,
)

N_DEV = len(jax.devices())

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)

# Same crafted spec as tests/test_chunked_prefill.py: with the scaled
# model's (k=64, n=128) f32 step projection the per-step selection picks
# block_1s for small token counts and global once a step carries >= 18
# tokens — so 4-slot plain decode (4 tokens) and a 4-slot K=4 verify
# window (20 tokens) land on DIFFERENT schemes.
FLIP_HW = HardwareSpec(
    name="flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    cfg = scaled_down(get_config("deepseek-v3-671b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2), dtype=jnp.float32)
    return cfg, model, params


def _engine(model, params, *, slots=2, max_len=64, **kw):
    return ServeEngine(model, params, slots=slots, max_len=max_len,
                       abft=ABFT, dtype=jnp.float32, **kw)


def _periodic_reqs(n=3, budget=10):
    """Periodic prompts (the prompt-lookup best case) with staggered
    periods/budgets; the random-init model settles into short output
    cycles, so the n-gram proposer reaches full-K proposals after the
    first few tokens."""
    return [Request(uid=i,
                    prompt=np.tile(3 + np.arange(4 + i % 2,
                                                 dtype=np.int32),
                                   16)[:21 + 2 * i],
                    max_new_tokens=budget + i % 3)
            for i in range(n)]


def _streams(reqs):
    return {r.uid: r.generated for r in reqs}


# ================================================= greedy equivalence

@pytest.mark.parametrize("kind,kw", [
    ("dense", {}),
    ("paged", {"cache_kind": "paged"}),
    ("prefix_shared", {"cache_kind": "paged", "prefix_sharing": True}),
    ("chunked", {"cache_kind": "paged", "chunk_tokens": 8}),
])
def test_spec_matches_unsped(small_model, kind, kw):
    _, model, params = small_model
    ref_reqs = _periodic_reqs()
    ref = _engine(model, params, **kw).run(ref_reqs)
    reqs = _periodic_reqs()
    eng = _engine(model, params, spec_decode="ngram", draft_len=3, **kw)
    out = eng.run(reqs)
    assert out == ref
    assert _streams(reqs) == _streams(ref_reqs)
    assert eng.stats.draft_accepted > 0       # speculation really engaged
    assert eng.stats.draft_accepted <= eng.stats.draft_proposed


def test_spec_matches_unsped_self_draft(small_model):
    _, model, params = small_model
    ref_reqs = _periodic_reqs()
    ref = _engine(model, params).run(ref_reqs)
    reqs = _periodic_reqs()
    eng = _engine(model, params, spec_decode="self_draft", draft_len=2)
    assert eng.run(reqs) == ref
    assert _streams(reqs) == _streams(ref_reqs)
    assert eng.stats.draft_proposed > 0


def test_spec_matches_unsped_mla(mla_model):
    """MLA + paged: the rejected-draft rollback path (low acceptance on
    this model) still reproduces the unsped stream."""
    _, model, params = mla_model
    ref_reqs = _periodic_reqs(n=2, budget=6)
    ref = _engine(model, params, cache_kind="paged").run(ref_reqs)
    reqs = _periodic_reqs(n=2, budget=6)
    eng = _engine(model, params, cache_kind="paged",
                  spec_decode="ngram", draft_len=3)
    assert eng.run(reqs) == ref
    assert _streams(reqs) == _streams(ref_reqs)


def test_spec_auto_draft_len_matches(small_model):
    _, model, params = small_model
    ref_reqs = _periodic_reqs()
    ref = _engine(model, params).run(ref_reqs)
    reqs = _periodic_reqs()
    eng = _engine(model, params, spec_decode="ngram", draft_len="auto")
    assert eng.run(reqs) == ref
    assert eng.draft_len >= 1                 # tuner resolved a real K


# ================================================= fault isolation

def test_verify_fault_retries_window_only(small_model):
    """A transient fault on a verify step: detected, the draft window
    re-executes from the pre-step cache/keys, the stream is unchanged
    and only ``verify_retries`` moves."""
    _, model, params = small_model
    clean_reqs = _periodic_reqs()
    clean = _engine(model, params, spec_decode="ngram",
                    draft_len=3).run(clean_reqs)
    reqs = _periodic_reqs()
    eng = _engine(model, params, spec_decode="ngram", draft_len=3,
                  policy=RecoveryPolicy(max_retries=1))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    out = eng.run(reqs, fault_at=(1, fault))
    assert out == clean
    assert _streams(reqs) == _streams(clean_reqs)
    assert eng.stats.faults_detected == 1
    assert eng.stats.verify_retries == 1
    assert eng.stats.retries == 1             # all retries were verify
    assert eng.stats.hard_faults == 0


def test_verify_hard_fault_evicts(small_model):
    """No retry budget: the faulted verify window becomes a hard fault
    and the resident slots are evicted with ``hard_fault:verify``."""
    _, model, params = small_model
    reqs = _periodic_reqs(n=2)
    eng = _engine(model, params, spec_decode="ngram", draft_len=3,
                  policy=RecoveryPolicy(max_retries=0))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    eng.run(reqs, fault_at=(1, fault))
    assert eng.stats.hard_faults == 1
    assert eng.stats.evictions == 2
    assert all(r.error == "hard_fault:verify" for r in reqs)


# ================================================= acceptance rules

def test_greedy_accept_prefix_semantics():
    t = np.array([5, 6, 7, 8], np.int32)
    assert greedy_accept(np.array([5, 6, 7]), t) == [5, 6, 7, 8]
    assert greedy_accept(np.array([5, 9, 7]), t) == [5, 6]
    assert greedy_accept(np.array([9, 6, 7]), t) == [5]
    assert greedy_accept(np.zeros((0,), np.int32), t) == [5]


def test_ngram_proposer_full_continuation():
    """A periodic tail matches itself near the end of history; the
    proposer must still find an occurrence with a full K-token
    continuation instead of stranding the proposal at one token."""
    req = Request(uid=0, prompt=np.tile(
        np.array([3, 4, 5, 6], np.int32), 8), max_new_tokens=4)
    out = NGramProposer().propose(req, 4)
    assert list(out) == [3, 4, 5, 6]
    # no n-gram of an all-distinct history recurs -> empty proposal
    req2 = Request(uid=1, prompt=np.arange(1, 20, dtype=np.int32),
                   max_new_tokens=4)
    assert NGramProposer().propose(req2, 4).size == 0


def test_rejection_sample_matches_target_law():
    """Point-mass speculative sampling is exact in law: over many keys,
    the first emitted token's empirical distribution matches the target
    row whether the draft is likely or unlikely under it."""
    probs = np.array([[0.5, 0.3, 0.2],
                      [1 / 3, 1 / 3, 1 / 3]], np.float64)  # bonus row
    for draft in (0, 2):
        counts = np.zeros(3)
        n = 3000
        for i in range(n):
            key = jax.random.PRNGKey(i)
            emitted = rejection_sample(
                np.array([draft], np.int32), probs, key)
            counts[emitted[0]] += 1
        assert np.abs(counts / n - probs[0]).max() < 0.03


def test_rejection_sample_bonus_token():
    """A fully accepted window emits one bonus draw from the last row."""
    probs = np.array([[1.0, 0.0], [0.0, 1.0]], np.float64)
    out = rejection_sample(np.array([0], np.int32), probs,
                           jax.random.PRNGKey(0))
    assert out == [0, 1]


def test_make_proposer_validation(small_model):
    _, model, params = small_model
    with pytest.raises(ValueError, match="unknown draft proposer"):
        make_proposer("beam", model, None, lambda: params)
    with pytest.raises(TypeError, match="propose"):
        make_proposer(42, model, None, lambda: params)


# ================================================= tune_draft_len

def test_tune_draft_len_properties(small_model):
    _, model, params = small_model
    plan = model.protection_plan(hw=FLIP_HW, phase="serve", n_tokens=4,
                                 dtype_bytes=4)
    k = plan.tune_draft_len(batch=4)
    assert 1 <= k <= 8
    assert plan.tune_draft_len(batch=4, hi=3) <= 3
    # zero acceptance can never amortize the larger window
    assert plan.tune_draft_len(batch=4, accept_rate=0.0) == 0
    # monotone: a better proposer never shrinks the chosen window
    ks = [plan.tune_draft_len(batch=4, accept_rate=a)
          for a in (0.0, 0.3, 0.6, 0.9, 1.0)]
    assert ks == sorted(ks)


def test_tune_draft_len_memoized(small_model):
    _, model, params = small_model
    plan = model.protection_plan(hw=FLIP_HW, phase="serve", n_tokens=4,
                                 dtype_bytes=4)
    assert plan.tune_draft_len(batch=2) == plan.tune_draft_len(batch=2)


# ================================================= scheme selection

def test_for_step_scheme_differs_for_verify_window(small_model):
    """The acceptance criterion: the SAME plan selects different schemes
    for a plain decode step vs a K-token verify window on the crafted
    hardware."""
    _, model, params = small_model
    plan = model.protection_plan(hw=FLIP_HW, phase="serve", n_tokens=4,
                                 dtype_bytes=4)
    assert plan.for_step(4).scheme_name == "block_1s"     # plain decode
    assert plan.for_step(4 * 5).scheme_name == "global"   # K=4 verify


def test_engine_scheme_flips_with_draft_len(small_model):
    """End to end: a 4-slot speculative engine on FLIP_HW crosses the
    CMR whenever full K=4 windows execute — the selection trace carries
    BOTH schemes for decode-composition steps and every flip has a
    matching scheme_flip telemetry instant."""
    _, model, params = small_model
    tel = EngineTelemetry(trace=True)
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                      hardware=FLIP_HW)
    eng = ServeEngine(model, params, slots=4, max_len=64, abft=abft,
                      dtype=jnp.float32, spec_decode="ngram",
                      draft_len=4, telemetry=tel)
    eng.run(_periodic_reqs(n=4, budget=14))
    verify_schemes = {e["scheme"] for e in eng.stats.selection_trace
                      if e["decode"] and not e["prefill"]}
    assert verify_schemes == {"block_1s", "global"}
    assert eng.stats.scheme_flips > 0
    flips = [e for e in tel.tracer.events
             if e.get("name") == "scheme_flip"]
    assert len(flips) == eng.stats.scheme_flips


# ================================================= engine validation

def test_spec_rejects_ssm_models():
    cfg = scaled_down(get_config("mamba2-1.3b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    with pytest.raises(ValueError, match="attention-only"):
        _engine(model, params, spec_decode="ngram", draft_len=2)


def test_spec_rejects_flash_attention(small_model):
    _, model, params = small_model
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=True,
                      flash_attention=True)
    with pytest.raises(ValueError, match="flash"):
        ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                    dtype=jnp.float32, spec_decode="ngram", draft_len=2)


def test_spec_rejects_bad_draft_len(small_model):
    _, model, params = small_model
    with pytest.raises(ValueError, match="draft_len"):
        _engine(model, params, spec_decode="ngram", draft_len=0)


# ================================================= adaptive shrink

def test_shrink_draft_json_roundtrip():
    p = ErrorAdaptivePolicy(shrink_draft=0.5)
    assert policy_from_json(p.to_json()).shrink_draft == 0.5
    # default survives round-trip of pre-existing serializations
    d = ErrorAdaptivePolicy().to_json()
    d.pop("shrink_draft")
    assert policy_from_json(d).shrink_draft == 1.0
    with pytest.raises(ValueError, match="shrink_draft"):
        ErrorAdaptivePolicy(shrink_draft=0.0)


def test_escalation_shrinks_draft_window(small_model):
    _, model, params = small_model
    adaptive = ErrorAdaptivePolicy(shrink_draft=0.5)
    abft = ABFTConfig.from_policy(adaptive, use_pallas=False)
    eng = ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                      dtype=jnp.float32, spec_decode="ngram",
                      draft_len=4)
    assert eng.draft_len == 4
    adaptive.level = 1
    eng._set_protection_level(1, {})
    assert eng.draft_len == 2
    adaptive.level = 0
    eng._set_protection_level(0, {})
    assert eng.draft_len == 4


# ================================================= telemetry counters

def test_spec_counters_exported(small_model):
    _, model, params = small_model
    tel = EngineTelemetry()
    eng = _engine(model, params, spec_decode="ngram", draft_len=3,
                  telemetry=tel)
    eng.run(_periodic_reqs())
    assert tel.counters_match(eng.stats)
    snap = tel.registry.snapshot()
    prop = snap["serve_spec_draft_proposed_total"]["series"][0]["value"]
    acc = snap["serve_spec_draft_accepted_total"]["series"][0]["value"]
    assert prop == eng.stats.draft_proposed > 0
    assert acc == eng.stats.draft_accepted <= prop
    gauges = {g: snap[g]["series"][0]["value"]
              for g in ("serve_spec_draft_len", "serve_spec_accept_rate")}
    assert gauges["serve_spec_draft_len"] == eng.draft_len
    assert gauges["serve_spec_accept_rate"] == pytest.approx(acc / prop)


# ================================================= sharded equality

@pytest.mark.skipif(N_DEV < 2, reason="needs 2 devices (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
def test_spec_matches_mesh1_baseline(small_model):
    cfg, model, _ = small_model
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.bfloat16)

    def run(mesh, spec):
        reqs = _periodic_reqs(n=3, budget=6)
        kw = dict(spec_decode="ngram", draft_len=3) if spec else {}
        ServeEngine(model, params, slots=2, max_len=64, abft=ABFT,
                    dtype=jnp.bfloat16, cache_kind="paged",
                    num_blocks=24, mesh=mesh, **kw).run(reqs)
        return _streams(reqs)

    base = run(1, spec=False)
    assert run(2, spec=True) == base
    assert run(2, spec=False) == base
