"""Roofline analyzer tests: the scan-corrected HLO parser must reproduce
hand-computed costs on known modules (the whole §Roofline rests on it).

HLO fixtures are produced in a subprocess (8 host devices) so these tests
are independent of the jax device state of the main pytest process.
"""

import json
import subprocess
import sys

import pytest

from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_parser import analyze_hlo, parse_module

_GEN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 4), ("data", "model"))

def compile_scan(L, m, k, n, nested):
    def f(x, ws):
        def body(h, w):
            if nested:
                def inner(hh, _):
                    return jnp.dot(hh, w,
                                   preferred_element_type=jnp.float32), None
                h2, _ = jax.lax.scan(inner, h, None, length=nested)
                return h2, None
            return jnp.dot(h, w, preferred_element_type=jnp.float32), None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, k, n), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, None, "model")),
        )).lower(x, ws).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one entry per device
        ca = ca[0]
    return {"hlo": c.as_text(), "xla_flops": ca["flops"]}

out = {
    "flat": compile_scan(5, 32, 64, 64, 0),
    "nested": compile_scan(5, 32, 64, 64, 3),
    "deep": compile_scan(8, 32, 64, 64, 0),
}
json.dump(out, sys.stdout)
"""


@pytest.fixture(scope="module")
def hlo_fixtures():
    res = subprocess.run(
        [sys.executable, "-c", _GEN], capture_output=True, text=True,
        timeout=300, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-2000:]
    return json.loads(res.stdout)


def test_scan_flops_exact(hlo_fixtures):
    fx = hlo_fixtures["flat"]
    res = analyze_hlo(fx["hlo"])
    exact = 5 * 2 * (32 // 2) * 64 * (64 // 4)   # per-device
    assert res["flops"] == exact
    # XLA's own analysis undercounts the loop (counts the body once)
    assert fx["xla_flops"] < exact


def test_nested_scan_flops_exact(hlo_fixtures):
    res = analyze_hlo(hlo_fixtures["nested"]["hlo"])
    exact = 5 * 3 * 2 * (32 // 2) * 64 * (64 // 4)
    assert res["flops"] == exact


def test_collectives_scale_with_trip_count(hlo_fixtures):
    res = analyze_hlo(hlo_fixtures["flat"]["hlo"])
    # TP dot all-gathers the (16, 64) f32 activation every iteration
    assert res["collectives"]["all-gather"] == 5 * 16 * 64 * 4


def test_parse_module_structure(hlo_fixtures):
    comps, entry = parse_module(hlo_fixtures["flat"]["hlo"])
    assert entry is not None and entry in comps
    kinds = {op.kind for comp in comps.values() for op in comp.ops}
    assert "while" in kinds and "dot" in kinds


def test_bytes_do_not_charge_full_stack_per_iteration(hlo_fixtures):
    """Layer-stacked weights are dynamic-sliced per iteration; traffic must
    be ~the per-layer slice x L, not the full stack x L."""
    L, k, n = 8, 64, 64
    res = analyze_hlo(hlo_fixtures["deep"]["hlo"])
    full_stack_per_iter = L * (L * k * (n // 4) * 4)  # pathological bound
    assert res["bytes"] < full_stack_per_iter


def test_roofline_terms_bottleneck():
    t = roofline_terms(1e12, 1e9, 1e6)
    assert t["bottleneck"] == "compute"
    t = roofline_terms(1e9, 1e12, 1e6)
    assert t["bottleneck"] == "memory"
    t = roofline_terms(1e9, 1e9, 1e12)
    assert t["bottleneck"] == "collective"
