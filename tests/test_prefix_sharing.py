"""Refcount-safe block lifecycle: prefix sharing / copy-on-write.

Three layers of coverage:

  * BlockPool property tests — alloc -> share -> COW -> evict round trips
    in random order never double-free or leak a block, and
    ``blocks_free + blocks_used == num_blocks`` with refcounts exactly
    equal to table references at every point (``check_invariants``);
  * PrefixIndex unit tests — chain matching, the partial-tail COW case,
    the ``len(prompt) - 1`` cap, and purge-on-free;
  * engine equivalence — greedy streams from the prefix-sharing engine
    are byte-identical to the unshared paged engine (itself dense-equal),
    including under injected faults (prefill and decode), for MLA, and
    across fault-driven eviction of one sharer.  Positions matter: the
    suffix prefill computes rotary offsets and causal masks from the true
    logical position, so any off-by-prefix bug shows up as divergence.

Plus the accounting satellites: the rejections/evictions split, fixed
``utilization`` (allocated-token denominator), head-of-line lookahead,
and the fault_at re-arm on empty steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.models import ModelFault, build_model
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine
from repro.serve.paged_cache import (
    BlockPool,
    PoolExhausted,
    PrefixIndex,
    blocks_for,
)

ABFT = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)


@pytest.fixture(scope="module")
def small_model():
    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, model, params


@pytest.fixture(scope="module")
def mla_model():
    cfg = scaled_down(get_config("deepseek-v3-671b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2), dtype=jnp.float32)
    return cfg, model, params


def _engine(model, params, slots=3, max_len=64, block_size=16, **kw):
    return ServeEngine(model, params, slots=slots, max_len=max_len,
                       abft=ABFT, dtype=jnp.float32, cache_kind="paged",
                       block_size=block_size, **kw)


TPL = np.arange(1, 41, dtype=np.int32)          # 40-token shared template


def _templated(n=8):
    """Template + unique tail, with staggered budgets so lifetimes
    overlap (sharing needs a live sharer holding the template blocks)."""
    out = []
    for i in range(n):
        tail = (100 + 7 * i + np.arange(1 + i % 3, dtype=np.int32)) \
            % 250 + 1
        out.append(Request(uid=i,
                           prompt=np.concatenate([TPL, tail.astype(np.int32)]),
                           max_new_tokens=4 + (i * 3) % 6))
    return out


# ================================================================ BlockPool

def test_refcount_share_and_last_reference_free():
    bp = BlockPool(num_blocks=6, block_size=4, slots=3, table_width=4)
    assert bp.try_alloc(0, 10)                    # 3 blocks
    owner = [int(b) for b in bp.tables[0, :3]]
    # slot 1 aliases slot 0's first two blocks + one fresh
    assert bp.try_admit_prefix(1, 9, owner[:2])
    assert bp.ref_of(owner[0]) == 2 and bp.ref_of(owner[1]) == 2
    assert bp.blocks_shared == 2
    assert bp.blocks_used == 4                    # 3 + 1 fresh
    bp.check_invariants()
    # evicting the ORIGINAL owner must not free the shared blocks
    freed = bp.free_slot(0)
    assert set(freed) == {owner[2]}               # only the unshared one
    assert bp.ref_of(owner[0]) == 1
    assert bp.blocks_used == 3 and bp.blocks_shared == 0
    bp.check_invariants()
    # last reference drops -> physically freed
    freed = bp.free_slot(1)
    assert set(freed) >= {owner[0], owner[1]}
    assert bp.blocks_used == 0
    bp.check_invariants()


def test_cow_redirects_shared_block_only():
    bp = BlockPool(num_blocks=5, block_size=4, slots=2, table_width=3)
    assert bp.try_alloc(0, 6)                     # blocks 0..1 of slot 0
    shared = [int(b) for b in bp.tables[0, :2]]
    assert bp.try_admit_prefix(1, 7, shared)      # full alias, no fresh
    # the tail block is shared -> COW redirects slot 1's entry
    pair = bp.try_cow(1, 1)
    assert pair is not None
    src, dst = pair
    assert src == shared[1] and dst != src
    assert int(bp.tables[1, 1]) == dst and int(bp.tables[0, 1]) == src
    assert bp.ref_of(src) == 1 and bp.ref_of(dst) == 1
    # exclusively owned block: no copy needed
    assert bp.try_cow(1, 1) is None
    bp.check_invariants()
    # COW with an empty free list raises (callers budget the block)
    bp2 = BlockPool(num_blocks=3, block_size=4, slots=2, table_width=2)
    assert bp2.try_alloc(0, 8)                    # 2 of 3 blocks
    assert bp2.try_admit_prefix(1, 5, [int(bp2.tables[0, 0])])
    assert bp2.blocks_free == 0                   # fresh tail took the last
    with pytest.raises(PoolExhausted):
        bp2.try_cow(1, 0)
    bp2.check_invariants()


def test_pool_random_lifecycle_never_leaks_or_double_frees():
    """Property test: random alloc/share/COW/grow/evict round trips keep
    refcounts == table references and the free-list disjointness at every
    step; draining at the end returns every block exactly once."""
    rng = np.random.default_rng(0xB10C)
    bp = BlockPool(num_blocks=12, block_size=4, slots=5, table_width=6)
    for _ in range(400):
        op = rng.choice(["alloc", "share", "cow", "grow", "free"])
        if op == "alloc":
            empties = [s for s in range(bp.slots) if bp.slot_blocks(s) == 0]
            if empties:
                bp.try_alloc(int(rng.choice(empties)),
                             int(rng.integers(1, 20)))
        elif op == "share":
            live = [s for s in range(bp.slots) if bp.slot_blocks(s) > 0]
            empties = [s for s in range(bp.slots) if bp.slot_blocks(s) == 0]
            if live and empties:
                src = int(rng.choice(live))
                k = int(rng.integers(1, bp.slot_blocks(src) + 1))
                shared = [int(b) for b in bp.tables[src, :k]]
                lo = (k - 1) * bp.block_size + 1
                hi = bp.table_width * bp.block_size
                bp.try_admit_prefix(int(rng.choice(empties)),
                                    int(rng.integers(lo, hi + 1)), shared)
        elif op == "cow":
            live = [s for s in range(bp.slots) if bp.slot_blocks(s) > 0]
            if live:
                s = int(rng.choice(live))
                try:
                    bp.try_cow(s, int(rng.integers(0, bp.slot_blocks(s))))
                except PoolExhausted:
                    pass
        elif op == "grow":
            live = [s for s in range(bp.slots) if bp.slot_blocks(s) > 0]
            if live:
                s = int(rng.choice(live))
                bp.try_grow(s, bp.capacity_tokens(s)
                            + int(rng.integers(1, 5)))
        else:
            bp.free_slot(int(rng.integers(0, bp.slots)))
        bp.check_invariants()
    for s in range(bp.slots):
        bp.free_slot(s)
    bp.check_invariants()
    assert bp.blocks_used == 0 and bp.blocks_free == bp.num_blocks


# ================================================================ PrefixIndex

def test_index_match_register_and_purge():
    bp = BlockPool(num_blocks=8, block_size=4, slots=2, table_width=6)
    idx = PrefixIndex(4)
    prompt = np.arange(1, 12, dtype=np.int32)     # 11 tokens: 2 full + 3
    assert bp.try_alloc(0, len(prompt))
    idx.add(prompt, bp.tables[0])
    row = [int(b) for b in bp.tables[0, :3]]

    # same template, different tail: 2 full blocks + partial lead of 3
    other = np.concatenate([prompt[:10], np.array([99, 98], np.int32)])
    m = idx.match(other)
    assert m.shared_ids == row and m.partial
    assert m.match_len == 10                      # 8 full + 2 common tail

    # identical prompt: capped at len - 1 so logits still come from a
    # real suffix token
    m = idx.match(prompt)
    assert m.match_len == len(prompt) - 1 and m.partial

    # divergence inside the first block: no match at all
    div = prompt.copy()
    div[2] = 77
    m = idx.match(div)
    assert m.shared_ids == [] and m.match_len == 0

    # physically freeing the blocks purges every entry
    freed = bp.free_slot(0)
    idx.purge(freed)
    m = idx.match(other)
    assert m.shared_ids == [] and m.match_len == 0


def test_index_block_aligned_full_entry_seeds_partial():
    """A block-aligned cached prompt matched by an identical prompt: the
    cap forces the last full block into a PARTIAL share (COW copy +
    recompute of one token)."""
    bp = BlockPool(num_blocks=4, block_size=4, slots=2, table_width=4)
    idx = PrefixIndex(4)
    prompt = np.arange(1, 9, dtype=np.int32)      # exactly 2 blocks
    assert bp.try_alloc(0, len(prompt))
    idx.add(prompt, bp.tables[0])
    m = idx.match(prompt)
    assert m.match_len == 7 and m.partial
    assert m.full_blocks == 1
    assert m.shared_ids == [int(bp.tables[0, 0]), int(bp.tables[0, 1])]


# ================================================================ engine

def _run_pair(model, params, reqs_fn, **run_kw):
    base = _engine(model, params)
    r_base = base.run(reqs_fn(), **run_kw)
    sh = _engine(model, params, prefix_sharing=True)
    r_sh = sh.run(reqs_fn(), **run_kw)
    return base, r_base, sh, r_sh


def test_shared_streams_byte_identical_to_unshared(small_model):
    _, model, params = small_model
    base, r_base, sh, r_sh = _run_pair(model, params, _templated)
    assert r_base == r_sh
    assert sh.stats.prefix_tokens_shared > 0      # sharing actually fired
    assert sh.stats.blocks_shared_peak > 0
    assert sh.stats.blocks_used_mean < base.stats.blocks_used_mean
    assert sh.pool.blocks_used == 0               # drained clean
    sh.pool.check_invariants()
    assert sh.cache_stats()["prefix_hit_rate"] > 0.2


def test_shared_streams_survive_decode_fault(small_model):
    """ABFT detect->recompute with live sharers: host tables/refcounts
    stay frozen across the attempt/retry window, so the recovered streams
    still match the unshared engine byte for byte."""
    _, model, params = small_model
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    base, r_base, sh, r_sh = _run_pair(
        model, params, _templated, fault_at=(4, fault))
    assert sh.stats.faults_detected >= 1 and sh.stats.retries >= 1
    assert sh.stats.hard_faults == 0
    assert sh.stats.prefix_tokens_shared > 0
    assert r_base == r_sh


def test_shared_streams_survive_admission_fault(small_model):
    """A faulty prefill of a SHARING admission batch retries from the
    pre-admission pool (which already contains the COW copies)."""
    _, model, params = small_model
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    base, r_base, sh, r_sh = _run_pair(
        model, params, _templated, admit_fault_at=(4, fault))
    assert sh.stats.faults_detected >= 1
    assert sh.stats.hard_faults == 0
    assert sh.stats.prefix_tokens_shared > 0
    assert r_base == r_sh


def test_mixed_shared_and_unique_batch_matches_unshared(small_model):
    """An admission batch mixing sharers with UNIQUE prompts: the unique
    rows ride the suffix path with prefix_lens == 0 (gathered-KV
    attention over extra fully-masked keys), which must stay bit-exact
    with the from-zero prefill — masked contributions are exact zeros."""
    _, model, params = small_model

    def reqs():
        out = []
        for i in range(10):
            if i % 3 == 2:
                prompt = ((777 * (i + 1)
                           + np.arange(9 + i, dtype=np.int64)) % 250
                          + 1).astype(np.int32)
            else:
                tail = (100 + 7 * i + np.arange(1 + i % 3,
                                                dtype=np.int32)) % 250 + 1
                prompt = np.concatenate([TPL, tail.astype(np.int32)])
            out.append(Request(uid=i, prompt=prompt,
                               max_new_tokens=4 + (i * 3) % 6))
        return out

    base, r_base, sh, r_sh = _run_pair(model, params, reqs)
    assert r_base == r_sh
    assert sh.stats.prefix_tokens_shared > 0
    sh.pool.check_invariants()


def test_shared_mla_latent_matches_unshared(mla_model):
    """deepseek MLA: sharing the paged latent pool (kv_lora + rope dims)
    must reproduce the unshared streams exactly."""
    _, model, params = mla_model

    def reqs():
        tpl = np.arange(1, 13, dtype=np.int32)
        return [Request(uid=i,
                        prompt=np.concatenate(
                            [tpl, np.array([50 + i], np.int32)]),
                        max_new_tokens=3 + i % 3)
                for i in range(5)]

    base = _engine(model, params, slots=2, max_len=32, block_size=8)
    sh = _engine(model, params, slots=2, max_len=32, block_size=8,
                 prefix_sharing=True)
    assert base.run(reqs()) == sh.run(reqs())
    assert sh.stats.prefix_tokens_shared > 0
    assert sh.stats.cow_copies > 0                # 12 % 8 != 0: COW tail


def test_identical_prompt_shares_via_cow(small_model):
    """Two identical prompts: the second aliases the first's blocks and
    COWs the tail, prefilling only ONE suffix token — stream unchanged."""
    _, model, params = small_model
    prompt = np.arange(1, 21, dtype=np.int32)     # 20 tokens, bs 16
    a = Request(uid=0, prompt=prompt, max_new_tokens=8)
    b = Request(uid=1, prompt=prompt.copy(), max_new_tokens=4)
    sh = _engine(model, params, slots=2, prefix_sharing=True)
    assert len(sh.admit([a])) == 1
    sh.step()
    assert len(sh.admit([b])) == 1
    assert sh.stats.cow_copies == 1               # partial tail copied
    assert sh.stats.prefix_tokens_shared == 19    # capped at len - 1
    while sh.active:
        sh.step()
    solo = _engine(model, params, slots=1).run(
        [Request(uid=1, prompt=prompt.copy(), max_new_tokens=4)])
    assert b.generated == solo[1]
    sh.pool.check_invariants()


def test_evicting_one_sharer_preserves_the_other(small_model):
    """Growth exhaustion evicts ONE sharer mid-decode: its references
    drop, the shared template blocks stay resident for the survivor, the
    pool invariant holds, and the survivor's stream matches solo."""
    _, model, params = small_model
    tpl = np.arange(1, 17, dtype=np.int32)        # exactly one 16-block x2
    a = Request(uid=0, prompt=tpl, max_new_tokens=10)
    b = Request(uid=1, prompt=np.concatenate([tpl, np.array([99], np.int32)]),
                max_new_tokens=10)
    eng = _engine(model, params, slots=2, max_len=32, block_size=8,
                  num_blocks=5, prefix_sharing=True)
    # staggered admission so b can match a's registered blocks: a holds
    # 2 template blocks; b aliases both and owns 1 for its tail; both
    # grow during decode until the pool runs dry and ONE is evicted
    assert len(eng.admit([a])) == 1
    eng.step()
    assert len(eng.admit([b])) == 1
    assert eng.stats.prefix_tokens_shared == 16
    assert eng.pool.blocks_shared == 2
    results = {}
    while eng.active:
        eng.step()
    for r in (a, b):
        results[r.uid] = r.generated
    errs = {r.uid: r.error for r in (a, b)}
    assert sorted(errs.values(), key=str) == [None, "oom:kv_blocks"]
    eng.pool.check_invariants()
    assert eng.pool.blocks_used == 0              # drained at the end
    assert eng.stats.evictions == 1 and eng.stats.rejections == 0
    ok = a if a.error is None else b
    solo = ServeEngine(model, params, slots=1, max_len=32, abft=ABFT,
                       dtype=jnp.float32).run(
        [Request(uid=ok.uid, prompt=ok.prompt.copy(),
                 max_new_tokens=10)])
    assert results[ok.uid] == solo[ok.uid]


def test_hard_decode_fault_evicts_sharers_without_corruption(small_model):
    """A persistent decode fault evicts every active sharer: refcounts
    drain to zero, the free list gets every block back exactly once, and
    the engine serves the next (templated) request from a clean pool."""
    _, model, params = small_model
    reqs = _templated(4)
    later = Request(uid=99, prompt=reqs[0].prompt.copy(), max_new_tokens=3)
    eng = _engine(model, params, prefix_sharing=True,
                  policy=RecoveryPolicy(max_retries=0))
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    results = eng.run(reqs + [later], fault_at=(2, fault))
    assert eng.stats.hard_faults >= 1
    eng.pool.check_invariants()
    assert eng.pool.blocks_used == 0
    solo = ServeEngine(model, params, slots=1, max_len=64, abft=ABFT,
                       dtype=jnp.float32).run(
        [Request(uid=99, prompt=later.prompt.copy(), max_new_tokens=3)])
    assert results[99] == solo[99]


def test_hybrid_and_encdec_models_refuse_prefix_sharing(small_model):
    cfg = scaled_down(get_config("jamba-v0.1-52b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1), dtype=jnp.float32)
    assert not model.supports_prefix_sharing
    with pytest.raises(ValueError, match="prefix_sharing"):
        _engine(model, params, slots=2, max_len=32, block_size=8,
                prefix_sharing=True)
    # and sharing requires the paged cache
    _, lmodel, lparams = small_model
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(lmodel, lparams, slots=2, max_len=32, abft=ABFT,
                    dtype=jnp.float32, prefix_sharing=True)


# ================================================================ accounting

def test_utilization_uses_allocated_denominator(small_model):
    """The cache_stats fix: paged utilization divides live logical tokens
    by ALLOCATED tokens (blocks_used * block_size), making internal
    fragmentation visible instead of hiding it behind pool capacity."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2, block_size=16)
    req = Request(uid=0, prompt=np.arange(1, 21, dtype=np.int32),
                  max_new_tokens=8)
    assert len(eng.admit([req])) == 1
    s = eng.cache_stats()
    assert s["blocks_used"] == 2 and s["tokens_allocated"] == 32
    assert s["active_tokens"] == 20
    assert s["utilization"] == pytest.approx(20 / 32)
    assert s["fragmentation"] == pytest.approx(12 / 32)
    assert s["blocks_shared"] == 0
    assert {"utilization", "fragmentation", "blocks_shared",
            "prefix_hit_rate"} <= set(s)


def test_blocks_shared_visible_mid_flight(small_model):
    _, model, params = small_model
    prompt = np.arange(1, 33, dtype=np.int32)     # 2 full 16-blocks
    eng = _engine(model, params, slots=2, prefix_sharing=True)
    assert len(eng.admit([Request(uid=0, prompt=prompt,
                                  max_new_tokens=6)])) == 1
    eng.step()
    assert len(eng.admit([Request(uid=1, prompt=prompt.copy(),
                                  max_new_tokens=4)])) == 1
    s = eng.cache_stats()
    assert s["blocks_shared"] >= 1
    assert s["prefix_hit_rate"] > 0
    # sharing can push utilization past 1.0: several slots count the same
    # allocated block — that excess IS the sharing win
    assert s["utilization"] > 0.5


# ================================================================ HOL / run()

def test_lookahead_admits_small_request_behind_deferred_big(small_model):
    """Head-of-line fix: a transiently-deferred large prompt no longer
    stalls a small request behind it, and still completes later without
    error once decode frees its blocks."""
    _, model, params = small_model
    eng = _engine(model, params, slots=2, num_blocks=5)
    c = Request(uid=0, prompt=np.arange(1, 33, dtype=np.int32),
                max_new_tokens=4)                 # 2 blocks, grows to 3
    assert len(eng.admit([c])) == 1
    big = Request(uid=1, prompt=np.arange(1, 50, dtype=np.int32),
                  max_new_tokens=4)               # needs 4 > 3 free
    small = Request(uid=2, prompt=np.arange(1, 11, dtype=np.int32),
                    max_new_tokens=3)             # fits right now
    pending = [big, small]
    consumed = eng.admit(pending)
    assert consumed == [small]                    # lookahead bypass
    assert pending == [big]                       # head stays queued
    while pending or eng.active:
        eng.admit(pending)
        eng.step()
    assert big.error is None and len(big.generated) == 4
    solo = ServeEngine(model, params, slots=1, max_len=64, abft=ABFT,
                       dtype=jnp.float32).run(
        [Request(uid=1, prompt=np.arange(1, 50, dtype=np.int32),
                 max_new_tokens=4)])
    assert big.generated == solo[1]


def test_bypass_budget_reserves_blocks_for_deferred_head(small_model):
    """Starvation bound: once the deferred head's bypass budget is spent,
    later requests stop jumping the queue — freed blocks accumulate for
    the head, which admits before any post-budget request."""
    _, model, params = small_model
    eng = _engine(model, params, slots=3, num_blocks=5, admit_lookahead=1)
    c = Request(uid=0, prompt=np.arange(1, 33, dtype=np.int32),
                max_new_tokens=4)
    assert len(eng.admit([c])) == 1
    big = Request(uid=1, prompt=np.arange(1, 50, dtype=np.int32),
                  max_new_tokens=4)
    b1 = Request(uid=2, prompt=np.arange(1, 11, dtype=np.int32),
                 max_new_tokens=6)
    b2 = Request(uid=3, prompt=np.arange(1, 11, dtype=np.int32),
                 max_new_tokens=3)
    pending = [big, b1, b2]
    assert eng.admit(pending) == [b1]             # budget of 1: b1 only
    assert eng.admit(pending) == []               # b2 reserved out
    assert pending == [big, b2]
    order = []
    while pending or eng.active:
        order += [r.uid for r in eng.admit(pending)]
        eng.step()
    assert order.index(1) < order.index(3)        # head admits before b2
    assert big.error is None and len(big.generated) == 4


def test_fault_at_rearms_on_step_with_no_active_slots(small_model):
    """A campaign fault landing on a step where nothing decodes (the
    whole admission batch finished at prefill) re-arms for the next real
    step instead of silently dropping."""
    _, model, params = small_model
    eng = ServeEngine(model, params, slots=1, max_len=64, abft=ABFT,
                      dtype=jnp.float32)
    done_at_prefill = Request(uid=0, prompt=np.arange(1, 6, dtype=np.int32),
                              max_new_tokens=1)
    real = Request(uid=1, prompt=np.arange(1, 7, dtype=np.int32),
                   max_new_tokens=4)
    fault = ModelFault.at(1, "mlp_down", FaultSpec.value(0, 2, 1e4))
    # step 0 has no active slots (uid 0 completed at admission)
    results = eng.run([done_at_prefill, real], fault_at=(0, fault))
    assert eng.stats.faults_detected == 1         # injection was NOT lost
    assert eng.stats.retries >= 1 and eng.stats.hard_faults == 0
    solo = ServeEngine(model, params, slots=1, max_len=64, abft=ABFT,
                       dtype=jnp.float32).run(
        [Request(uid=1, prompt=np.arange(1, 7, dtype=np.int32),
                 max_new_tokens=4)])
    assert results[1] == solo[1]
