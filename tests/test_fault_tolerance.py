"""Fault-tolerance substrate tests: checkpoint/restart, elastic re-mesh,
heartbeat failure detection, straggler mitigation, trainer recovery."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.runtime.elastic import ElasticState, plan_remesh, rescale_batch
from repro.runtime.heartbeat import HeartbeatMonitor, StragglerPolicy


# ---------------------------------------------------------------- checkpoint

def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(r.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(r.standard_normal((16,)), jnp.float32),
        },
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(5, tree)
    restored, step = ck.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    path = ck.save(1, tree)
    # flip bytes in one leaf blob
    blob = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(blob.read_bytes())
    raw[-1] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum mismatch"):
        ck.restore(tree)


def test_checkpoint_atomic_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_000000003", "step_000000004"]
    assert ck.latest_step() == 4
    assert not list(tmp_path.glob(".tmp_*"))  # no partial writes left


def test_checkpoint_async_overlap(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save_async(10, tree)
    ck.wait()
    _, step = ck.restore(tree)
    assert step == 10


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore places leaves onto new shardings (elastic restart path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(2, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = ck.restore(tree, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


# ---------------------------------------------------------------- elastic

def test_plan_remesh_keeps_model_width():
    plan = plan_remesh(512, model_parallel=16)
    assert plan.shape == (32, 16)
    plan = plan_remesh(500, model_parallel=16)   # 12 dead
    assert plan.shape == (31, 16)
    assert plan.devices_idle == 500 - 31 * 16


def test_plan_remesh_insufficient():
    with pytest.raises(RuntimeError):
        plan_remesh(8, model_parallel=16)


def test_rescale_batch_preserves_global():
    r = rescale_batch(256, old_data=16, new_data=15)
    assert r["per_replica"] * 15 >= 256
    assert r["pad"] == r["padded_global"] - 256
    assert 0 < r["grad_scale"] <= 1.0


def test_elastic_failure_promotes_spares():
    st = ElasticState(model_parallel=4,
                      spares=[f"s{i}" for i in range(4)],
                      active=[f"w{i}" for i in range(16)])
    plan = st.on_failure(["w3", "w7"])
    # 14 alive + spares promoted to keep multiples of model_parallel
    assert len(st.active) % 4 == 0
    assert plan.model == 4
    assert plan.data == len(st.active) // 4


def test_elastic_straggler_replacement():
    st = ElasticState(model_parallel=2, spares=["s0"],
                      active=["w0", "w1", "w2", "w3"])
    plan = st.on_straggler("w2")
    assert "w2" not in st.active
    assert "s0" in st.active
    assert plan.shape == (2, 2)


# ---------------------------------------------------------------- heartbeat

def test_heartbeat_detects_timeout():
    clock = [0.0]
    hb = HeartbeatMonitor(["a", "b"], timeout_s=10.0,
                          clock=lambda: clock[0])
    clock[0] = 5.0
    hb.beat("a")
    clock[0] = 12.0
    dead = hb.check()
    assert dead == ["b"]
    assert hb.alive == ["a"]


def test_straggler_policy_flags_slow_worker():
    sp = StragglerPolicy(threshold=1.5, window=8, min_samples=4)
    for _ in range(6):
        for w in ("a", "b", "c", "d"):
            sp.record(w, 1.0)
        sp.record("slow", 2.5)
    assert sp.stragglers() == ["slow"]


def test_straggler_policy_no_false_positive_on_uniform():
    sp = StragglerPolicy()
    for _ in range(6):
        for w in ("a", "b", "c"):
            sp.record(w, 1.0 + 0.01 * hash(w) % 3 / 100)
    assert sp.stragglers() == []


# ---------------------------------------------------------------- trainer

def test_trainer_end_to_end_with_restart(tmp_path):
    """Loss decreases; checkpoint restart resumes exactly."""
    from repro.configs import get_config, scaled_down
    from repro.core import ABFTConfig, Scheme
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.train import OptConfig, TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    tcfg = TrainConfig(opt=OptConfig(lr=5e-3, name="adamw"))
    dcfg = DataConfig(global_batch=4, seq_len=32, vocab_size=cfg.vocab_size)
    rcfg = TrainerConfig(steps=12, ckpt_every=5, log_every=100,
                         ckpt_dir=str(tmp_path))
    abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)

    tr = Trainer(model, params, tcfg, dcfg, rcfg, abft=abft)
    hist = tr.run()
    assert len(hist) == 12
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first  # learning on synthetic data

    # simulate crash + restart: new trainer restores from checkpoint
    tr2 = Trainer(model, params, tcfg, dcfg, rcfg, abft=abft)
    assert tr2.maybe_restore()
    assert tr2.step == 10  # latest checkpoint cadence multiple
    tr2.run()
    assert tr2.step == 12


def test_trainer_elastic_failure_hook(tmp_path):
    from repro.configs import get_config, scaled_down
    from repro.core import ABFTConfig
    from repro.data.pipeline import DataConfig
    from repro.models import build_model
    from repro.train import OptConfig, TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = scaled_down(get_config("llama3.2-1b"), n_layers=1)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    tr = Trainer(
        model, params,
        TrainConfig(opt=OptConfig(lr=1e-3)),
        DataConfig(global_batch=2, seq_len=16, vocab_size=cfg.vocab_size),
        TrainerConfig(steps=4, ckpt_every=2, ckpt_dir=str(tmp_path)),
        abft=ABFTConfig.off(),
        workers=[f"w{i}" for i in range(8)], spares=["s0", "s1"],
    )

    def kill_w3(trainer):
        plan = trainer.on_worker_failure(["w3"])
        assert plan.data * plan.model <= 8 + 1

    tr.run(simulate={2: kill_w3})
    kinds = [e[0] for e in tr.events]
    assert "remesh" in kinds
