"""Dependency-free metrics registry: Counter / Gauge / Histogram with
label sets, bounded cardinality, and two export surfaces — a JSON
``snapshot()`` (the benchmark/CI artifact format) and Prometheus
text-exposition rendering (``render_prometheus()``) for scrape-style
consumption.

Design constraints (why this is hand-rolled instead of a client lib):

* the container pins its dependency set — no ``prometheus_client`` —
  and the serving engine's per-step hot path cannot afford one anyway;
* counters support ``inc_to(value)``: a *monotonic set* used to mirror
  an upstream cumulative counter (``EngineStats``) into the registry
  without instrumenting every increment site — the engine syncs once
  per step and the exported counter is exact by construction;
* label cardinality is bounded per metric (``max_series``, default
  64): a runaway label value (per-request uid, say) raises
  ``CardinalityError`` instead of silently growing an unbounded series
  map inside a long-lived serving process.

Bucket boundaries for the serving latency histograms live here as
explicit module constants so the engine, the launch driver, and the
benchmark all agree on the exposition schema:

* ``TTFT_BUCKETS_S``   — time-to-first-token (admission + prefill);
* ``ITL_BUCKETS_S``    — inter-token latency (decode cadence);
* ``STEP_LATENCY_BUCKETS_S`` — engine step wall time.
"""

from __future__ import annotations

import json
import math
import re

# seconds; chosen to straddle both CPU-container smoke runs (ms-scale
# dispatch-dominated steps) and real-TPU serving (sub-ms decode steps)
STEP_LATENCY_BUCKETS_S = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5)
# TTFT includes prefill, so the tail extends further
TTFT_BUCKETS_S = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0, 10.0)
# ITL is one decode step plus queueing; same floor, shorter tail
ITL_BUCKETS_S = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


class CardinalityError(ValueError):
    """A metric exceeded its bounded label-set budget."""


class RegistrationError(ValueError):
    """Conflicting re-registration (same name, different type/labels)."""


def _escape_label_value(v: str) -> str:
    return (v.replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers render bare, +Inf as
    ``+Inf``."""
    if v == math.inf:
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(float(v)) if isinstance(v, float) else str(v)


class _Metric:
    """Shared series bookkeeping: a metric with label names is a family
    whose children are keyed by the label-value tuple; a label-less
    metric is its own single child (empty tuple key)."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: tuple = (), max_series: int = 64):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in label_names:
            if not _LABEL_RE.match(ln) or ln == "le":
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self.max_series = max_series
        self._children: dict = {}
        if not self.label_names:
            self._children[()] = self._new_child()

    def _new_child(self):
        raise NotImplementedError

    def labels(self, **kv):
        """The child series for this label-value set (created on first
        use, up to ``max_series``)."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.label_names)}")
        key = tuple(str(kv[ln]) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            if len(self._children) >= self.max_series:
                raise CardinalityError(
                    f"{self.name}: series cap {self.max_series} "
                    f"exceeded by labels {dict(zip(self.label_names, key))}")
            child = self._children[key] = self._new_child()
        return child

    def remove(self, **kv) -> None:
        """Drop one labeled series (e.g. a removed heartbeat worker)."""
        key = tuple(str(kv[ln]) for ln in self.label_names)
        self._children.pop(key, None)

    def _default(self):
        """The single child of a label-less metric."""
        if self.label_names:
            raise ValueError(
                f"{self.name} has labels {self.label_names}; use "
                f".labels(...)")
        return self._children[()]

    def series(self):
        for key, child in self._children.items():
            yield dict(zip(self.label_names, key)), child


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def inc_to(self, v: float) -> None:
        """Monotonic set: mirror an upstream cumulative counter."""
        if v < self.value:
            raise ValueError(
                f"inc_to({v}) would decrease counter from {self.value}")
        self.value = v


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def inc_to(self, v: float) -> None:
        self._default().inc_to(v)

    @property
    def value(self) -> float:
        return self._default().value


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._default().set(v)

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    @property
    def value(self) -> float:
        return self._default().value


class _HistogramChild:
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds):
        self.bounds = bounds             # finite, sorted; +Inf implicit
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1             # +Inf bucket

    def cumulative(self):
        """[(le, cumulative_count)] including +Inf; the exposition and
        snapshot invariant is that the +Inf count equals ``count``."""
        out, running = [], 0
        for b, c in zip(self.bounds, self.counts):
            running += c
            out.append((b, running))
        out.append((math.inf, running + self.counts[-1]))
        return out


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help="", label_names=(), max_series=64,
                 buckets=STEP_LATENCY_BUCKETS_S):
        bounds = tuple(float(b) for b in buckets if b != math.inf)
        if not bounds or sorted(bounds) != list(bounds):
            raise ValueError(
                f"{name}: bucket bounds must be non-empty and sorted, "
                f"got {buckets}")
        self.bounds = bounds
        super().__init__(name, help, label_names, max_series)

    def _new_child(self):
        return _HistogramChild(self.bounds)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def count(self) -> int:
        return self._default().count

    @property
    def sum(self) -> float:
        return self._default().sum


class MetricsRegistry:
    """Named metric families; registration is idempotent for an
    identical spec and raises ``RegistrationError`` on conflicts."""

    def __init__(self):
        self._metrics: dict = {}

    def _get_or_register(self, cls, name, help, labels, max_series,
                         **extra):
        existing = self._metrics.get(name)
        if existing is not None:
            same = (type(existing) is cls
                    and existing.label_names == tuple(labels))
            if same and cls is Histogram:
                same = existing.bounds == tuple(
                    float(b) for b in extra["buckets"] if b != math.inf)
            if not same:
                raise RegistrationError(
                    f"{name} already registered as {existing.kind} "
                    f"with labels {existing.label_names}")
            return existing
        m = cls(name, help, tuple(labels), max_series, **extra)
        self._metrics[name] = m
        return m

    def counter(self, name, help="", labels=(),
                max_series=64) -> Counter:
        return self._get_or_register(Counter, name, help, labels,
                                     max_series)

    def gauge(self, name, help="", labels=(), max_series=64) -> Gauge:
        return self._get_or_register(Gauge, name, help, labels,
                                     max_series)

    def histogram(self, name, help="", labels=(), max_series=64,
                  buckets=STEP_LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_register(Histogram, name, help, labels,
                                     max_series, buckets=buckets)

    def get(self, name):
        return self._metrics.get(name)

    def names(self) -> list:
        return sorted(self._metrics)

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """JSON-ready registry state: the benchmark/CI artifact format
        (``check_telemetry_schema.py`` validates its invariants)."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = []
            for labels, child in m.series():
                if m.kind == "histogram":
                    series.append({
                        "labels": labels,
                        "buckets": [["+Inf" if le == math.inf else le, c]
                                    for le, c in child.cumulative()],
                        "sum": child.sum,
                        "count": child.count,
                    })
                else:
                    series.append({"labels": labels,
                                   "value": child.value})
            out[name] = {"type": m.kind, "help": m.help,
                         "series": series}
        return out

    def to_json(self, indent=2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4: ``# HELP`` /
        ``# TYPE`` headers, escaped label values, and per-histogram
        ``_bucket``/``_sum``/``_count`` sample families."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {name} {m.kind}")
            for labels, child in m.series():
                base = ",".join(
                    f'{k}="{_escape_label_value(v)}"'
                    for k, v in labels.items())
                if m.kind == "histogram":
                    for le, c in child.cumulative():
                        lab = (base + "," if base else "") + \
                            f'le="{_fmt(float(le))}"'
                        lines.append(f"{name}_bucket{{{lab}}} {c}")
                    brace = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{brace} {_fmt(child.sum)}")
                    lines.append(
                        f"{name}_count{brace} {child.count}")
                else:
                    brace = f"{{{base}}}" if base else ""
                    lines.append(f"{name}{brace} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"
