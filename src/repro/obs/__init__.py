"""Serving observability: metrics registry (Prometheus/JSON export),
structured span tracing (Chrome-trace/Perfetto JSON), and the
fault-rate monitor feeding adaptive protection (ROADMAP item 5b)."""

from repro.obs.faultrate import FaultRateMonitor
from repro.obs.metrics import (
    ITL_BUCKETS_S,
    STEP_LATENCY_BUCKETS_S,
    TTFT_BUCKETS_S,
    CardinalityError,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RegistrationError,
)
from repro.obs.telemetry import ENGINE_COUNTERS, EngineTelemetry
from repro.obs.trace import Tracer, check_events

__all__ = [
    "CardinalityError",
    "Counter",
    "ENGINE_COUNTERS",
    "EngineTelemetry",
    "FaultRateMonitor",
    "Gauge",
    "Histogram",
    "ITL_BUCKETS_S",
    "MetricsRegistry",
    "RegistrationError",
    "STEP_LATENCY_BUCKETS_S",
    "TTFT_BUCKETS_S",
    "Tracer",
    "check_events",
]
