"""Rolling-window fault-rate monitor: the engine-observed error
environment as a policy input.

The paper picks an ABFT scheme from *static* arithmetic intensity; the
adaptive follow-on (ROADMAP item 5b, "Adaptive Soft Error Protection",
arxiv 2407.19664) needs the engine's *observed* detection/retry/hard-
fault rates as its second input — protection strength should scale with
the measured error environment (spacecraft mode vs datacenter mode)
instead of being fixed at plan-compile time.  ``FaultRateMonitor`` is
that input surface: the serving engine feeds it one observation per
executed step (and per admission prefill), and ``snapshot()`` exposes

* **windowed rates** over the last ``window`` observations — detections,
  retries, and hard faults per step and per generated token (the
  responsive signal an adaptive policy reacts to);
* **EWMA rates** (per observation, smoothing factor ``alpha``) — the
  slow-moving baseline that survives a quiet window;
* **lifetime totals** — the audit trail.

Observations arrive as *deltas* (the telemetry sync computes them from
the cumulative ``EngineStats``), so the monitor needs no knowledge of
engine internals and is trivially reusable by the trainer or a
cluster-level aggregator.
"""

from __future__ import annotations

from collections import deque


class FaultRateMonitor:
    def __init__(self, window: int = 256, alpha: float = 0.05):
        if window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.window = window
        self.alpha = alpha
        self._obs: deque = deque(maxlen=window)
        # lifetime totals
        self.steps = 0
        self.tokens = 0
        self.detections = 0
        self.retries = 0
        self.hard_faults = 0
        # EWMA per observation (≈ per engine step)
        self.ewma_detections = 0.0
        self.ewma_retries = 0.0
        self.ewma_hard_faults = 0.0
        self.observations = 0

    def observe(self, *, steps: int = 1, tokens: int = 0,
                detections: int = 0, retries: int = 0,
                hard_faults: int = 0) -> None:
        """One engine observation (deltas since the previous one)."""
        self._obs.append((steps, tokens, detections, retries,
                          hard_faults))
        self.steps += steps
        self.tokens += tokens
        self.detections += detections
        self.retries += retries
        self.hard_faults += hard_faults
        a = self.alpha
        self.ewma_detections += a * (detections - self.ewma_detections)
        self.ewma_retries += a * (retries - self.ewma_retries)
        self.ewma_hard_faults += a * (hard_faults - self.ewma_hard_faults)
        self.observations += 1

    def reset(self) -> None:
        """Re-baseline the responsive signals: clear the rolling window
        and the EWMA state, KEEPING the lifetime totals (the audit
        trail).  The adaptive policy calls this after an escalation so
        the post-escalation regime is judged on fresh observations
        instead of the pre-escalation window."""
        self._obs.clear()
        self.ewma_detections = 0.0
        self.ewma_retries = 0.0
        self.ewma_hard_faults = 0.0
        self.observations = 0

    # ------------------------------------------------------ windowed rates
    def _window_sums(self):
        s = t = d = r = h = 0
        for steps, tokens, det, ret, hard in self._obs:
            s += steps
            t += tokens
            d += det
            r += ret
            h += hard
        return s, t, d, r, h

    @property
    def window_detection_rate(self) -> float:
        """Detections per step over the rolling window."""
        s, _, d, _, _ = self._window_sums()
        return d / max(s, 1)

    @property
    def window_detection_rate_per_token(self) -> float:
        _, t, d, _, _ = self._window_sums()
        return d / max(t, 1)

    @property
    def window_retry_rate(self) -> float:
        s, _, _, r, _ = self._window_sums()
        return r / max(s, 1)

    @property
    def window_hard_fault_rate(self) -> float:
        s, _, _, _, h = self._window_sums()
        return h / max(s, 1)

    def snapshot(self) -> dict:
        """The adaptive-policy input surface (JSON-ready)."""
        s, t, d, r, h = self._window_sums()
        return {
            "window": self.window,
            "window_filled": len(self._obs),
            "window_steps": s,
            "window_tokens": t,
            "window_detections": d,
            "window_retries": r,
            "window_hard_faults": h,
            "window_detection_rate": self.window_detection_rate,
            "window_detection_rate_per_token":
                self.window_detection_rate_per_token,
            "window_retry_rate": self.window_retry_rate,
            "window_hard_fault_rate": self.window_hard_fault_rate,
            "ewma_alpha": self.alpha,
            "ewma_detections_per_step": self.ewma_detections,
            "ewma_retries_per_step": self.ewma_retries,
            "ewma_hard_faults_per_step": self.ewma_hard_faults,
            "total_steps": self.steps,
            "total_tokens": self.tokens,
            "total_detections": self.detections,
            "total_retries": self.retries,
            "total_hard_faults": self.hard_faults,
        }
