"""Engine telemetry facade: one object bundling the metrics registry,
the span tracer, and the fault-rate monitor, with a single ``sync()``
point that mirrors the engine's cumulative ``EngineStats`` into
exported counters.

Mirroring via ``Counter.inc_to`` (monotonic set) instead of per-site
increments is the invariant that makes the acceptance check cheap to
hold: the exported counter equals the ``EngineStats`` field *by
construction* after every sync, so no instrumentation site can drift
out of agreement with the engine's own accounting (and existing tests
asserting on ``EngineStats`` stay authoritative).  The same sync
computes per-step deltas and feeds them to the ``FaultRateMonitor`` —
the rolling detection/retry/hard-fault rates ROADMAP item 5b's
adaptive protection policy consumes via ``ServeEngine.telemetry``.

The facade is duck-typed against ``EngineStats`` (attribute names
only), so ``repro.obs`` has no import edge into ``repro.serve`` and
stays reusable by the trainer, the heartbeat monitor, and benchmarks.
"""

from __future__ import annotations

from repro.obs.faultrate import FaultRateMonitor
from repro.obs.metrics import (
    ITL_BUCKETS_S,
    STEP_LATENCY_BUCKETS_S,
    TTFT_BUCKETS_S,
    MetricsRegistry,
)
from repro.obs.trace import Tracer

# exported counter name -> EngineStats attribute.  The telemetry
# acceptance gate (tests + check_telemetry_schema.py) asserts exact
# equality across this whole mapping after a run.
ENGINE_COUNTERS = {
    "serve_steps_total": "steps",
    "serve_tokens_total": "tokens",
    "abft_faults_detected_total": "faults_detected",
    "abft_retries_total": "retries",
    "abft_hard_faults_total": "hard_faults",
    "serve_evictions_total": "evictions",
    "serve_rejections_total": "rejections",
    "serve_prompt_tokens_total": "prompt_tokens_total",
    "serve_prefix_tokens_shared_total": "prefix_tokens_shared",
    "serve_cow_copies_total": "cow_copies",
    "serve_prefill_chunks_total": "prefill_chunks",
    "serve_chunk_retries_total": "chunk_retries",
    "serve_chunk_budget_retunes_total": "chunk_budget_retunes",
    "serve_scheme_flips_total": "scheme_flips",
    # speculative decoding (serve/spec_decode.py)
    "serve_spec_draft_proposed_total": "draft_proposed",
    "serve_spec_draft_accepted_total": "draft_accepted",
    "serve_spec_verify_retries_total": "verify_retries",
    # fault-campaign classification (shadow-stream harness) + adaptive
    # protection level changes — SDCs are first-class exported counters
    "abft_faults_injected_total": "faults_injected",
    "abft_faults_corrected_total": "faults_corrected",
    "abft_faults_uncorrected_total": "faults_uncorrected",
    "abft_sdc_total": "sdc_faults",
    "abft_masked_faults_total": "masked_faults",
    "serve_protection_escalations_total": "protection_escalations",
    "serve_protection_deescalations_total": "protection_deescalations",
}

# deltas of these stats feed the fault-rate monitor each sync
_FAULT_DELTAS = ("steps", "tokens", "faults_detected", "retries",
                 "hard_faults")


class EngineTelemetry:
    """``ServeEngine(telemetry=EngineTelemetry(...))`` — or build one
    standalone and attach with ``engine.attach_telemetry``."""

    def __init__(self, *, trace: bool = False,
                 registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 fault_window: int = 256, fault_alpha: float = 0.05,
                 trace_max_events: int = 200_000, trace_sink=None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(
            enabled=trace, max_events=trace_max_events, sink=trace_sink)
        self.faults = FaultRateMonitor(window=fault_window,
                                       alpha=fault_alpha)
        r = self.registry
        self._counters = {
            name: r.counter(name, f"engine cumulative {attr}")
            for name, attr in ENGINE_COUNTERS.items()
        }
        self._g_active = r.gauge("serve_active_slots",
                                 "slots with a resident decode stream")
        self._g_cursors = r.gauge("serve_prefill_cursors",
                                  "prompts parked mid-chunked-prefill")
        self._g_blocks_used = r.gauge("serve_blocks_used",
                                      "paged KV blocks allocated")
        self._g_blocks_free = r.gauge("serve_blocks_free",
                                      "paged KV blocks on the free list")
        self._g_chunk_budget = r.gauge(
            "serve_chunk_budget_tokens",
            "current chunked-prefill step token budget")
        self._g_draft_len = r.gauge(
            "serve_spec_draft_len",
            "current speculative-decoding draft length K")
        self._g_accept_rate = r.gauge(
            "serve_spec_accept_rate",
            "cumulative draft acceptance rate "
            "(draft_accepted / draft_proposed)")
        self._g_det_win = r.gauge(
            "abft_detection_rate_window",
            "windowed ABFT detections per step (FaultRateMonitor)")
        self._g_det_tok = r.gauge(
            "abft_detection_rate_per_token_window",
            "windowed ABFT detections per generated token")
        self._g_retry_win = r.gauge(
            "abft_retry_rate_window", "windowed ABFT retries per step")
        self._g_hard_win = r.gauge(
            "abft_hard_fault_rate_window",
            "windowed hard faults per step")
        self._g_det_ewma = r.gauge(
            "abft_detection_rate_ewma",
            "EWMA ABFT detections per step")
        self.step_latency = r.histogram(
            "serve_step_latency_seconds", "engine step wall time",
            buckets=STEP_LATENCY_BUCKETS_S)
        self.ttft = r.histogram(
            "serve_ttft_seconds",
            "time to first token (observed by the driver)",
            buckets=TTFT_BUCKETS_S)
        self.itl = r.histogram(
            "serve_itl_seconds",
            "inter-token latency (observed by the driver)",
            buckets=ITL_BUCKETS_S)
        self._prev = {attr: 0 for attr in _FAULT_DELTAS}

    # ------------------------------------------------------------ syncing
    def sync(self, stats, *, active_slots: int | None = None,
             prefill_cursors: int | None = None,
             blocks_used: int | None = None,
             blocks_free: int | None = None,
             chunk_budget: int | None = None,
             draft_len: int | None = None) -> None:
        """Mirror cumulative ``EngineStats`` into the registry and feed
        the delta since the last sync to the fault-rate monitor.  Called
        by the engine after every ``step()``/``admit()``."""
        for name, attr in ENGINE_COUNTERS.items():
            self._counters[name].inc_to(getattr(stats, attr))
        deltas = {}
        for attr in _FAULT_DELTAS:
            cur = getattr(stats, attr)
            deltas[attr] = cur - self._prev[attr]
            self._prev[attr] = cur
        if any(deltas.values()):
            self.faults.observe(
                steps=deltas["steps"], tokens=deltas["tokens"],
                detections=deltas["faults_detected"],
                retries=deltas["retries"],
                hard_faults=deltas["hard_faults"])
            self._g_det_win.set(self.faults.window_detection_rate)
            self._g_det_tok.set(
                self.faults.window_detection_rate_per_token)
            self._g_retry_win.set(self.faults.window_retry_rate)
            self._g_hard_win.set(self.faults.window_hard_fault_rate)
            self._g_det_ewma.set(self.faults.ewma_detections)
        if active_slots is not None:
            self._g_active.set(active_slots)
        if prefill_cursors is not None:
            self._g_cursors.set(prefill_cursors)
        if blocks_used is not None:
            self._g_blocks_used.set(blocks_used)
        if blocks_free is not None:
            self._g_blocks_free.set(blocks_free)
        if chunk_budget is not None:
            self._g_chunk_budget.set(chunk_budget)
        if draft_len is not None:
            self._g_draft_len.set(draft_len)
            if stats.draft_proposed:
                self._g_accept_rate.set(
                    stats.draft_accepted / stats.draft_proposed)

    def counters_match(self, stats) -> bool:
        """True iff every mirrored counter equals its EngineStats field
        (the telemetry acceptance invariant)."""
        return all(
            self._counters[name].value == getattr(stats, attr)
            for name, attr in ENGINE_COUNTERS.items())

    # ------------------------------------------------- driver observations
    def observe_step_latency(self, seconds: float) -> None:
        self.step_latency.observe(seconds)

    def observe_ttft(self, seconds: float) -> None:
        self.ttft.observe(seconds)

    def observe_itl(self, seconds: float) -> None:
        self.itl.observe(seconds)

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """One JSON-ready artifact: metrics + fault-rate surface + trace
        accounting (the per-cell benchmark telemetry payload)."""
        return {
            "schema_version": 1,
            "metrics": self.registry.snapshot(),
            "faultrate": self.faults.snapshot(),
            "trace": {
                "enabled": self.tracer.enabled,
                "events": len(self.tracer.events),
                "dropped": self.tracer.dropped,
            },
        }
