"""Structured span/event tracer emitting Chrome-trace / Perfetto
compatible JSON (the ``traceEvents`` array format: complete events
``ph="X"`` with microsecond ``ts``/``dur``, instant events ``ph="i"``).

Spans use the monotonic clock (``time.perf_counter_ns``) so a wall-clock
adjustment mid-run can never produce negative durations.  JAX dispatch
is asynchronous — a jitted call returns before the device work finishes
— so a span that should *contain* device work must fence on its outputs
before closing:

    with tracer.span("decode_step", {"tokens": n}) as sp:
        out, cache, flag, keys = jitted_step(...)
        sp.fence(out, flag)          # block_until_ready at span exit

Fencing happens only when the tracer is enabled; a disabled tracer hands
out a shared no-op span, so instrumented hot paths cost one attribute
check when tracing is off and the engine's token streams are
byte-identical either way (fencing orders host timestamps, never
values).

Event volume is bounded (``max_events``): once full, new events are
counted in ``dropped`` instead of growing an unbounded list inside a
long-lived serving process.  An optional ``sink`` callback receives each
event dict as it is recorded — the launch driver's ``--log-events``
structured logging hook.

``check_events()`` validates the invariants tests and the CI telemetry
schema gate rely on: known phases, non-negative ts/dur, and proper span
nesting per (pid, tid) — two spans on one thread either nest or are
disjoint, which is exactly what Perfetto's JSON importer assumes when it
builds slice stacks.
"""

from __future__ import annotations

import json
import time


class _NullSpan:
    """Shared do-nothing span for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, *values):
        pass

    def set_args(self, **kv):
        pass


_NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("tracer", "name", "args", "_t0", "_fence")

    def __init__(self, tracer, name, args):
        self.tracer = tracer
        self.name = name
        self.args = dict(args) if args else {}
        self._t0 = None
        self._fence = ()

    def fence(self, *values):
        """Values to ``jax.block_until_ready`` before the span closes,
        attributing their device work to this span."""
        self._fence = values

    def set_args(self, **kv):
        self.args.update(kv)

    def __enter__(self):
        self._t0 = self.tracer._now_us()
        return self

    def __exit__(self, *exc):
        if self._fence:
            # local import: obs stays importable without jax (metrics/
            # faultrate are pure-stdlib); fencing is only reachable from
            # engine code that already runs under jax
            import jax

            jax.block_until_ready(self._fence)
        t1 = self.tracer._now_us()
        self.tracer._emit({
            "name": self.name, "ph": "X", "ts": self._t0,
            "dur": max(0.0, t1 - self._t0), "pid": self.tracer.pid,
            "tid": self.tracer.tid, "args": self.args,
        })
        return False


class Tracer:
    def __init__(self, enabled: bool = True, max_events: int = 200_000,
                 pid: int = 0, tid: int = 0, sink=None,
                 clock=time.perf_counter_ns):
        self.enabled = enabled
        self.max_events = max_events
        self.pid = pid
        self.tid = tid
        self.sink = sink
        self._clock = clock
        self._origin = clock()
        self.events: list = []
        self.dropped = 0

    def _now_us(self) -> float:
        return (self._clock() - self._origin) / 1e3

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
        else:
            self.events.append(ev)
        if self.sink is not None:
            self.sink(ev)

    def span(self, name: str, args: dict | None = None):
        """Context manager recording a complete event around its body."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, args)

    def instant(self, name: str, args: dict | None = None) -> None:
        """Thread-scoped instant event (scheme flips, evictions, fault
        detections)."""
        if not self.enabled:
            return
        self._emit({
            "name": name, "ph": "i", "ts": self._now_us(), "s": "t",
            "pid": self.pid, "tid": self.tid,
            "args": dict(args) if args else {},
        })

    # ------------------------------------------------------------ export
    def to_dict(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def to_json(self, indent=None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())


def check_events(events: list) -> list:
    """Validate Perfetto-JSON invariants; returns a list of problem
    strings (empty == valid).  Checked: required fields per phase,
    non-negative ``ts``/``dur``, and per-(pid, tid) span nesting."""
    problems = []
    spans = []
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"event {i}: missing name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
                continue
            spans.append((ev.get("pid", 0), ev.get("tid", 0),
                          float(ts), float(ts) + float(dur),
                          ev.get("name"), i))
    # nesting: per (pid, tid), sweep spans by (start, -end); each span
    # must close before or exactly at its enclosing span's end
    by_thread: dict = {}
    for pid, tid, t0, t1, name, i in spans:
        by_thread.setdefault((pid, tid), []).append((t0, t1, name, i))
    for key, sp in by_thread.items():
        sp.sort(key=lambda s: (s[0], -s[1]))
        stack: list = []
        for t0, t1, name, i in sp:
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            if stack and t1 > stack[-1][1]:
                problems.append(
                    f"event {i} ({name!r}): span [{t0}, {t1}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]}, {stack[-1][1]}] on tid {key}")
                continue
            stack.append((t0, t1, name))
    return problems
