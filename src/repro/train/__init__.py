"""Training substrate: optimizers, schedules, train step, trainer loop."""

from repro.train.optimizer import (
    AdamWState,
    OptConfig,
    init_opt_state,
    lr_schedule,
    update,
)
from repro.train.train_step import TrainConfig, make_loss_fn, make_train_step

__all__ = [
    "AdamWState",
    "OptConfig",
    "TrainConfig",
    "init_opt_state",
    "lr_schedule",
    "make_loss_fn",
    "make_train_step",
    "update",
]
