"""Optimizers built from scratch in JAX: AdamW (bf16-moment option for
>=100B configs), SGD-momentum, global-norm clipping, and int8 gradient
compression with error feedback (distributed-optimization trick: compressed
DP all-reduce payloads; the residual buffer keeps the update unbiased).

Optimizer state is a plain pytree so the ZeRO-1 sharding rules in
distributed/sharding.py apply directly (moments sharded over 'data').
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" for >=100B (memory)
    compress_grads: bool = False      # int8 + error feedback


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object
    err: object      # error-feedback residuals (zeros when compression off)


def _zeros_like(params, dtype):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype), params)


def init_opt_state(params, cfg: OptConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=_zeros_like(params, mdt),
        nu=_zeros_like(params, mdt),
        err=(
            _zeros_like(params, jnp.bfloat16)
            if cfg.compress_grads
            else jax.tree_util.tree_map(lambda p: jnp.zeros((), F32), params)
        ),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


# ------------------------------------------------------- gradient compression

def compress_int8(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g.astype(F32))), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(F32) * scale


def compress_with_feedback(g, err):
    """Error-feedback compression: quantize (g + residual), carry the
    quantization error to the next step (Seide et al. / EF-SGD)."""
    gf = g.astype(F32) + err.astype(F32)
    q, scale = compress_int8(gf)
    deq = decompress_int8(q, scale)
    new_err = (gf - deq).astype(err.dtype)
    return deq.astype(g.dtype), new_err


# ------------------------------------------------------- adamw

def adamw_update(grads, state: AdamWState, params, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if cfg.compress_grads:
        pairs = jax.tree_util.tree_map(
            compress_with_feedback, grads, state.err)
        grads = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(lambda pr: pr[1], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.err

    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(F32)
        m_new = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * gf * gf
        m_hat = m_new / b1c
        v_hat = v_new / b2c
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        p_new = p.astype(F32) - cfg.lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu, err=new_err)
    return new_params, new_state, {"grad_norm": gnorm}


def sgd_update(grads, state: AdamWState, params, cfg: OptConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1

    def upd(p, g, m):
        m_new = cfg.b1 * m.astype(F32) + g.astype(F32)
        p_new = p.astype(F32) - cfg.lr * m_new
        return p_new.astype(p.dtype), m_new.astype(m.dtype)

    out = jax.tree_util.tree_map(upd, params, grads, state.mu)
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, state._replace(step=step, mu=new_mu), {
        "grad_norm": gnorm}


def update(grads, state, params, cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_update(grads, state, params, cfg)
    if cfg.name == "sgd":
        return sgd_update(grads, state, params, cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def lr_schedule(step, base_lr: float, warmup: int = 100,
                total: int = 10000, min_ratio: float = 0.1):
    """Linear warmup + cosine decay."""
    s = step.astype(F32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)
