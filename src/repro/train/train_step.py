"""Train-step construction: CE loss (+ MoE aux + MTP), microbatched gradient
accumulation, ABFT flag aggregation, optimizer update.

The returned step function is pjit-ready: pure, params/opt-state in-out,
metrics as scalars.  The ABFT flag of the *forward* pass is surfaced in the
metrics — the trainer (train/trainer.py) re-executes the step when a fault
was detected (detect -> retry recovery, paper §1's detection goal plus a
recovery policy at the framework level).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model
from repro.train import optimizer as opt_lib

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_lib.OptConfig = opt_lib.OptConfig()
    aux_loss_coef: float = 0.01
    mtp_loss_coef: float = 0.3
    z_loss_coef: float = 1e-4
    microbatches: int = 1        # gradient accumulation steps


def make_loss_fn(model: Model, abft: ABFTConfig,
                 tcfg: TrainConfig, hints=None) -> Callable:
    def loss_fn(params, batch, fault=None):
        ctx = LayerCtx(abft=abft, fault=fault, hints=hints)
        out = model.forward(params, batch, ctx)
        logits = out.logits.astype(F32)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        logp = jnp.take_along_axis(
            logits, labels[..., None], axis=-1)[..., 0] - logz
        mask = (labels >= 0).astype(F32)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        nll = -jnp.sum(logp * mask) / denom
        loss = nll
        loss = loss + tcfg.z_loss_coef * jnp.sum(
            (logz ** 2) * mask) / denom
        loss = loss + tcfg.aux_loss_coef * out.aux_loss
        if out.mtp_logits is not None:
            # predict token t+2: labels shifted one more step
            l2 = jnp.roll(labels, -1, axis=1)
            m2 = mask * jnp.roll(mask, -1, axis=1)
            lg2 = out.mtp_logits.astype(F32)
            lp2 = jnp.take_along_axis(
                jax.nn.log_softmax(lg2, -1), l2[..., None], -1)[..., 0]
            loss = loss - tcfg.mtp_loss_coef * jnp.sum(lp2 * m2) / denom
        metrics = {
            "loss": nll,
            "aux_loss": out.aux_loss,
            "abft_flag": out.flag,
        }
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, abft: ABFTConfig,
                    tcfg: TrainConfig, hints=None) -> Callable:
    """Returns step(params, opt_state, batch, fault=None) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, abft, tcfg, hints=hints)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch, fault):
        (loss, metrics), grads = grad_fn(params, batch, fault)
        return loss, metrics, grads

    def step(params, opt_state, batch, fault=None):
        if fault is None:
            fault = ModelFault.none()
        if tcfg.microbatches > 1:
            # gradient accumulation: split the batch on the leading dim
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape((mb, b // mb) + x.shape[1:])

            mb_batch = {k: split(v) for k, v in batch.items()}

            def acc_step(carry, mb):
                g_acc, l_acc, f_acc = carry
                loss, metrics, grads = single(params, mb, fault)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(F32), g_acc, grads)
                return (g_acc, l_acc + loss,
                        jnp.logical_or(f_acc, metrics["abft_flag"])), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss_sum, flag), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), F32), jnp.zeros((), bool)),
                mb_batch)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
            metrics = {"loss": loss, "abft_flag": flag,
                       "aux_loss": jnp.zeros((), F32)}
        else:
            loss, metrics, grads = single(params, batch, fault)

        new_params, new_opt, opt_metrics = opt_lib.update(
            grads, opt_state, params, tcfg.opt)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return new_params, new_opt, metrics

    return step
