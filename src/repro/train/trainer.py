"""Trainer: the fault-tolerant training loop.

Integrates every FT mechanism in the framework:
  * ABFT forward protection — a flagged step is retried (detect->recompute)
    before the optimizer consumes the gradients;
  * async sharded checkpointing on a cadence, checksummed at rest;
  * heartbeat failure detection + elastic re-mesh + reshard-restore;
  * straggler demotion with hot-spare promotion;
  * deterministic, restart-safe data (step index is the only data state).

On this container the loop runs single-host; the failure/straggler paths
are exercised by tests through the simulation hooks (``simulate``).
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.checkpoint.checkpoint import Checkpointer
from repro.core.protected import ABFTConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import Model
from repro.runtime.elastic import ElasticState
from repro.runtime.heartbeat import HeartbeatMonitor, StragglerPolicy
from repro.train.optimizer import init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    retry_on_abft_flag: bool = True
    max_retries: int = 2


class Trainer:
    def __init__(self, model: Model, params, tcfg: TrainConfig,
                 dcfg: DataConfig, rcfg: TrainerConfig,
                 abft: ABFTConfig = ABFTConfig(), hints=None,
                 workers=None, spares=None):
        self.model = model
        self.params = params
        self.tcfg = tcfg
        self.rcfg = rcfg
        self.data = SyntheticLM(dcfg)
        self.opt_state = init_opt_state(params, tcfg.opt)
        self.step_fn = jax.jit(make_train_step(model, abft, tcfg,
                                               hints=hints))
        self.ckpt = Checkpointer(rcfg.ckpt_dir)
        self.step = 0
        self.history: list = []
        # control plane (simulated single-host)
        workers = workers or ["w0"]
        self.heartbeat = HeartbeatMonitor(workers, timeout_s=60.0)
        self.stragglers = StragglerPolicy()
        self.elastic = ElasticState(
            model_parallel=1, spares=list(spares or []),
            active=list(workers))
        self.events: list = []

    # ------------------------------------------------------------ restore
    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored, step = self.ckpt.restore(state)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        self.events.append(("restored", step))
        return True

    # ------------------------------------------------------------ loop
    def run(self, simulate: dict | None = None) -> list:
        """simulate: {step: callable(trainer)} fault-injection hooks."""
        simulate = simulate or {}
        while self.step < self.rcfg.steps:
            if self.step in simulate:
                simulate[self.step](self)
            batch = self.data.batch(self.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.monotonic()
            retries = 0
            while True:
                new_params, new_opt, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                if (not self.rcfg.retry_on_abft_flag
                        or not bool(metrics["abft_flag"])
                        or retries >= self.rcfg.max_retries):
                    break
                retries += 1
                self.events.append(("abft_retry", self.step))
            if bool(metrics["abft_flag"]) and retries >= self.rcfg.max_retries:
                self.events.append(("abft_hard_fault", self.step))
            self.params, self.opt_state = new_params, new_opt
            dt = time.monotonic() - t0
            for w in self.heartbeat.alive:
                self.heartbeat.beat(w)
                self.stragglers.record(w, dt)
            self.history.append(
                {"step": self.step, "loss": float(metrics["loss"]),
                 "time_s": dt, "retries": retries})
            if self.step and self.step % self.rcfg.ckpt_every == 0:
                self.ckpt.save_async(
                    self.step, {"params": self.params, "opt": self.opt_state})
                self.events.append(("checkpoint", self.step))
            self.step += 1
        self.ckpt.wait()
        return self.history

    # ------------------------------------------------- failure simulation
    def on_worker_failure(self, dead: list):
        """Heartbeat-detected failure: re-mesh + restore from checkpoint."""
        plan = self.elastic.on_failure(dead)
        self.events.append(("remesh", tuple(plan.shape)))
        restored = self.maybe_restore()
        if not restored:
            self.events.append(("cold_restart", self.step))
        return plan
