"""Soft-error fault injection (paper §2.3 fault model).

We model a single faulty output value in a GEMM's output matrix: a transient
error in processing logic corrupts one accumulator before it is written
back.  Injection sites:

* ``inject_output_fault`` — post-hoc corruption of a materialized output
  (used on the global-ABFT path and in system tests).
* the Pallas kernels accept a ``FaultSpec`` and corrupt the main accumulator
  *after* the checksum path has consumed the operands, mimicking an MXU
  error invisible to the (independent) VPU checksum data path.

Bit-flips are expressed by XOR on the raw bit pattern, matching neutron-beam
observed upsets; value faults add a chosen delta.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class FaultSpec(NamedTuple):
    """Where/what to inject.  All fields are scalars (static or traced).

    row/col: coordinates in the 2-D GEMM output.
    delta: value added to the output element (value-fault mode).
    bit: if >= 0, flip this bit of the element instead (bit-flip mode).
      Bit indices are dtype-relative to the corrupted buffer: the fused
      block kernel corrupts its f32 accumulator (exponent bits 23-30);
      the global path corrupts the materialized output in its own dtype
      (bf16 exponent bits 8-14).
    enabled: 0/1 master switch so the same jitted graph can run clean.
    """

    row: jnp.ndarray
    col: jnp.ndarray
    delta: jnp.ndarray
    bit: jnp.ndarray
    enabled: jnp.ndarray

    @staticmethod
    def none() -> "FaultSpec":
        z = jnp.zeros((), jnp.int32)
        return FaultSpec(row=z, col=z, delta=jnp.zeros((), jnp.float32),
                         bit=jnp.full((), -1, jnp.int32), enabled=z)

    @staticmethod
    def value(row: int, col: int, delta: float) -> "FaultSpec":
        return FaultSpec(
            row=jnp.asarray(row, jnp.int32),
            col=jnp.asarray(col, jnp.int32),
            delta=jnp.asarray(delta, jnp.float32),
            bit=jnp.full((), -1, jnp.int32),
            enabled=jnp.ones((), jnp.int32),
        )

    @staticmethod
    def bitflip(row: int, col: int, bit: int) -> "FaultSpec":
        return FaultSpec(
            row=jnp.asarray(row, jnp.int32),
            col=jnp.asarray(col, jnp.int32),
            delta=jnp.zeros((), jnp.float32),
            bit=jnp.asarray(bit, jnp.int32),
            enabled=jnp.ones((), jnp.int32),
        )


_UINT_FOR_BYTES = {2: jnp.uint16, 4: jnp.uint32}


def flip_bit(value: jnp.ndarray, bit) -> jnp.ndarray:
    """XOR one bit of each element of ``value`` (same shape)."""
    nbytes = jnp.dtype(value.dtype).itemsize
    uint = _UINT_FOR_BYTES[nbytes]
    raw = jax.lax.bitcast_convert_type(value, uint)
    mask = (jnp.ones((), uint) << bit.astype(uint)).astype(uint)
    return jax.lax.bitcast_convert_type(raw ^ mask, value.dtype)


def inject_output_fault(y: jnp.ndarray, fault: FaultSpec) -> jnp.ndarray:
    """Corrupt one element of a (..., m, n) output per ``fault``."""
    m, n = y.shape[-2], y.shape[-1]
    rows = jnp.arange(m, dtype=jnp.int32)
    cols = jnp.arange(n, dtype=jnp.int32)
    mask = (rows[:, None] == fault.row) & (cols[None, :] == fault.col)
    mask = jnp.broadcast_to(mask, y.shape)
    on = fault.enabled.astype(bool)

    flipped = flip_bit(y, jnp.maximum(fault.bit, 0))
    bit_mode = fault.bit >= 0
    corrupted = jnp.where(
        bit_mode, flipped, y + fault.delta.astype(y.dtype)
    )
    return jnp.where(on & mask, corrupted, y)


def random_fault(rng: np.random.Generator, m: int, n: int,
                 magnitude: float | None = None) -> FaultSpec:
    """Sample a random single-output fault for campaigns: exponent-region
    bit-flip (the catastrophic case) or a value fault of given magnitude."""
    row = int(rng.integers(m))
    col = int(rng.integers(n))
    if magnitude is None:
        # bf16: bits 8..14 are exponent — flips there scale the value by
        # powers of two, the classic soft-error signature.
        bit = int(rng.integers(8, 15))
        return FaultSpec.bitflip(row, col, bit)
    return FaultSpec.value(row, col, magnitude)
