"""Soft-error fault injection (paper §2.3 fault model).

We model a single faulty output value in a GEMM's output matrix: a transient
error in processing logic corrupts one accumulator before it is written
back.  Injection sites:

* ``inject_output_fault`` — post-hoc corruption of a materialized output
  (used on the global-ABFT path and in system tests).
* the Pallas kernels accept a ``FaultSpec`` and corrupt the main accumulator
  *after* the checksum path has consumed the operands, mimicking an MXU
  error invisible to the (independent) VPU checksum data path.

Bit-flips are expressed by XOR on the raw bit pattern, matching neutron-beam
observed upsets; value faults add a chosen delta.

Campaign injection (``FaultModel``) generalizes the one-shot surface to a
fault *process*: Bernoulli-per-step transient faults at a configurable
rate, plus sticky *permanent* faults (a faulty output unit corrupting
every matching GEMM output from onset until cleared — the arxiv
2205.12177 fault class that one-shot injection never exercises).  The
whole schedule is driven by one seeded ``numpy.random.Generator``, so a
campaign replays bit-identically from its seed.
"""

from __future__ import annotations

import dataclasses

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class FaultSpec(NamedTuple):
    """Where/what to inject.  All fields are scalars (static or traced).

    row/col: coordinates in the 2-D GEMM output.
    delta: value added to the output element (value-fault mode).
    bit: if >= 0, flip this bit of the element instead (bit-flip mode).
      Bit indices are dtype-relative to the corrupted buffer: the fused
      block kernel corrupts its f32 accumulator (exponent bits 23-30);
      the global path corrupts the materialized output in its own dtype
      (bf16 exponent bits 8-14).
    enabled: 0/1 master switch so the same jitted graph can run clean.
    """

    row: jnp.ndarray
    col: jnp.ndarray
    delta: jnp.ndarray
    bit: jnp.ndarray
    enabled: jnp.ndarray

    @staticmethod
    def none() -> "FaultSpec":
        z = jnp.zeros((), jnp.int32)
        return FaultSpec(row=z, col=z, delta=jnp.zeros((), jnp.float32),
                         bit=jnp.full((), -1, jnp.int32), enabled=z)

    @staticmethod
    def value(row: int, col: int, delta: float) -> "FaultSpec":
        return FaultSpec(
            row=jnp.asarray(row, jnp.int32),
            col=jnp.asarray(col, jnp.int32),
            delta=jnp.asarray(delta, jnp.float32),
            bit=jnp.full((), -1, jnp.int32),
            enabled=jnp.ones((), jnp.int32),
        )

    @staticmethod
    def bitflip(row: int, col: int, bit: int) -> "FaultSpec":
        return FaultSpec(
            row=jnp.asarray(row, jnp.int32),
            col=jnp.asarray(col, jnp.int32),
            delta=jnp.zeros((), jnp.float32),
            bit=jnp.asarray(bit, jnp.int32),
            enabled=jnp.ones((), jnp.int32),
        )


_UINT_FOR_BYTES = {2: jnp.uint16, 4: jnp.uint32}


def flip_bit(value: jnp.ndarray, bit) -> jnp.ndarray:
    """XOR one bit of each element of ``value`` (same shape)."""
    nbytes = jnp.dtype(value.dtype).itemsize
    uint = _UINT_FOR_BYTES[nbytes]
    raw = jax.lax.bitcast_convert_type(value, uint)
    mask = (jnp.ones((), uint) << bit.astype(uint)).astype(uint)
    return jax.lax.bitcast_convert_type(raw ^ mask, value.dtype)


def inject_output_fault(y: jnp.ndarray, fault: FaultSpec) -> jnp.ndarray:
    """Corrupt one element of a (..., m, n) output per ``fault``."""
    m, n = y.shape[-2], y.shape[-1]
    rows = jnp.arange(m, dtype=jnp.int32)
    cols = jnp.arange(n, dtype=jnp.int32)
    mask = (rows[:, None] == fault.row) & (cols[None, :] == fault.col)
    mask = jnp.broadcast_to(mask, y.shape)
    on = fault.enabled.astype(bool)

    flipped = flip_bit(y, jnp.maximum(fault.bit, 0))
    bit_mode = fault.bit >= 0
    corrupted = jnp.where(
        bit_mode, flipped, y + fault.delta.astype(y.dtype)
    )
    return jnp.where(on & mask, corrupted, y)


# exponent-bit index range [lo, hi) per floating dtype: flips there scale
# the value by powers of two, the classic catastrophic soft-error signature
_EXPONENT_BITS = {
    np.dtype(jnp.bfloat16): (8, 15),     # s1 e8 m7
    np.dtype(np.float32): (23, 31),      # s1 e8 m23
    np.dtype(np.float16): (10, 15),      # s1 e5 m10
}


def exponent_bit_range(dtype) -> tuple:
    """``[lo, hi)`` exponent-bit indices of a floating dtype (bf16 bits
    8-14, f32 bits 23-30, f16 bits 10-14)."""
    dt = np.dtype(dtype)
    try:
        return _EXPONENT_BITS[dt]
    except KeyError:
        raise ValueError(
            f"no exponent-bit range for dtype {dt}; known: "
            f"{sorted(str(d) for d in _EXPONENT_BITS)}") from None


def random_fault(rng: np.random.Generator, m: int, n: int,
                 magnitude: float | None = None,
                 dtype=jnp.bfloat16) -> FaultSpec:
    """Sample a random single-output fault for campaigns: exponent-region
    bit-flip (the catastrophic case) or a value fault of given magnitude.
    ``dtype`` is the corrupted buffer's dtype — it picks the exponent-bit
    range (bf16 bits 8-14, f32 bits 23-30), so campaigns against f32
    accumulators flip real exponent bits."""
    row = int(rng.integers(m))
    col = int(rng.integers(n))
    if magnitude is None:
        lo, hi = exponent_bit_range(dtype)
        bit = int(rng.integers(lo, hi))
        return FaultSpec.bitflip(row, col, bit)
    return FaultSpec.value(row, col, magnitude)


# ------------------------------------------------------------- campaigns

@dataclasses.dataclass
class CampaignFault:
    """One fault the campaign process emitted for one engine step.

    ``kind`` is "transient" (fires once) or "permanent" (a sticky faulty
    output unit: the SAME (layer, site, row, col, bit) target re-emitted
    every step from ``onset_step`` until cleared).  ``model_fault`` is the
    prebuilt device-scalar target the engine threads into the jitted
    call."""

    kind: str
    onset_step: int
    layer: int
    site: str
    row: int
    col: int
    bit: int                       # -1 => value fault of ``delta``
    delta: float
    model_fault: object            # ModelFault (device scalars)

    def describe(self) -> dict:
        """JSON-ready ground truth (the replay-equality surface)."""
        return {
            "kind": self.kind, "onset_step": self.onset_step,
            "layer": self.layer, "site": self.site,
            "row": self.row, "col": self.col,
            "bit": self.bit, "delta": self.delta,
        }


class FaultModel:
    """Seeded, deterministic fault process for serving campaigns.

    ``poll()`` is called once per engine step and returns at most ONE
    ``CampaignFault`` (the jitted entry points take a single target per
    call): an active sticky permanent fault takes precedence, else a
    Bernoulli(``transient_rate``) draw decides whether this step suffers
    a transient fault.  A Bernoulli(``permanent_rate``) draw governs the
    ONSET of a sticky fault, which then corrupts every subsequent step
    until ``permanent_duration`` steps elapse (or ``clear_sticky()``) —
    the repair/remap event.

    Every random decision flows through one ``numpy.random.Generator``
    seeded at construction, and the per-poll draw ORDER is fixed, so the
    same seed replays the exact same schedule (``self.schedule`` records
    it; campaigns assert bit-identical replays on that record).

    ``rows``/``cols`` bound the (row, col) target within the faulted GEMM
    *call*: the row is a token row of that call's output, so decode-step
    GEMMs (one token row per call) only ever see row 0 — the default.
    Raise ``rows`` to target prefill/chunk calls, whose output carries
    one row per prompt token; an out-of-range target is a physical no-op
    and classifies as ``masked``.
    """

    def __init__(self, *, transient_rate: float = 0.0,
                 permanent_rate: float = 0.0,
                 permanent_duration: int | None = 8,
                 seed: int = 0, layers: int = 1,
                 sites: tuple = ("qkv", "attn_out", "mlp_up", "mlp_down"),
                 rows: int = 1, cols: int = 32,
                 dtype=jnp.bfloat16, magnitude: float | None = None):
        if not 0.0 <= transient_rate <= 1.0:
            raise ValueError("transient_rate must be in [0, 1]")
        if not 0.0 <= permanent_rate <= 1.0:
            raise ValueError("permanent_rate must be in [0, 1]")
        if permanent_duration is not None and permanent_duration < 1:
            raise ValueError("permanent_duration must be >= 1 or None")
        self.transient_rate = float(transient_rate)
        self.permanent_rate = float(permanent_rate)
        self.permanent_duration = permanent_duration
        self.seed = int(seed)
        self.layers = int(layers)
        self.sites = tuple(sites)
        self.rows = int(rows)
        self.cols = int(cols)
        self.dtype = dtype
        self.magnitude = magnitude
        self.reset()

    def reset(self) -> None:
        """Rewind to the seed: same schedule on the next run (replay)."""
        self._rng = np.random.default_rng(self.seed)
        self.step = 0
        self.sticky: CampaignFault | None = None
        self.schedule: list = []

    def clear_sticky(self) -> None:
        """Repair the faulty unit (ends the permanent fault early)."""
        self.sticky = None

    # ------------------------------------------------------------ drawing
    def _draw_target(self, kind: str) -> CampaignFault:
        # deferred import: models.layers imports this module
        from repro.models.layers import ModelFault

        layer = int(self._rng.integers(self.layers))
        site = self.sites[int(self._rng.integers(len(self.sites)))]
        spec = random_fault(self._rng, self.rows, self.cols,
                            magnitude=self.magnitude, dtype=self.dtype)
        return CampaignFault(
            kind=kind, onset_step=self.step, layer=layer, site=site,
            row=int(spec.row), col=int(spec.col), bit=int(spec.bit),
            delta=float(spec.delta),
            model_fault=ModelFault.at(layer, site, spec))

    def poll(self) -> CampaignFault | None:
        """Advance the process by one engine step; return this step's
        fault (or None).  Fixed draw order per poll — two Bernoulli
        draws, then target draws only when one fires — keeps the
        schedule a pure function of the seed and the poll count."""
        u_perm = float(self._rng.random())
        u_trans = float(self._rng.random())
        if self.sticky is not None and self.permanent_duration is not None \
                and self.step - self.sticky.onset_step >= \
                self.permanent_duration:
            self.sticky = None                       # repaired/remapped
        if self.sticky is None and u_perm < self.permanent_rate:
            self.sticky = self._draw_target("permanent")
        if self.sticky is not None:
            fired: CampaignFault | None = self.sticky
        elif u_trans < self.transient_rate:
            fired = self._draw_target("transient")
        else:
            fired = None
        if fired is not None:
            rec = fired.describe()
            rec["step"] = self.step
            self.schedule.append(rec)
        self.step += 1
        return fired
