"""Core ABFT library: the paper's contribution as composable JAX modules."""

from repro.core.checksums import (
    CheckResult,
    global_row_check,
    global_scalar_check,
    weight_abs_checksum,
    weight_row_checksum,
)
from repro.core.faults import FaultSpec, inject_output_fault, random_fault
from repro.core.hardware import DEFAULT, NVIDIA_T4, TPU_V5E, HardwareSpec
from repro.core.intensity import (
    GemmDims,
    aggregate_intensity,
    compute_bound_ai,
    gemm_time,
    is_compute_bound,
    roofline_time,
)
from repro.core.policy import (
    FixedPolicy,
    IntensityGuidedPolicy,
    LayerSpec,
    ProfileGuidedPolicy,
    ProtectionPlan,
    ProtectionPolicy,
    SchemeRegistry,
    SchemeSpec,
    Selection,
    StepShape,
    default_registry,
    policy_from_selector,
)
from repro.core.protected import (
    ABFTConfig,
    WeightChecksums,
    precompute_weight_checksums,
    protected_matmul,
)
from repro.core.schemes import (
    BlockShape,
    Scheme,
    overhead_pct,
    protected_time,
    scheme_cost,
)
from repro.core.selector import SelectorConfig, select_scheme, selection_report

__all__ = [
    "ABFTConfig",
    "BlockShape",
    "CheckResult",
    "DEFAULT",
    "FaultSpec",
    "FixedPolicy",
    "GemmDims",
    "HardwareSpec",
    "IntensityGuidedPolicy",
    "LayerSpec",
    "NVIDIA_T4",
    "ProfileGuidedPolicy",
    "ProtectionPlan",
    "ProtectionPolicy",
    "Scheme",
    "SchemeRegistry",
    "SchemeSpec",
    "Selection",
    "SelectorConfig",
    "StepShape",
    "TPU_V5E",
    "WeightChecksums",
    "aggregate_intensity",
    "compute_bound_ai",
    "default_registry",
    "gemm_time",
    "global_row_check",
    "global_scalar_check",
    "inject_output_fault",
    "is_compute_bound",
    "overhead_pct",
    "policy_from_selector",
    "precompute_weight_checksums",
    "protected_matmul",
    "protected_time",
    "random_fault",
    "roofline_time",
    "scheme_cost",
    "select_scheme",
    "selection_report",
    "weight_abs_checksum",
    "weight_row_checksum",
]
