"""Hardware specifications used by the roofline model and scheme selector.

The paper (Kosaian & Rashmi, SC '21) keys its adaptive ABFT decision off the
device compute-to-memory-bandwidth ratio (CMR).  We generalize this to a
small spec record covering the terms needed by the three-term roofline
(compute / memory / collective) plus the TPU-specific split between the MXU
(systolic matmul unit) and the VPU (vector unit), which is where the
block-level ABFT checksum math executes (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Static per-chip hardware description.

    Attributes:
      name: human-readable device name.
      peak_flops: peak matmul-unit FLOP/s at the working precision (MXU on
        TPU, Tensor Cores on GPU).
      vpu_flops: peak vector-unit FLOP/s (VPU on TPU, CUDA cores on GPU).
        Checksum generation runs here; it co-issues with the matmul unit.
      hbm_bw: main-memory bandwidth, bytes/s.
      ici_bw: per-link interconnect bandwidth, bytes/s (ICI on TPU, NVLink
        on GPU).  Used for the collective roofline term.
      hbm_bytes: main-memory capacity per chip.
      vmem_bytes: on-chip scratchpad (VMEM / shared memory) capacity.
      fixed_op_overhead_s: fixed per-dispatched-op overhead (kernel launch on
        GPU, ~op scheduling on TPU).  Charged once per *unfused* redundant
        op; this is what makes a separate global-ABFT reduction kernel
        non-free on thin, bandwidth-bound layers.
    """

    name: str
    peak_flops: float
    vpu_flops: float
    hbm_bw: float
    ici_bw: float
    hbm_bytes: float
    vmem_bytes: float
    fixed_op_overhead_s: float = 1.5e-6

    @property
    def cmr(self) -> float:
        """Compute-to-memory-bandwidth ratio (FLOPs per byte)."""
        return self.peak_flops / self.hbm_bw


# TPU v5e — the target device for this reproduction.  Constants per the
# assignment brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    vpu_flops=1.9e12,        # 8x128 lanes x 2 (FMA) x ~0.94 GHz
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=64 * 1024**2,
    fixed_op_overhead_s=1.5e-6,
)

# NVIDIA T4 — the paper's evaluation device; used only by the
# paper-validation benchmarks to reproduce the published crossovers.
NVIDIA_T4 = HardwareSpec(
    name="nvidia-t4",
    peak_flops=65e12,        # FP16 Tensor Core
    vpu_flops=8.1e12,        # FP32 CUDA cores
    hbm_bw=320e9,
    ici_bw=16e9,             # PCIe gen3 x16
    hbm_bytes=16 * 1024**3,
    vmem_bytes=64 * 1024,    # shared memory per SM
    fixed_op_overhead_s=5e-6,
)

DEFAULT = TPU_V5E


def get_hardware(name: str) -> HardwareSpec:
    table = {h.name: h for h in (TPU_V5E, NVIDIA_T4)}
    try:
        return table[name]
    except KeyError:
        raise ValueError(
            f"unknown hardware {name!r}; known: {sorted(table)}") from None
