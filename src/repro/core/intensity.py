"""Arithmetic-intensity accounting for linear layers (paper §3).

Every linear layer in the framework is described by ``GemmDims``; its
arithmetic intensity (FLOPs / bytes moved) is compared against the device
CMR to classify the layer as compute- or bandwidth-bound, which drives the
intensity-guided ABFT scheme selection (paper §5.3).
"""

from __future__ import annotations

import dataclasses

from repro.core.hardware import HardwareSpec


@dataclasses.dataclass(frozen=True)
class GemmDims:
    """A (possibly batched) GEMM: (m, k) @ (k, n), repeated ``batch`` times.

    ``bytes_a/b/out`` model the *HBM traffic* of each operand.  Weights that
    are resident and re-read per step still count; operands known to be
    fused away (e.g., an activation checksum produced in a previous layer's
    epilogue) can be excluded by the caller via ``bytes_*_override``.
    """

    m: int
    k: int
    n: int
    batch: int = 1
    dtype_bytes: int = 2          # bf16 operands
    acc_bytes: int = 4            # f32 accumulation/output before downcast
    out_dtype_bytes: int = 2

    @property
    def flops(self) -> float:
        return 2.0 * self.batch * self.m * self.k * self.n

    @property
    def bytes_a(self) -> float:
        return float(self.batch * self.m * self.k * self.dtype_bytes)

    @property
    def bytes_b(self) -> float:
        return float(self.batch * self.k * self.n * self.dtype_bytes)

    @property
    def bytes_out(self) -> float:
        return float(self.batch * self.m * self.n * self.out_dtype_bytes)

    @property
    def bytes_total(self) -> float:
        return self.bytes_a + self.bytes_b + self.bytes_out

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.bytes_total


def compute_bound_ai(ai: float, hw: HardwareSpec) -> bool:
    """Paper Eq. (1), the SINGLE boundary predicate: AI strictly greater
    than the device CMR => compute bound.  AI exactly equal to the CMR is
    bandwidth-bound (the kernel still saturates HBM).  Every consumer —
    ``is_compute_bound``, the policy reason strings, the report tables,
    and the chunk-budget autotuner — goes through this one function, so
    the classification can never disagree with itself at the boundary."""
    return float(ai) > hw.cmr


def is_compute_bound(dims: GemmDims, hw: HardwareSpec) -> bool:
    """Paper Eq. (1): AI > CMR => compute bound (see compute_bound_ai)."""
    return compute_bound_ai(dims.arithmetic_intensity, hw)


def gemm_time(dims: GemmDims, hw: HardwareSpec) -> float:
    """Roofline execution-time estimate for the unprotected GEMM."""
    return max(dims.flops / hw.peak_flops, dims.bytes_total / hw.hbm_bw)


def roofline_time(
    flops_mxu: float,
    flops_vpu: float,
    bytes_hbm: float,
    hw: HardwareSpec,
    fixed_ops: int = 0,
) -> float:
    """Three-way roofline: MXU, VPU and HBM operate concurrently; fixed
    per-op overheads serialize.  This is the analytic model referenced by
    paper §7.2 and used by the intensity-guided selector."""
    return (
        max(
            flops_mxu / hw.peak_flops,
            flops_vpu / hw.vpu_flops,
            bytes_hbm / hw.hbm_bw,
        )
        + fixed_ops * hw.fixed_op_overhead_s
    )


def step_gemm_dims(tokens: int, d_model: int, d_ff: int | None = None,
                   dtype_bytes: int = 2,
                   out_dtype_bytes: int | None = None) -> GemmDims:
    """Representative GEMM of one *serving step*: ``tokens`` is the step's
    actual token composition (resident decode tokens + co-scheduled
    prefill-chunk tokens), the weight is the widest per-token projection
    (``d_model x d_ff`` when an FFN exists, else ``d_model x d_model``).

    The step composition — not the static phase — is what moves the
    operating point between the memory-bound regime (decode-only steps,
    ``m ~ batch``) and the compute-bound regime (mixed steps carrying a
    prefill chunk, ``m ~ chunk_tokens``), so the intensity-guided
    selector should be re-consulted with THESE dims every step (paper
    §5.3 applied at serving time; the engine records the resulting
    ``(intensity, scheme)`` trace in ``EngineStats``)."""
    return GemmDims(
        m=int(tokens), k=int(d_model), n=int(d_ff or d_model),
        dtype_bytes=dtype_bytes,
        out_dtype_bytes=(dtype_bytes if out_dtype_bytes is None
                         else out_dtype_bytes),
    )


def aggregate_intensity(layers: list[GemmDims]) -> float:
    """Paper §3.2 'aggregate arithmetic intensity' of a network: total FLOPs
    across linear layers divided by total bytes across linear layers."""
    total_flops = sum(l.flops for l in layers)
    total_bytes = sum(l.bytes_total for l in layers)
    if total_bytes == 0:
        return 0.0
    return total_flops / total_bytes
