"""``protected_matmul`` — the paper's contribution as a composable JAX op.

Every linear layer in the framework calls this instead of ``x @ w``.  The
intensity-guided selector (paper §5.3) resolves Scheme.AUTO per layer shape
at trace time; the chosen scheme executes and returns (y, CheckResult).

Scheme dispatch:
  GLOBAL   — XLA dot + Hari-style global check using the offline weight
             checksum (precompute via ``precompute_weight_checksums``).
  BLOCK_*  — the fused Pallas kernel (kernels/ops.py).
  REPLICA  — fused kernel in replica mode (ablation baseline).
  NONE     — plain dot, clean CheckResult.

Distribution note: under pjit/shard_map the GLOBAL path shards exactly like
the dot it protects (the check einsums follow the same specs); the BLOCK
path runs the Pallas kernel per shard — on a TP-sharded weight each shard
checks its local sub-GEMM, which is precisely the paper's "smallest parallel
subproblem" principle lifted one level up the hierarchy.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import checksums
from repro.core.checksums import CheckResult
from repro.core.faults import FaultSpec, inject_output_fault
from repro.core.hardware import DEFAULT, HardwareSpec
from repro.core.intensity import GemmDims
from repro.core.schemes import BlockShape, Scheme
from repro.core.selector import SelectorConfig, select_scheme


class WeightChecksums(NamedTuple):
    """Offline row checksums of a weight matrix (paper §2.5)."""

    w_sum: jnp.ndarray
    w_abs_sum: jnp.ndarray


def precompute_weight_checksums(w: jnp.ndarray) -> WeightChecksums:
    return WeightChecksums(
        w_sum=checksums.weight_row_checksum(w),
        w_abs_sum=checksums.weight_abs_checksum(w),
    )


@dataclasses.dataclass(frozen=True)
class ABFTConfig:
    """Framework-wide ABFT policy, threaded through model construction."""

    enabled: bool = True
    scheme: Scheme = Scheme.AUTO
    selector: SelectorConfig = SelectorConfig()
    hardware: HardwareSpec = DEFAULT
    blocks: BlockShape = BlockShape()
    use_pallas: bool = True        # False: block schemes via the jnp oracle
    c_factor: float = 16.0
    protect_backward: bool = False  # optional dgrad/wgrad protection
    # fused-ABFT flash attention backend (kernels/flash_attention.py):
    # protects attention's own GEMMs in-kernel and keeps score chunks in
    # VMEM (the §Perf-identified lever).  XLA chunked attention otherwise.
    flash_attention: bool = False

    def resolve(self, dims: GemmDims, first_layer: bool = False) -> Scheme:
        if not self.enabled:
            return Scheme.NONE
        if self.scheme != Scheme.AUTO:
            return self.scheme
        return select_scheme(
            dims, self.hardware, self.selector, first_layer=first_layer
        ).scheme

    @staticmethod
    def off() -> "ABFTConfig":
        return ABFTConfig(enabled=False)


def _gemm_dims(x: jnp.ndarray, w: jnp.ndarray, out_dtype) -> GemmDims:
    *lead, m, k = x.shape
    n = w.shape[-1]
    batch = 1
    for d in lead:
        batch *= d
    return GemmDims(
        m=batch * m, k=k, n=n, batch=1,
        dtype_bytes=jnp.dtype(x.dtype).itemsize,
        out_dtype_bytes=jnp.dtype(out_dtype).itemsize,
    )


def protected_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: ABFTConfig = ABFTConfig(),
    *,
    wsums: WeightChecksums | None = None,
    out_dtype=None,
    fault: FaultSpec | None = None,
    first_layer: bool = False,
) -> tuple[jnp.ndarray, CheckResult]:
    """ABFT-protected ``y = x @ w``.

    x: (..., m, k);  w: (k, n).  Returns (y, CheckResult).
    ``fault`` (optional) injects a single output fault for testing — on the
    block path it corrupts the kernel accumulator; on the global path the
    materialized output.
    """
    out_dtype = out_dtype or x.dtype
    dims = _gemm_dims(x, w, out_dtype)
    scheme = cfg.resolve(dims, first_layer=first_layer)

    if scheme == Scheme.NONE:
        y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
        y = y.astype(out_dtype)
        if fault is not None:
            y = inject_output_fault(y, fault)
        return y, CheckResult.clean()

    if scheme == Scheme.GLOBAL:
        y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
        y = y.astype(out_dtype)
        if fault is not None:
            y = inject_output_fault(y, fault)
        if wsums is None:
            wsums = precompute_weight_checksums(w)
        x2 = x.reshape((-1, x.shape[-1]))
        y2 = y.reshape((-1, y.shape[-1]))
        check = checksums.global_row_check(
            x2, wsums.w_sum, wsums.w_abs_sum, y2, c_factor=cfg.c_factor
        )
        return y, check

    # Block-level schemes — fused Pallas kernel (or jnp oracle fallback).
    mode = {
        Scheme.BLOCK_1S: "1s",
        Scheme.BLOCK_2S: "2s",
        Scheme.REPLICA: "replica",
    }[scheme]
    if cfg.use_pallas:
        from repro.kernels import ops

        return ops.abft_matmul(
            x, w, mode=mode, blocks=cfg.blocks, out_dtype=out_dtype,
            fault=fault, c_factor=cfg.c_factor,
        )
    # XLA emulation of the fused kernel's *semantics* (used inside the
    # 512-device dry-run, where interpret-mode pallas_call cannot lower):
    # the one-sided check with the weight checksum recomputed inline, as
    # the kernel does.  Sharding-friendly: pure einsums, no reshapes of
    # sharded dims.  On real TPU the Pallas kernel replaces this path; its
    # internal costs are modeled analytically for the roofline since a
    # custom-call's internals are opaque to cost_analysis either way.
    f32 = jnp.float32
    y = jnp.matmul(x, w, preferred_element_type=f32).astype(out_dtype)
    if fault is not None:
        y = inject_output_fault(y, fault)
    # reductions accumulate in f32 via dtype= — materializing .astype(f32)
    # copies of the weights would add 3x weight traffic per layer to the
    # emulation (measured; the fused kernel pays none of this)
    w_sum = jnp.sum(w, axis=-1, dtype=f32)
    w_abs = jnp.sum(jnp.abs(w), axis=-1, dtype=f32)
    check = jnp.einsum("...mk,k->...m", x, w_sum.astype(x.dtype),
                       preferred_element_type=f32)
    bound = jnp.einsum("...mk,k->...m", jnp.abs(x), w_abs.astype(x.dtype),
                       preferred_element_type=f32)
    yf = y.astype(f32)
    rowsum = jnp.sum(y, axis=-1, dtype=f32)
    res = jnp.abs(check - rowsum)
    rtol = checksums.tolerance_scale(x.shape[-1], c=cfg.c_factor)
    if x.dtype != f32:
        # w_sum was quantized to the activation dtype for the check
        # einsum: absorb its quantization into the threshold
        rtol = rtol + 0.5 * checksums.eps_of(x.dtype)
    tau = checksums.ATOL + rtol * bound
    if y.dtype != f32:
        tau = tau + 0.5 * checksums.eps_of(y.dtype) * jnp.sum(
            jnp.abs(yf), axis=-1)
    flag = checksums.flag_from(res, tau)
    return y, CheckResult(flag=flag, residual=res, threshold=tau)
