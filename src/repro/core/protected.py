"""``protected_matmul`` — the paper's contribution as a composable JAX op.

Every linear layer in the framework calls this instead of ``x @ w``.  The
active ProtectionPolicy (core/policy.py, paper §5.3) resolves the scheme
per layer shape at trace time; the chosen scheme's registered *executor*
runs and returns (y, CheckResult).

Scheme dispatch goes through the SchemeRegistry — the executors defined
here register at import for the built-ins:
  global   — XLA dot + Hari-style global check using the offline weight
             checksum (precompute via ``precompute_weight_checksums``).
  block_*  — the fused Pallas kernel (kernels/ops.py), or the XLA
             emulation of its semantics when ``use_pallas=False``.
  replica  — fused kernel in replica mode (ablation baseline).
  none     — plain dot, clean CheckResult.
A newly registered scheme (cost model + executor) dispatches here with no
edit to this module.

``ABFTConfig`` below is the DEPRECATED facade: it survives for existing
callers and simply constructs a ProtectionPolicy (``effective_policy``) —
an ``IntensityGuidedPolicy`` for ``scheme=AUTO``, a ``FixedPolicy``
otherwise.  New code should build policies (and ``ProtectionPlan``s)
directly and wrap them via ``ABFTConfig.from_policy`` where a config
object is still required.

Distribution note: under pjit/shard_map the GLOBAL path shards exactly like
the dot it protects (the check einsums follow the same specs); the BLOCK
path runs the Pallas kernel per shard — on a TP-sharded weight each shard
checks its local sub-GEMM, which is precisely the paper's "smallest parallel
subproblem" principle lifted one level up the hierarchy.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax.numpy as jnp

from repro.analysis.markers import protection_scope
from repro.core import checksums
from repro.core.checksums import CheckResult
from repro.core.faults import FaultSpec, inject_output_fault
from repro.core.hardware import DEFAULT, HardwareSpec
from repro.core.intensity import GemmDims
from repro.core.policy import (
    FixedPolicy,
    ProtectionPolicy,
    default_registry,
    policy_from_selector,
    scheme_name_of,
)
from repro.core.schemes import BlockShape, Scheme
from repro.core.selector import SelectorConfig


class WeightChecksums(NamedTuple):
    """Offline row checksums of a weight matrix (paper §2.5)."""

    w_sum: jnp.ndarray
    w_abs_sum: jnp.ndarray


def precompute_weight_checksums(w: jnp.ndarray) -> WeightChecksums:
    return WeightChecksums(
        w_sum=checksums.weight_row_checksum(w),
        w_abs_sum=checksums.weight_abs_checksum(w),
    )


@dataclasses.dataclass(frozen=True)
class ABFTConfig:
    """Framework-wide ABFT config, threaded through model construction.

    DEPRECATED as a policy surface: selection lives in the
    ProtectionPolicy API (core/policy.py); this object merely carries
    execution knobs (hardware, kernel choice, c_factor) plus the policy.
    ``scheme``/``selector`` survive for legacy callers and are folded
    into ``effective_policy()``; prefer ``ABFTConfig.from_policy``."""

    enabled: bool = True
    scheme: Scheme = Scheme.AUTO
    selector: SelectorConfig = SelectorConfig()
    hardware: HardwareSpec = DEFAULT
    blocks: BlockShape = BlockShape()
    use_pallas: bool = True        # False: block schemes via the jnp oracle
    c_factor: float = 16.0
    protect_backward: bool = False  # optional dgrad/wgrad protection
    # fused-ABFT flash attention backend (kernels/flash_attention.py):
    # protects attention's own GEMMs in-kernel and keeps score chunks in
    # VMEM (the §Perf-identified lever).  XLA chunked attention otherwise.
    flash_attention: bool = False
    # the first-class selection strategy; None falls back to the legacy
    # scheme/selector fields (exact same decisions, same code path)
    policy: ProtectionPolicy | None = None

    def __post_init__(self):
        # Warn exactly when the legacy selection surface is in use: a
        # non-AUTO fixed scheme or a non-default SelectorConfig with no
        # first-class policy.  Plain ABFTConfig() / scheme=AUTO stays
        # silent — those denote the default IntensityGuidedPolicy and are
        # not steering selection through the deprecated fields.
        # stacklevel=3: warn -> __init__ (generated) -> caller.
        if self.policy is None and (
                self.scheme != Scheme.AUTO
                or self.selector != SelectorConfig()):
            warnings.warn(
                "ABFTConfig(scheme=..., selector=...) is deprecated as a "
                "selection surface; build a ProtectionPolicy "
                "(core/policy.py) and wrap it via ABFTConfig.from_policy "
                "— FixedPolicy(scheme) replaces scheme=, "
                "policy_from_selector(selector) replaces selector=",
                DeprecationWarning, stacklevel=3)

    def effective_policy(self) -> ProtectionPolicy:
        """The ProtectionPolicy this config denotes (the facade's whole
        job).  Precedence: disabled > explicit policy > fixed legacy
        scheme > legacy SelectorConfig."""
        if not self.enabled:
            return FixedPolicy(Scheme.NONE)
        if self.policy is not None:
            return self.policy
        if self.scheme != Scheme.AUTO:
            return FixedPolicy(self.scheme)
        return policy_from_selector(self.selector)

    def resolve(self, dims: GemmDims, first_layer: bool = False):
        """Scheme for one GEMM shape (Scheme enum for built-ins, name
        string for registered plug-in schemes).  Passes itself as the
        policy's ``cfg`` so registry availability predicates see the
        active backend."""
        return self.effective_policy().select(
            dims, self.hardware, first_layer=first_layer,
            cfg=self).scheme

    @staticmethod
    def off() -> "ABFTConfig":
        return ABFTConfig(enabled=False)

    @staticmethod
    def from_policy(policy: ProtectionPolicy, **kw) -> "ABFTConfig":
        """Wrap a ProtectionPolicy for call sites that still take the
        config object (models, engine, trainer)."""
        return ABFTConfig(policy=policy, **kw)


def _gemm_dims(x: jnp.ndarray, w: jnp.ndarray, out_dtype) -> GemmDims:
    *lead, m, k = x.shape
    n = w.shape[-1]
    batch = 1
    for d in lead:
        batch *= d
    return GemmDims(
        m=batch * m, k=k, n=n, batch=1,
        dtype_bytes=jnp.dtype(x.dtype).itemsize,
        out_dtype_bytes=jnp.dtype(out_dtype).itemsize,
    )


def protected_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: ABFTConfig = ABFTConfig(),
    *,
    wsums: WeightChecksums | None = None,
    out_dtype=None,
    fault: FaultSpec | None = None,
    first_layer: bool = False,
    site: str = "unlabeled",
) -> tuple[jnp.ndarray, CheckResult]:
    """ABFT-protected ``y = x @ w``.

    x: (..., m, k);  w: (k, n).  Returns (y, CheckResult).
    ``fault`` (optional) injects a single output fault for testing — on the
    block path it corrupts the kernel accumulator; on the global path the
    materialized output.

    The active policy resolves the scheme for these dims at trace time;
    the scheme's registered executor (SchemeRegistry) runs it inside an
    ``abft[<scheme>][<site>]`` named scope — the static marker the
    coverage auditor (repro.analysis) reads back off the jaxpr to prove
    every GEMM flows through a registered scheme.  ``site`` is the
    plan-facing layer tag (``attn.q``, ``mlp.down``, ...) threaded down
    from the model layers; audit cross-validation matches it against
    ``ProtectionPlan`` LayerSpec names."""
    out_dtype = out_dtype or x.dtype
    dims = _gemm_dims(x, w, out_dtype)
    scheme = cfg.resolve(dims, first_layer=first_layer)
    executor = default_registry().executor(scheme)
    with protection_scope(scheme_name_of(scheme), site):
        return executor(x, w, cfg, wsums=wsums, out_dtype=out_dtype,
                        fault=fault)


# ------------------------------------------------------------- executors
# The built-in schemes' execution paths, registered below.  Signature:
# (x, w, cfg, *, wsums, out_dtype, fault) -> (y, CheckResult).

def _plain_dot(x, w, out_dtype, fault):
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    y = y.astype(out_dtype)
    if fault is not None:
        y = inject_output_fault(y, fault)
    return y


def _exec_none(x, w, cfg, *, wsums, out_dtype, fault):
    return _plain_dot(x, w, out_dtype, fault), CheckResult.clean()


def _exec_global(x, w, cfg, *, wsums, out_dtype, fault):
    y = _plain_dot(x, w, out_dtype, fault)
    if wsums is None:
        wsums = precompute_weight_checksums(w)
    x2 = x.reshape((-1, x.shape[-1]))
    y2 = y.reshape((-1, y.shape[-1]))
    check = checksums.global_row_check(
        x2, wsums.w_sum, wsums.w_abs_sum, y2, c_factor=cfg.c_factor
    )
    return y, check


def _block_executor(mode: str):
    """Block-level schemes — fused Pallas kernel, or the XLA emulation of
    the fused kernel's *semantics* when ``use_pallas=False`` (used inside
    the 512-device dry-run, where interpret-mode pallas_call cannot
    lower): the one-sided check with the weight checksum recomputed
    inline, as the kernel does.  Sharding-friendly: pure einsums, no
    reshapes of sharded dims.  On real TPU the Pallas kernel replaces
    this path; its internal costs are modeled analytically for the
    roofline since a custom-call's internals are opaque to cost_analysis
    either way."""

    def _exec(x, w, cfg, *, wsums, out_dtype, fault):
        if cfg.use_pallas:
            from repro.kernels import ops

            return ops.abft_matmul(
                x, w, mode=mode, blocks=cfg.blocks, out_dtype=out_dtype,
                fault=fault, c_factor=cfg.c_factor,
            )
        f32 = jnp.float32
        y = jnp.matmul(x, w, preferred_element_type=f32).astype(out_dtype)
        if fault is not None:
            y = inject_output_fault(y, fault)
        # reductions accumulate in f32 via dtype= — materializing
        # .astype(f32) copies of the weights would add 3x weight traffic
        # per layer to the emulation (measured; the fused kernel pays
        # none of this)
        w_sum = jnp.sum(w, axis=-1, dtype=f32)
        w_abs = jnp.sum(jnp.abs(w), axis=-1, dtype=f32)
        check = jnp.einsum("...mk,k->...m", x, w_sum.astype(x.dtype),
                           preferred_element_type=f32)
        bound = jnp.einsum("...mk,k->...m", jnp.abs(x),
                           w_abs.astype(x.dtype),
                           preferred_element_type=f32)
        yf = y.astype(f32)
        rowsum = jnp.sum(y, axis=-1, dtype=f32)
        res = jnp.abs(check - rowsum)
        rtol = checksums.tolerance_scale(x.shape[-1], c=cfg.c_factor)
        if x.dtype != f32:
            # w_sum was quantized to the activation dtype for the check
            # einsum: absorb its quantization into the threshold
            rtol = rtol + 0.5 * checksums.eps_of(x.dtype)
        tau = checksums.ATOL + rtol * bound
        if y.dtype != f32:
            tau = tau + 0.5 * checksums.eps_of(y.dtype) * jnp.sum(
                jnp.abs(yf), axis=-1)
        flag = checksums.flag_from(res, tau)
        return y, CheckResult(flag=flag, residual=res, threshold=tau)

    return _exec


for _name, _exec in (
    ("none", _exec_none),
    ("global", _exec_global),
    ("block_1s", _block_executor("1s")),
    ("block_2s", _block_executor("2s")),
    ("replica", _block_executor("replica")),
):
    default_registry().set_executor(_name, _exec)
