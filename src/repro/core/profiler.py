"""Empirical pre-deployment profiling (paper §5.3 / §6.1).

The paper integrates ABFT-scheme selection into the CUTLASS profiler: all
schemes are *executed* per layer shape and the fastest wins.  This module
is that mode for our stack: measure wall time per (GemmDims, Scheme) on
the current backend and emit a ``profile_table`` consumable by
``SelectorConfig(mode="profile")``.

On this CPU container the timings rank XLA emulations (useful for the
mode's plumbing and tests); on a real TPU the same code times the fused
Pallas kernel vs the global-ABFT XLA path — exactly the paper's flow.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.intensity import GemmDims
from repro.core.policy import FixedPolicy
from repro.core.protected import ABFTConfig, protected_matmul
from repro.core.schemes import Scheme

DEFAULT_CANDIDATES = (Scheme.GLOBAL, Scheme.BLOCK_1S)


def _time(fn, *args, warmup=1, iters=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def profile_layer(
    dims: GemmDims,
    candidates=DEFAULT_CANDIDATES,
    dtype=jnp.float32,
    use_pallas: bool | None = None,
    seed: int = 0,
) -> dict:
    """Measured seconds per scheme for one GEMM shape."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((dims.m, dims.k)), dtype)
    w = jnp.asarray(rng.standard_normal((dims.k, dims.n)), dtype)
    out = {}
    for sc in candidates:
        cfg = ABFTConfig.from_policy(FixedPolicy(sc),
                                     use_pallas=use_pallas)
        fn = jax.jit(lambda a, b, _cfg=cfg: protected_matmul(
            a, b, _cfg, out_dtype=dtype)[0])
        out[sc] = _time(fn, x, w)
    return out


def build_profile_table(
    layer_dims,
    candidates=DEFAULT_CANDIDATES,
    **kw,
) -> dict:
    """profile_table for SelectorConfig(mode='profile'):
    {GemmDims: fastest Scheme}."""
    table = {}
    for dims in layer_dims:
        times = profile_layer(dims, candidates, **kw)
        table[dims] = min(times, key=times.get)
    return table


def build_profile_policy(
    layer_dims,
    candidates=DEFAULT_CANDIDATES,
    fallback=None,
    **kw,
):
    """Measure the given shapes and return the ``ProfileGuidedPolicy``
    that serves them from the table, falling back to the analytic
    roofline for unprofiled shapes (paper §5.3's pre-deployment flow as
    a first-class policy object)."""
    from repro.core.policy import IntensityGuidedPolicy, ProfileGuidedPolicy

    table = build_profile_table(layer_dims, candidates, **kw)
    return ProfileGuidedPolicy(
        table=table, fallback=fallback or IntensityGuidedPolicy())
