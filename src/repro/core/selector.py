"""Legacy selection facade (DEPRECATED — use core/policy.py).

The intensity-guided per-layer selection (paper §5.3) now lives in the
ProtectionPolicy API: ``IntensityGuidedPolicy`` (analytic roofline),
``ProfileGuidedPolicy`` (empirical table + analytic fallback),
``FixedPolicy``, and the compiled ``ProtectionPlan``.  This module keeps
the original entry points as thin delegations so existing callers and
scripts keep working:

* ``select_scheme(dims, hw, SelectorConfig(...))`` — builds the
  equivalent policy (``policy_from_selector``) and delegates; decisions
  are bit-identical to the policy API because they ARE the policy API.
* ``selection_report`` — builds a ``ProtectionPlan`` whose layer
  descriptors carry the explicit ``first`` flag (a Mapping input marks
  its first entry, matching the old enumeration behavior; pass
  ``LayerSpec``s to place the flag on the true first layer).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.hardware import DEFAULT, HardwareSpec
from repro.core.intensity import GemmDims
from repro.core.policy import (
    LayerSpec,
    ProtectionPlan,
    Selection,
    default_registry,
    policy_from_selector,
)
from repro.core.schemes import BlockShape, Scheme, protected_time

__all__ = [
    "LayerSpec",
    "Selection",
    "SelectorConfig",
    "select_scheme",
    "selection_report",
    "modeled_layer_time",
]


@dataclasses.dataclass(frozen=True)
class SelectorConfig:
    """DEPRECATED mode-string selector config; ``policy_from_selector``
    maps it onto the ProtectionPolicy it denotes."""

    mode: str = "analytic"                 # "analytic" | "profile" | "fixed"
    fixed_scheme: Scheme = Scheme.BLOCK_1S  # used when mode == "fixed"
    blocks: BlockShape = BlockShape()
    # () => every auto-eligible AND available registered scheme (the
    # built-ins: global + block_1s — REPLICA/BLOCK_2S stay out, one-sided
    # dominates both per paper §6.5); pin a tuple to override
    candidates: tuple = ()


def select_scheme(
    dims: GemmDims,
    hw: HardwareSpec = DEFAULT,
    config: SelectorConfig = SelectorConfig(),
    profile_table: Mapping[GemmDims, Scheme] | None = None,
    first_layer: bool = False,
) -> Selection:
    """Pick the ABFT scheme for one linear layer (legacy entry point)."""
    if config.mode == "profile" and profile_table and dims in profile_table:
        # legacy semantics preserved: a LIVE O(1) table hit per call —
        # canonicalizing the whole table into a ProfileGuidedPolicy per
        # select would re-sort it for every layer site.  Long-lived
        # tables should build the policy once (profiler.
        # build_profile_policy) instead of passing a dict here.
        return Selection(
            scheme=default_registry().get(profile_table[dims]).scheme,
            arithmetic_intensity=dims.arithmetic_intensity,
            cmr=hw.cmr,
            modeled_overhead_pct={},
            reason="empirical profile table",
        )
    policy = policy_from_selector(config)
    return policy.select(dims, hw, first_layer=first_layer)


def selection_report(
    layer_dims,
    hw: HardwareSpec = DEFAULT,
    config: SelectorConfig = SelectorConfig(),
) -> list[dict]:
    """Human-readable per-layer selection table (used by the examples and
    the pre-deployment report).  ``layer_dims``: a ``{name: GemmDims}``
    mapping (first entry explicitly flagged as the first protected
    layer) or an iterable of ``LayerSpec`` with the flag placed by the
    caller."""
    plan = ProtectionPlan.build(
        layer_dims, hw=hw, policy=policy_from_selector(config),
        model="report", phase="report")
    return plan.report_rows()


def modeled_layer_time(
    dims: GemmDims,
    scheme: Scheme,
    hw: HardwareSpec = DEFAULT,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> float:
    return protected_time(scheme, dims, hw, blocks, first_layer)
