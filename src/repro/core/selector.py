"""Intensity-guided per-layer ABFT scheme selection (paper §5.3).

The selector picks, for every linear layer, the ABFT scheme with the lowest
modeled execution-time overhead.  Two modes:

* ``analytic`` (default) — the roofline model of schemes.py; paper §7.2
  explicitly endorses substituting the empirical profile with an analytic
  model.  Layers with AI below the device CMR end up on block-level ABFT,
  layers above on global ABFT, exactly the paper's guideline.
* ``profile`` — an empirical table measured by a pre-deployment profiling
  pass (``repro.core.profiler``), mirroring the paper's CUTLASS-profiler
  integration.  Falls back to analytic for unprofiled layers.

Selections are cached per (dims, hardware) so the decision is made once per
layer shape at trace time — never inside the compiled graph.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

from repro.core.hardware import DEFAULT, HardwareSpec
from repro.core.intensity import GemmDims
from repro.core.schemes import BlockShape, Scheme, overhead_pct, protected_time

# Schemes eligible for automatic selection.  REPLICA and BLOCK_2S are kept
# out of AUTO (the paper shows one-sided dominates both, §6.5) but remain
# selectable explicitly for ablations.
_AUTO_CANDIDATES = (Scheme.GLOBAL, Scheme.BLOCK_1S)


@dataclasses.dataclass(frozen=True)
class Selection:
    scheme: Scheme
    arithmetic_intensity: float
    cmr: float
    modeled_overhead_pct: dict
    reason: str


@dataclasses.dataclass(frozen=True)
class SelectorConfig:
    mode: str = "analytic"                 # "analytic" | "profile" | "fixed"
    fixed_scheme: Scheme = Scheme.BLOCK_1S  # used when mode == "fixed"
    blocks: BlockShape = BlockShape()
    candidates: tuple = _AUTO_CANDIDATES


@functools.lru_cache(maxsize=4096)
def _select_analytic(
    dims: GemmDims,
    hw: HardwareSpec,
    blocks: BlockShape,
    candidates: tuple,
    first_layer: bool,
) -> Selection:
    overheads = {
        s: overhead_pct(s, dims, hw, blocks, first_layer) for s in candidates
    }
    best = min(candidates, key=lambda s: (overheads[s], s.value))
    ai = dims.arithmetic_intensity
    reason = (
        f"AI={ai:.1f} {'<' if ai < hw.cmr else '>='} CMR={hw.cmr:.0f}; "
        f"min modeled overhead -> {best.value}"
    )
    return Selection(
        scheme=best,
        arithmetic_intensity=ai,
        cmr=hw.cmr,
        modeled_overhead_pct={s.value: overheads[s] for s in candidates},
        reason=reason,
    )


def select_scheme(
    dims: GemmDims,
    hw: HardwareSpec = DEFAULT,
    config: SelectorConfig = SelectorConfig(),
    profile_table: Mapping[GemmDims, Scheme] | None = None,
    first_layer: bool = False,
) -> Selection:
    """Pick the ABFT scheme for one linear layer."""
    if config.mode == "fixed":
        return Selection(
            scheme=config.fixed_scheme,
            arithmetic_intensity=dims.arithmetic_intensity,
            cmr=hw.cmr,
            modeled_overhead_pct={},
            reason=f"fixed scheme {config.fixed_scheme.value}",
        )
    if config.mode == "profile" and profile_table and dims in profile_table:
        scheme = profile_table[dims]
        return Selection(
            scheme=scheme,
            arithmetic_intensity=dims.arithmetic_intensity,
            cmr=hw.cmr,
            modeled_overhead_pct={},
            reason="empirical profile table",
        )
    return _select_analytic(
        dims, hw, config.blocks, tuple(config.candidates), first_layer
    )


def selection_report(
    layer_dims: Mapping[str, GemmDims],
    hw: HardwareSpec = DEFAULT,
    config: SelectorConfig = SelectorConfig(),
) -> list[dict]:
    """Human-readable per-layer selection table (used by the examples and
    the pre-deployment report)."""
    rows = []
    for i, (name, dims) in enumerate(layer_dims.items()):
        sel = select_scheme(dims, hw, config, first_layer=(i == 0))
        rows.append(
            {
                "layer": name,
                "m": dims.m,
                "k": dims.k,
                "n": dims.n,
                "batch": dims.batch,
                "ai": round(sel.arithmetic_intensity, 2),
                "bound": "compute" if sel.arithmetic_intensity >= hw.cmr
                else "bandwidth",
                "scheme": sel.scheme.value,
                "overheads_pct": {
                    k: round(v, 3) for k, v in sel.modeled_overhead_pct.items()
                },
            }
        )
    return rows


def modeled_layer_time(
    dims: GemmDims,
    scheme: Scheme,
    hw: HardwareSpec = DEFAULT,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> float:
    return protected_time(scheme, dims, hw, blocks, first_layer)
