"""ABFT checksum math shared by the global path and the Pallas kernels.

Floating-point note (DESIGN.md §6): the paper evaluates FP16 on GPUs; we
target bf16 with f32 accumulation on TPU.  Checksum equality therefore
becomes a *threshold* test.  Residuals are compared against a principled
bound built from the magnitude sum of the products entering the check:

    |check - recompute| <= tau,
    tau = atol + eps_acc * c(K) * Sigma|a_ik||b_kj|  (+ output-quantization
          term eps_out/2 * rowsum|y| when the checked output was downcast)

Any injected fault with |delta| > tau is detected; faults below tau are, by
construction, within the accumulated rounding noise of a correct GEMM at the
working precision.  NaN/Inf corruptions always trip the check (the compare
is written as ``~(residual <= tau)`` so NaN residuals flag).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

F32 = jnp.float32

# Empirical safety factor over the sqrt-growth rounding model; calibrated by
# tests/test_checksums.py (hypothesis sweep: zero false positives at 8x the
# observed worst residual/bound ratio).
DEFAULT_C_FACTOR = 16.0
ATOL = 1e-30


def eps_of(dtype) -> float:
    return float(jnp.finfo(dtype).eps)


def tolerance_scale(k: int, acc_dtype=jnp.float32, c: float = DEFAULT_C_FACTOR):
    """eps_acc * c * sqrt(k): the relative threshold multiplier applied to
    the magnitude bound.  sqrt(k) reflects random-walk error growth of
    f32 summation over the contraction dimension."""
    return eps_of(acc_dtype) * c * math.sqrt(max(k, 1))


class CheckResult(NamedTuple):
    """Outcome of one ABFT check.  All fields are JAX arrays (pytree-safe).

    ``flag``: scalar bool — True iff a fault was detected (residual above
    threshold anywhere, or NaN/Inf in the residual).
    ``residual``: the raw |check - recompute| values (shape depends on the
    scheme: per-row for one-sided, scalar for two-sided/global-scalar).
    ``threshold``: matching thresholds.
    """

    flag: jnp.ndarray
    residual: jnp.ndarray
    threshold: jnp.ndarray

    @staticmethod
    def combine(*results: "CheckResult") -> "CheckResult":
        """Fold many checks into a single scalar flag (used when aggregating
        across layers inside a scanned stack)."""
        flags = [r.flag for r in results]
        out = flags[0]
        for f in flags[1:]:
            out = jnp.logical_or(out, f)
        return CheckResult(
            flag=out,
            residual=jnp.zeros((), F32),
            threshold=jnp.zeros((), F32),
        )

    @staticmethod
    def clean() -> "CheckResult":
        return CheckResult(
            flag=jnp.zeros((), bool),
            residual=jnp.zeros((), F32),
            threshold=jnp.zeros((), F32),
        )


def flag_from(residual, threshold):
    """NaN-safe threshold compare: NaN/Inf residuals always flag."""
    return jnp.logical_not(jnp.all(residual <= threshold))


# ----------------------------------------------------------------------
# Offline weight checksums (paper §2.5: built once, reused every request).
# ----------------------------------------------------------------------

def weight_row_checksum(w: jnp.ndarray) -> jnp.ndarray:
    """rowsum over the output dim: (k, n) -> (k,), f32."""
    return jnp.sum(w.astype(F32), axis=-1)


def weight_abs_checksum(w: jnp.ndarray) -> jnp.ndarray:
    """Magnitude companion used for the residual threshold."""
    return jnp.sum(jnp.abs(w.astype(F32)), axis=-1)


# ----------------------------------------------------------------------
# Global ABFT check (Hari et al.-style, adapted: left-applied so the
# offline weight checksum is the reused operand; residual locates the
# faulty output *row*).
# ----------------------------------------------------------------------

def global_row_check(
    x: jnp.ndarray,
    w_sum: jnp.ndarray,
    w_abs_sum: jnp.ndarray,
    y: jnp.ndarray,
    c_factor: float = DEFAULT_C_FACTOR,
) -> CheckResult:
    """Check y == x @ w using the offline checksum of w.

    x: (..., m, k); y: (..., m, n); w_sum/w_abs_sum: (k,).
    """
    k = x.shape[-1]
    xf = x.astype(F32)
    check = jnp.einsum("...mk,k->...m", xf, w_sum)
    bound = jnp.einsum("...mk,k->...m", jnp.abs(xf), w_abs_sum)
    yf = y.astype(F32)
    y_rowsum = jnp.sum(yf, axis=-1)
    residual = jnp.abs(check - y_rowsum)
    tau = ATOL + tolerance_scale(k) * bound
    if y.dtype != F32:
        # Output-quantization term: y was rounded to its storage dtype.
        tau = tau + 0.5 * eps_of(y.dtype) * jnp.sum(jnp.abs(yf), axis=-1)
    return CheckResult(flag=flag_from(residual, tau), residual=residual,
                       threshold=tau)


def global_scalar_check(
    x: jnp.ndarray,
    w_sum: jnp.ndarray,
    w_abs_sum: jnp.ndarray,
    y: jnp.ndarray,
    c_factor: float = DEFAULT_C_FACTOR,
) -> CheckResult:
    """Paper Fig. 1 single-dot-product variant: colsum(x) . w_sum vs sum(y).
    Cheapest possible global check; detects but does not locate."""
    k = x.shape[-1]
    xf = x.astype(F32)
    a_sum = jnp.sum(xf, axis=-2)
    a_abs = jnp.sum(jnp.abs(xf), axis=-2)
    check = jnp.einsum("...k,k->...", a_sum, w_sum)
    bound = jnp.einsum("...k,k->...", a_abs, w_abs_sum)
    yf = y.astype(F32)
    total = jnp.sum(yf, axis=(-1, -2))
    residual = jnp.abs(check - total)
    m = x.shape[-2]
    tau = ATOL + tolerance_scale(k * m) * bound
    if y.dtype != F32:
        tau = tau + 0.5 * eps_of(y.dtype) * jnp.sum(jnp.abs(yf), axis=(-1, -2))
    return CheckResult(flag=flag_from(residual, tau), residual=residual,
                       threshold=tau)
