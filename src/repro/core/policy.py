"""ProtectionPolicy API — the single protection surface (paper §5.3).

The paper's contribution is a *decision*: per layer, pick the ABFT scheme
with the lowest modeled execution-time overhead, keyed off arithmetic
intensity vs the device CMR.  This module makes that decision a
first-class, extensible API instead of enum-switches smeared across
``schemes.py`` / ``protected.py`` / ``selector.py`` / the serving engine:

``SchemeRegistry``
    Every scheme registers a cost model, an executor, and a
    kernel-availability predicate.  Adding a scheme (an FT-CNN-style conv
    checksum, a fused paged-prefill kernel variant) is a registration,
    not a core edit: once registered it participates in ``scheme_cost``,
    ``protected_matmul`` dispatch, and — if ``auto_eligible`` — in
    intensity-guided selection.

``ProtectionPolicy``
    The selection strategy protocol, replacing ``SelectorConfig`` mode
    strings:

    * ``FixedPolicy``          — one scheme everywhere (ablations).
    * ``IntensityGuidedPolicy``— the paper's analytic roofline (§5.3,
      with §7.2's endorsement of the analytic substitute).
    * ``ProfileGuidedPolicy``  — empirical profiler table with analytic
      fallback (the paper's CUTLASS-profiler integration).

``ProtectionPlan``
    The policy *compiled* against a concrete (model, hardware, phase):
    named per-layer selections with an EXPLICIT ``first`` flag on the
    first protected layer (no positional guessing), JSON-serializable as
    a deployment artifact, plus two serving-time fast paths:

    * ``plan.for_step(decode_tokens, prefill_tokens)`` — the cached
      per-step re-selection the engine consults every executed step;
    * ``plan.tune_chunk_budget(...)`` — the roofline chunk-budget
      autotuner: the smallest chunked-prefill token budget whose
      mixed-step arithmetic intensity clears the device CMR (surfaced as
      ``ServeEngine(chunk_tokens="auto")``).

``ABFTConfig`` (core/protected.py) survives as a thin deprecated facade
that builds one of these policies; all selection logic lives here.
"""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Any, Callable, Mapping

from repro.core.hardware import DEFAULT, HardwareSpec
from repro.core.intensity import GemmDims, compute_bound_ai, step_gemm_dims
from repro.core.schemes import (
    BlockShape,
    Scheme,
    SchemeCost,
    cost_block_1s,
    cost_block_2s,
    cost_global,
    cost_none,
    cost_replica,
    overhead_pct,
    protected_time,
)


def scheme_name_of(scheme) -> str:
    """Canonical registry key of a Scheme enum or a raw scheme name."""
    return scheme.value if isinstance(scheme, Scheme) else str(scheme)


def as_scheme(name: str):
    """Name -> Scheme enum when it is a built-in, else the name itself
    (registered plug-in schemes have no enum member — by design)."""
    try:
        return Scheme(name)
    except ValueError:
        return name


# ------------------------------------------------------------------ registry

CostFn = Callable[[GemmDims, BlockShape, bool], SchemeCost]


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One registered ABFT scheme.

    ``cost``: analytic redundant-work model ``(dims, blocks, first_layer)
    -> SchemeCost`` — feeds the roofline overhead model and therefore the
    intensity-guided selection.
    ``executor``: ``(x, w, cfg, *, wsums, out_dtype, fault) -> (y,
    CheckResult)`` — the scheme's protected-GEMM implementation
    (``protected_matmul`` dispatches here).  Built-in executors attach
    from core/protected.py at import.
    ``available``: kernel-availability predicate over the ABFT config
    (e.g. a scheme needing a fused Pallas kernel can refuse backends
    without it); ``None`` means always available.  The predicate is
    called with the active ``ABFTConfig`` — threaded through
    ``resolve()``/``select(cfg=...)`` — or ``None`` when no config is in
    play (plan building, legacy ``select_scheme``); predicates must
    treat ``None`` as "backend unknown" and answer for the general case.
    ``auto_eligible``: candidate for automatic intensity-guided selection.
    REPLICA and BLOCK_2S stay out (one-sided dominates both, paper §6.5)
    but remain registered for explicit/ablation use.
    ``enum``: the legacy Scheme member, when one exists."""

    name: str
    cost: CostFn
    executor: Callable | None = None
    available: Callable[[Any], bool] | None = None
    auto_eligible: bool = False
    enum: Scheme | None = None

    @property
    def scheme(self):
        """Selection-facing handle: the enum for built-ins, else the name."""
        return self.enum if self.enum is not None else self.name


def _invalidate_selection_cache() -> None:
    """Registry mutations invalidate memoized selections: cached
    Selections were computed against the old candidate set / cost
    models.  (Guarded lookup: the built-ins register at module init,
    before the cache exists.)"""
    cache = globals().get("_analytic_selection")
    if cache is not None:
        cache.cache_clear()


class SchemeRegistry:
    """Name -> SchemeSpec with duplicate/unknown-name error reporting."""

    def __init__(self):
        self._specs: dict = {}

    def register(self, spec: SchemeSpec, *, override: bool = False) -> None:
        if spec.name in self._specs and not override:
            raise ValueError(
                f"scheme {spec.name!r} is already registered; pass "
                f"override=True to replace it")
        self._specs[spec.name] = spec
        _invalidate_selection_cache()

    def unregister(self, scheme) -> None:
        """Remove a registered scheme (plug-in teardown)."""
        self.get(scheme)                       # unknown-name error path
        del self._specs[scheme_name_of(scheme)]
        _invalidate_selection_cache()

    def get(self, scheme) -> SchemeSpec:
        name = scheme_name_of(scheme)
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(
                f"unknown scheme {name!r}; registered: "
                f"{sorted(self._specs)}") from None

    def __contains__(self, scheme) -> bool:
        return scheme_name_of(scheme) in self._specs

    def names(self) -> tuple:
        return tuple(sorted(self._specs))

    def set_executor(self, scheme, fn: Callable) -> None:
        """Attach (or replace) a scheme's executor after registration —
        how core/protected.py wires the built-in execution paths in
        without a circular import."""
        name = scheme_name_of(scheme)
        self._specs[name] = dataclasses.replace(self.get(name), executor=fn)

    def executor(self, scheme) -> Callable:
        spec = self.get(scheme)
        if spec.executor is None:
            # built-in executors register when core/protected.py imports
            import repro.core.protected  # noqa: F401

            spec = self.get(scheme)
        if spec.executor is None:
            raise KeyError(f"scheme {spec.name!r} has no executor")
        return spec.executor

    def auto_candidates(self, cfg=None) -> tuple:
        """Scheme names eligible for automatic selection, filtered by the
        availability predicate (``cfg`` is the active ABFT config, or
        None for 'backend unknown' — see SchemeSpec.available)."""
        return tuple(sorted(
            s.name for s in self._specs.values()
            if s.auto_eligible and (s.available is None or s.available(cfg))
        ))


_DEFAULT_REGISTRY = SchemeRegistry()
for _spec in (
    SchemeSpec("none", cost_none, enum=Scheme.NONE),
    SchemeSpec("global", cost_global, auto_eligible=True,
               enum=Scheme.GLOBAL),
    SchemeSpec("block_1s", cost_block_1s, auto_eligible=True,
               enum=Scheme.BLOCK_1S),
    SchemeSpec("block_2s", cost_block_2s, enum=Scheme.BLOCK_2S),
    SchemeSpec("replica", cost_replica, enum=Scheme.REPLICA),
):
    _DEFAULT_REGISTRY.register(_spec)


def default_registry() -> SchemeRegistry:
    """The process-wide scheme registry (plug-in schemes register here)."""
    return _DEFAULT_REGISTRY


# ------------------------------------------------------------------ selection

@dataclasses.dataclass(frozen=True)
class Selection:
    """One selection decision (scheme + the evidence behind it)."""

    scheme: Any                      # Scheme enum (built-ins) or name str
    arithmetic_intensity: float
    cmr: float
    modeled_overhead_pct: dict
    reason: str

    @property
    def scheme_name(self) -> str:
        return scheme_name_of(self.scheme)


@functools.lru_cache(maxsize=4096)
def _analytic_selection(
    dims: GemmDims,
    hw: HardwareSpec,
    blocks: BlockShape,
    candidates: tuple,
    first_layer: bool,
) -> Selection:
    """Roofline selection, cached per (dims, hardware, candidates) so the
    decision is made once per layer shape at trace time — never inside
    the compiled graph."""
    reg = default_registry()
    overheads = {
        name: overhead_pct(name, dims, hw, blocks, first_layer)
        for name in candidates
    }
    best = min(candidates, key=lambda n: (overheads[n], n))
    ai = dims.arithmetic_intensity
    bound = compute_bound_ai(ai, hw)     # the ONE boundary predicate
    reason = (
        f"AI={ai:.1f} {'>' if bound else '<='} CMR={hw.cmr:.0f}; "
        f"min modeled overhead -> {best}"
    )
    return Selection(
        scheme=reg.get(best).scheme,
        arithmetic_intensity=ai,
        cmr=hw.cmr,
        modeled_overhead_pct=dict(overheads),
        reason=reason,
    )


# ------------------------------------------------------------------ policies

class ProtectionPolicy:
    """Protocol: a per-layer ABFT selection strategy.

    Implementations are frozen dataclasses (hashable — they ride inside
    ``ABFTConfig`` and lru-cached plans) exposing::

        select(dims, hw=DEFAULT, *, first_layer=False, cfg=None)
        to_json() -> dict        # round-trips via policy_from_json

    ``cfg`` is the active ABFT config when one is in play (threaded by
    ``ABFTConfig.resolve`` so registry availability predicates can see
    the backend), or None.
    """

    kind = "abstract"

    def select(self, dims: GemmDims, hw: HardwareSpec = DEFAULT, *,
               first_layer: bool = False, cfg=None) -> Selection:
        raise NotImplementedError

    def to_json(self) -> dict:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedPolicy(ProtectionPolicy):
    """Always the same scheme (ablations, protection-off)."""

    scheme: Any = Scheme.BLOCK_1S

    kind = "fixed"

    def select(self, dims, hw=DEFAULT, *, first_layer=False,
               cfg=None) -> Selection:
        spec = default_registry().get(self.scheme)   # unknown-name guard
        return Selection(
            scheme=spec.scheme,
            arithmetic_intensity=dims.arithmetic_intensity,
            cmr=hw.cmr,
            modeled_overhead_pct={},
            reason=f"fixed scheme {spec.name}",
        )

    def to_json(self) -> dict:
        return {"kind": self.kind, "scheme": scheme_name_of(self.scheme)}


@dataclasses.dataclass(frozen=True)
class IntensityGuidedPolicy(ProtectionPolicy):
    """The paper's §5.3 decision: per layer, the candidate scheme with the
    lowest roofline-modeled execution-time overhead.  Layers below the
    device CMR land on fused block ABFT, layers above on global ABFT.
    ``candidates=()`` means 'every auto-eligible registered scheme'."""

    blocks: BlockShape = BlockShape()
    candidates: tuple = ()

    kind = "intensity"

    def _candidates(self, cfg=None) -> tuple:
        if self.candidates:
            return tuple(scheme_name_of(c) for c in self.candidates)
        return default_registry().auto_candidates(cfg)

    def select(self, dims, hw=DEFAULT, *, first_layer=False,
               cfg=None) -> Selection:
        return _analytic_selection(
            dims, hw, self.blocks, self._candidates(cfg),
            bool(first_layer))

    def to_json(self) -> dict:
        return {
            "kind": self.kind,
            "blocks": dataclasses.asdict(self.blocks),
            "candidates": [scheme_name_of(c) for c in self.candidates],
        }


@dataclasses.dataclass(frozen=True)
class ProfileGuidedPolicy(ProtectionPolicy):
    """Empirical profile table (core/profiler.py) with analytic fallback
    for unprofiled shapes — the paper's CUTLASS-profiler integration.
    ``table`` accepts a mapping or iterable of (GemmDims, scheme) pairs
    and is canonicalized to a sorted tuple so the policy stays hashable
    and order-insensitive."""

    table: Any = ()
    fallback: IntensityGuidedPolicy = IntensityGuidedPolicy()

    kind = "profile"

    def __post_init__(self):
        items = (self.table.items() if isinstance(self.table, Mapping)
                 else tuple(self.table))
        canon = tuple(sorted(
            ((dims, scheme_name_of(s)) for dims, s in items),
            key=lambda e: dataclasses.astuple(e[0]),
        ))
        object.__setattr__(self, "table", canon)
        object.__setattr__(self, "_lookup", dict(canon))

    def select(self, dims, hw=DEFAULT, *, first_layer=False,
               cfg=None) -> Selection:
        hit = self._lookup.get(dims)
        if hit is not None:
            return Selection(
                scheme=default_registry().get(hit).scheme,
                arithmetic_intensity=dims.arithmetic_intensity,
                cmr=hw.cmr,
                modeled_overhead_pct={},
                reason="empirical profile table",
            )
        return self.fallback.select(dims, hw, first_layer=first_layer,
                                    cfg=cfg)

    def to_json(self) -> dict:
        return {
            "kind": self.kind,
            "table": [
                {"dims": dataclasses.asdict(d), "scheme": s}
                for d, s in self.table
            ],
            "fallback": self.fallback.to_json(),
        }


class ErrorAdaptivePolicy(ProtectionPolicy):
    """Error-rate-adaptive protection ("Adaptive Soft Error Protection",
    arxiv 2407.19664; ROADMAP 5b): wrap a ``base`` policy and escalate to
    an ``escalated`` policy (strongest coverage — ``global`` by default)
    when the engine's OBSERVED error environment crosses thresholds,
    de-escalating with hysteresis when quiet.

    Unlike every other policy this one is deliberately MUTABLE (it holds
    the current protection level), so it must not ride inside a
    trace-time ``LayerCtx`` — the engine splits it into two immutable
    per-level configs and swaps runners/plans on ``update()`` level
    changes (see ``ServeEngine``).

    ``update(snapshot)`` consumes ``FaultRateMonitor.snapshot()`` at plan
    re-selection time:

    * escalate when the windowed OR EWMA detection rate reaches
      ``detection_threshold``, or the windowed hard-fault rate reaches
      ``hard_fault_threshold``;
    * de-escalate only after ``deescalate_after`` consecutive quiet
      updates with every rate at or below ``clear_factor`` x its
      threshold — rates in the dead band between the two keep the
      current level (no flapping).

    ``shrink_chunk`` (0 < f <= 1) optionally scales the engine's chunked
    prefill token budget while escalated: smaller chunks shrink the
    retry blast radius when errors are frequent.  ``shrink_draft``
    (0 < f <= 1) does the same for the speculative-decoding draft
    length: a shorter draft window shrinks the verify-retry blast
    radius AND the number of speculated tokens a hard fault discards.
    """

    kind = "adaptive"

    def __init__(self, base: ProtectionPolicy | None = None, *,
                 escalated: ProtectionPolicy | None = None,
                 detection_threshold: float = 0.05,
                 hard_fault_threshold: float = 0.01,
                 clear_factor: float = 0.5,
                 deescalate_after: int = 16,
                 shrink_chunk: float = 1.0,
                 shrink_draft: float = 1.0):
        if not 0.0 < clear_factor <= 1.0:
            raise ValueError("clear_factor must be in (0, 1]")
        if deescalate_after < 1:
            raise ValueError("deescalate_after must be >= 1")
        if not 0.0 < shrink_chunk <= 1.0:
            raise ValueError("shrink_chunk must be in (0, 1]")
        if not 0.0 < shrink_draft <= 1.0:
            raise ValueError("shrink_draft must be in (0, 1]")
        self.base = base if base is not None else IntensityGuidedPolicy()
        self.escalated = escalated if escalated is not None \
            else FixedPolicy(Scheme.GLOBAL)
        self.detection_threshold = float(detection_threshold)
        self.hard_fault_threshold = float(hard_fault_threshold)
        self.clear_factor = float(clear_factor)
        self.deescalate_after = int(deescalate_after)
        self.shrink_chunk = float(shrink_chunk)
        self.shrink_draft = float(shrink_draft)
        self.level = 0                 # 0 = base, 1 = escalated
        self.escalations = 0
        self.deescalations = 0
        self._quiet = 0

    @property
    def active(self) -> ProtectionPolicy:
        return self.escalated if self.level else self.base

    def update(self, snapshot: Mapping) -> bool:
        """One adaptation decision from a FaultRateMonitor snapshot.
        Returns True iff the protection level CHANGED."""
        det = max(float(snapshot.get("window_detection_rate", 0.0)),
                  float(snapshot.get("ewma_detections_per_step", 0.0)))
        hard = max(float(snapshot.get("window_hard_fault_rate", 0.0)),
                   float(snapshot.get("ewma_hard_faults_per_step", 0.0)))
        hot = det >= self.detection_threshold \
            or hard >= self.hard_fault_threshold
        cool = det <= self.clear_factor * self.detection_threshold \
            and hard <= self.clear_factor * self.hard_fault_threshold
        if self.level == 0:
            if hot:
                self.level = 1
                self.escalations += 1
                self._quiet = 0
                return True
            return False
        if not cool:                   # hot OR dead band: stay escalated
            self._quiet = 0
            return False
        self._quiet += 1
        if self._quiet >= self.deescalate_after:
            self.level = 0
            self.deescalations += 1
            self._quiet = 0
            return True
        return False

    def select(self, dims, hw=DEFAULT, *, first_layer=False,
               cfg=None) -> Selection:
        return self.active.select(dims, hw, first_layer=first_layer,
                                  cfg=cfg)

    def to_json(self) -> dict:
        return {
            "kind": self.kind,
            "base": self.base.to_json(),
            "escalated": self.escalated.to_json(),
            "detection_threshold": self.detection_threshold,
            "hard_fault_threshold": self.hard_fault_threshold,
            "clear_factor": self.clear_factor,
            "deescalate_after": self.deescalate_after,
            "shrink_chunk": self.shrink_chunk,
            "shrink_draft": self.shrink_draft,
            "level": self.level,
        }


def policy_from_selector(config, profile_table=None) -> ProtectionPolicy:
    """Legacy ``SelectorConfig`` mode string -> ProtectionPolicy (the
    compatibility shim behind ``select_scheme`` and ``ABFTConfig``)."""
    if config.mode == "fixed":
        return FixedPolicy(config.fixed_scheme)
    base = IntensityGuidedPolicy(
        blocks=config.blocks, candidates=tuple(config.candidates))
    if config.mode == "profile":
        return ProfileGuidedPolicy(
            table=profile_table or (), fallback=base)
    return base


class PlanValidationError(ValueError):
    """A serialized ProtectionPlan failed static validation against the
    live SchemeRegistry (unknown scheme, duplicate layer, stale dims)."""


def _policy_scheme_names(d: dict) -> list:
    """(path, scheme-name) pairs referenced by a serialized policy."""
    kind = d.get("kind")
    if kind == "fixed":
        return [("policy.scheme", d.get("scheme"))]
    if kind == "intensity":
        return [(f"policy.candidates[{i}]", c)
                for i, c in enumerate(d.get("candidates") or ())]
    if kind == "profile":
        out = [(f"policy.table[{i}].scheme", e.get("scheme"))
               for i, e in enumerate(d.get("table") or ())]
        out += [("policy.fallback." + p.removeprefix("policy."), n)
                for p, n in _policy_scheme_names(d.get("fallback") or {})]
        return out
    if kind == "adaptive":
        out = []
        for sub in ("base", "escalated"):
            out += [(f"policy.{sub}." + p.removeprefix("policy."), n)
                    for p, n in _policy_scheme_names(d.get(sub) or {})]
        return out
    return []


def validate_plan_payload(d: dict) -> None:
    """Static validation of a serialized plan against the live registry.

    Raises ``PlanValidationError`` listing EVERY problem (diff-style, one
    line per offense) rather than stopping at the first — a stale
    deployment artifact should be fully diagnosable from one failure."""
    reg = default_registry()
    known = reg.names()
    problems = []
    seen: dict = {}
    for i, e in enumerate(d.get("layers") or ()):
        where = f"layers[{i}] {e.get('name')!r}"
        name = e.get("name")
        if name in seen:
            problems.append(
                f"{where}: duplicate layer name (first at "
                f"layers[{seen[name]}])")
        else:
            seen[name] = i
        if e.get("scheme") not in known:
            problems.append(
                f"{where}: unknown scheme {e.get('scheme')!r}; "
                f"registered: {list(known)}")
        dims = e.get("dims") or {}
        mkn = {k: dims.get(k, 1) for k in ("m", "k", "n", "batch")}
        if any(not isinstance(v, int) or v < 1 for v in mkn.values()):
            problems.append(
                f"{where}: stale dims "
                + " ".join(f"{k}={v}" for k, v in mkn.items())
                + " (m/k/n/batch must all be ints >= 1)")
        count = e.get("count", 1)
        if not isinstance(count, int) or count < 1:
            problems.append(f"{where}: count={count!r} must be an "
                            f"int >= 1")
    for path, sname in _policy_scheme_names(d.get("policy") or {}):
        if sname not in known:
            problems.append(
                f"{path}: unknown scheme {sname!r}; "
                f"registered: {list(known)}")
    if problems:
        raise PlanValidationError(
            f"ProtectionPlan JSON failed validation against the live "
            f"SchemeRegistry ({len(problems)} problem"
            f"{'s' if len(problems) != 1 else ''}):\n  - "
            + "\n  - ".join(problems))


def policy_from_json(d: dict) -> ProtectionPolicy:
    kind = d["kind"]
    if kind == "fixed":
        return FixedPolicy(as_scheme(d["scheme"]))
    if kind == "intensity":
        return IntensityGuidedPolicy(
            blocks=BlockShape(**d["blocks"]),
            candidates=tuple(d.get("candidates") or ()),
        )
    if kind == "profile":
        return ProfileGuidedPolicy(
            table=tuple(
                (GemmDims(**e["dims"]), e["scheme"]) for e in d["table"]),
            fallback=policy_from_json(d["fallback"]),
        )
    if kind == "adaptive":
        # reconstructed at level 0: runtime escalation state is engine
        # state, not deployment-artifact state
        return ErrorAdaptivePolicy(
            base=policy_from_json(d["base"]),
            escalated=policy_from_json(d["escalated"]),
            detection_threshold=d["detection_threshold"],
            hard_fault_threshold=d["hard_fault_threshold"],
            clear_factor=d["clear_factor"],
            deescalate_after=d["deescalate_after"],
            shrink_chunk=d.get("shrink_chunk", 1.0),
            shrink_draft=d.get("shrink_draft", 1.0),
        )
    raise ValueError(f"unknown policy kind {kind!r}")


# ------------------------------------------------------------------ the plan

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Plan-facing layer descriptor.  ``first`` is the EXPLICIT
    first-protected-layer flag (global ABFT pays an unfused read of A
    there, schemes.cost_global) — carried by the descriptor instead of
    inferred from enumeration order."""

    name: str
    dims: GemmDims
    count: int = 1
    first: bool = False


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    layer: LayerSpec
    selection: Selection


@dataclasses.dataclass(frozen=True)
class StepShape:
    """Geometry of one serving step's representative GEMM: the widest
    per-token projection (d_model x d_ff when an FFN exists)."""

    d_model: int
    d_ff: int
    dtype_bytes: int = 2


def as_layer_specs(layers) -> tuple:
    """Normalize plan input: an iterable of LayerSpec passes through; a
    legacy ``{name: GemmDims}`` mapping becomes descriptors with the
    first entry explicitly flagged ``first=True`` (what the old
    enumeration heuristic silently assumed)."""
    if isinstance(layers, Mapping):
        return tuple(
            LayerSpec(name=k, dims=v, first=(i == 0))
            for i, (k, v) in enumerate(layers.items())
        )
    return tuple(layers)


@dataclasses.dataclass(frozen=True)
class ProtectionPlan:
    """A ProtectionPolicy compiled against one (model, hardware, phase).

    Built once, consulted many times: per-layer selections are fixed at
    build; ``for_step`` / ``tune_chunk_budget`` memoize on top of the
    policy.  ``to_json``/``from_json`` round-trip the whole artifact —
    hardware spec, policy, layer descriptors, selections — so a plan can
    ship with a deployment and reproduce identical per-step schemes."""

    model: str
    phase: str
    hardware: HardwareSpec
    policy: ProtectionPolicy
    entries: tuple = ()
    step_shape: StepShape | None = None
    # tensor-parallel width the entries were compiled for: a plan built
    # with model_parallel=k describes ONE shard's post-sharding GEMMs
    # (TP shrinks per-device (m,k,n), so intensity — and the selected
    # scheme — legitimately differ between mesh widths)
    model_parallel: int = 1

    def __post_init__(self):
        object.__setattr__(self, "_step_cache", {})
        object.__setattr__(self, "_tune_cache", {})

    # ---------------------------------------------------------- builders
    @classmethod
    def build(cls, layers, hw: HardwareSpec = DEFAULT,
              policy: ProtectionPolicy | None = None, *,
              model: str = "adhoc", phase: str = "prefill",
              step_shape: StepShape | None = None) -> "ProtectionPlan":
        policy = policy or IntensityGuidedPolicy()
        specs = as_layer_specs(layers)
        entries = tuple(
            PlanEntry(ls, policy.select(ls.dims, hw, first_layer=ls.first))
            for ls in specs
        )
        return cls(model=model, phase=phase, hardware=hw, policy=policy,
                   entries=entries, step_shape=step_shape)

    @classmethod
    def for_model(cls, cfg, hw: HardwareSpec = DEFAULT,
                  policy: ProtectionPolicy | None = None, *,
                  phase: str = "prefill", n_tokens: int = 128,
                  dtype_bytes: int = 2,
                  model_parallel: int = 1) -> "ProtectionPlan":
        """Compile a plan for a ModelConfig: per-GEMM-site descriptors
        with the true first layer flagged from the model's layer plan.

        ``model_parallel=k`` compiles the plan from one device's
        POST-sharding GEMM shapes on a k-wide model axis
        (``counting.shard_gemms``) — the per-shard plan the sharded
        serving executor installs.  The step fast path shrinks with it:
        the representative per-token projection is column-parallel, so
        its n dim is d_ff/k per device."""
        from repro.models.counting import layer_specs

        mp = max(1, int(model_parallel))
        d_ff = cfg.d_ff or cfg.d_model
        if mp > 1 and d_ff % mp == 0:
            d_ff //= mp
        plan = cls.build(
            layer_specs(cfg, n_tokens, dtype_bytes=dtype_bytes,
                        model_parallel=mp),
            hw=hw, policy=policy, model=cfg.name, phase=phase,
            step_shape=StepShape(
                d_model=cfg.d_model, d_ff=d_ff, dtype_bytes=dtype_bytes),
        )
        if mp != 1:
            plan = dataclasses.replace(plan, model_parallel=mp)
        return plan

    # ---------------------------------------------------------- lookups
    def scheme_for(self, layer_name: str) -> str:
        for e in self.entries:
            if e.layer.name == layer_name:
                return e.selection.scheme_name
        raise KeyError(
            f"no layer {layer_name!r} in plan; layers: "
            f"{[e.layer.name for e in self.entries]}")

    def report_rows(self) -> list:
        """Human-readable per-layer table (the pre-deployment report)."""
        rows = []
        for e in self.entries:
            d, sel = e.layer.dims, e.selection
            rows.append({
                "layer": e.layer.name,
                "m": d.m, "k": d.k, "n": d.n, "batch": d.batch,
                "count": e.layer.count,
                "first": e.layer.first,
                "ai": round(sel.arithmetic_intensity, 2),
                "bound": ("compute"
                          if compute_bound_ai(
                              sel.arithmetic_intensity, self.hardware)
                          else "bandwidth"),
                "scheme": sel.scheme_name,
                "overheads_pct": {
                    k: round(v, 3)
                    for k, v in sel.modeled_overhead_pct.items()},
            })
        return rows

    # ------------------------------------------------------- serving fast path
    def step_dims(self, tokens: int) -> GemmDims:
        if self.step_shape is None:
            raise ValueError("plan has no step_shape; build it via "
                             "for_model() or pass step_shape= to build()")
        s = self.step_shape
        return step_gemm_dims(tokens, s.d_model, s.d_ff,
                              dtype_bytes=s.dtype_bytes)

    def step_intensity(self, tokens: int) -> float:
        return self.step_dims(tokens).arithmetic_intensity

    def modeled_step_time(self, tokens: int) -> float:
        """Roofline-modeled execution time of one step's representative
        GEMM under the scheme the policy selects for that composition
        (the throughput model behind the chunk-budget margin)."""
        sel = self.for_step(tokens)
        return protected_time(
            sel.scheme, self.step_dims(tokens), self.hardware)

    def for_step(self, decode_tokens: int,
                 prefill_tokens: int = 0) -> Selection:
        """Selection for one serving step's ACTUAL token composition
        (resident decode tokens + co-scheduled prefill-chunk tokens) —
        the cached fast path the engine consults every executed step.
        Intensity depends only on the total, so the cache is keyed by
        ``decode + prefill``."""
        tokens = int(decode_tokens) + int(prefill_tokens)
        sel = self._step_cache.get(tokens)
        if sel is None:
            sel = self.policy.select(self.step_dims(tokens), self.hardware)
            self._step_cache[tokens] = sel
        return sel

    def tune_chunk_budget(self, decode_tokens: int = 0, *, lo: int = 8,
                          hi: int = 4096, quantum: int = 8,
                          tput_margin: float | None = 0.1) -> int:
        """Roofline chunk-budget autotuning (ROADMAP item): the smallest
        per-step token budget that (a) clears the device CMR — strictly,
        via ``compute_bound_ai`` — AND (b) keeps modeled per-token step
        time within ``tput_margin`` of the best attainable budget under
        ``hi``.  (a) alone lands exactly on the roofline knee, where the
        redundant-work and fixed-op terms are not yet amortized; (b)
        walks just far enough past the knee that a fixed-budget sweep
        cannot beat the tuned budget's throughput by more than the
        margin.  ``tput_margin=None`` disables (b) and returns the bare
        crossing.

        The floor tracks occupancy: the budget always exceeds
        ``decode_tokens`` by at least one quantum, so resident decodes
        (packed first) can never starve prefill progress.  When the step
        geometry cannot reach the CMR below ``hi`` (small models, huge
        CMR), the cap is returned — the maximum-intensity budget
        attainable.  Budgets are quantized to ``quantum`` (the engine's
        chunk-length bucketing, serve/engine._pad_len)."""
        q = max(1, int(quantum))
        key = (int(decode_tokens), int(lo), int(hi), q, tput_margin)
        got = self._tune_cache.get(key)
        if got is not None:
            return got
        floor = max(int(lo), int(decode_tokens) + q)
        floor = -(-floor // q) * q
        cap = max(floor, (int(hi) // q) * q)

        def clears(b: int) -> bool:
            return compute_bound_ai(self.step_intensity(b), self.hardware)

        if clears(floor):
            best = floor
        elif not clears(cap):
            best = cap
        else:
            # AI is monotone in tokens: binary-search the crossing
            lo_b, hi_b = floor, cap          # !clears(lo_b), clears(hi_b)
            while hi_b - lo_b > q:
                mid = ((lo_b + hi_b) // 2) // q * q
                if mid <= lo_b:
                    mid = lo_b + q
                if clears(mid):
                    hi_b = mid
                else:
                    lo_b = mid
            best = hi_b
        if tput_margin is not None and best < cap:
            # per-token step time decreases as the budget amortizes the
            # scheme's fixed terms: advance until within the margin of
            # the cap's per-token time
            target = (1.0 + tput_margin) * self.modeled_step_time(cap) / cap
            while best < cap and \
                    self.modeled_step_time(best) / best > target:
                best += q
        self._tune_cache[key] = best
        return best

    def tune_draft_len(self, batch: int = 1, *, lo: int = 1, hi: int = 8,
                       accept_rate: float = 0.7,
                       tput_margin: float = 0.0) -> int:
        """Roofline draft-length autotuning for speculative decoding:
        the LARGEST K in ``[lo, hi]`` whose modeled per-EMITTED-token
        verify time beats plain decode's per-token time by at least
        ``tput_margin``.  A K-draft verify step scores ``batch * (K+1)``
        tokens through the same GEMMs as decode — K multiplies step
        intensity, so the modeled time comes from the SAME protected
        roofline (``modeled_step_time``) that drives scheme selection,
        and the chosen K shifts as the step crosses the CMR.  Expected
        tokens emitted per slot per verify step, with independent
        per-draft acceptance probability ``accept_rate`` = a:
        ``a(1-a^K)/(1-a) + 1`` (the accepted prefix plus the bonus
        token).  Returns 0 when no K wins — speculation cannot pay off
        on this hardware/occupancy point."""
        b = max(1, int(batch))
        a = min(max(float(accept_rate), 0.0), 1.0)
        key = ("draft", b, int(lo), int(hi), a, float(tput_margin))
        got = self._tune_cache.get(key)
        if got is not None:
            return got
        base = self.modeled_step_time(b) / b     # plain decode, s/token

        def per_token(k: int) -> float:
            emitted = (k + 1.0) if a >= 1.0 \
                else a * (1.0 - a ** k) / (1.0 - a) + 1.0
            return self.modeled_step_time(b * (k + 1)) / (b * emitted)

        best = 0
        for k in range(max(1, int(lo)), max(1, int(hi)) + 1):
            if per_token(k) < base * (1.0 - float(tput_margin)):
                best = k
        self._tune_cache[key] = best
        return best

    # ---------------------------------------------------------- serialization
    def to_json(self, indent: int | None = 2) -> str:
        payload = {
            "version": 1,
            "model": self.model,
            "phase": self.phase,
            "model_parallel": self.model_parallel,
            "hardware": dataclasses.asdict(self.hardware),
            "policy": self.policy.to_json(),
            "step_shape": (dataclasses.asdict(self.step_shape)
                           if self.step_shape is not None else None),
            "layers": [
                {
                    "name": e.layer.name,
                    "dims": dataclasses.asdict(e.layer.dims),
                    "count": e.layer.count,
                    "first": e.layer.first,
                    "scheme": e.selection.scheme_name,
                    "arithmetic_intensity": e.selection.arithmetic_intensity,
                    "cmr": e.selection.cmr,
                    "modeled_overhead_pct": e.selection.modeled_overhead_pct,
                    "reason": e.selection.reason,
                }
                for e in self.entries
            ],
        }
        return json.dumps(payload, indent=indent)

    @classmethod
    def from_json(cls, payload) -> "ProtectionPlan":
        d = json.loads(payload) if isinstance(payload, str) else payload
        validate_plan_payload(d)
        entries = tuple(
            PlanEntry(
                LayerSpec(name=e["name"], dims=GemmDims(**e["dims"]),
                          count=e["count"], first=e["first"]),
                Selection(
                    scheme=as_scheme(e["scheme"]),
                    arithmetic_intensity=e["arithmetic_intensity"],
                    cmr=e["cmr"],
                    modeled_overhead_pct=e["modeled_overhead_pct"],
                    reason=e["reason"]),
            )
            for e in d["layers"]
        )
        return cls(
            model=d["model"],
            phase=d["phase"],
            hardware=HardwareSpec(**d["hardware"]),
            policy=policy_from_json(d["policy"]),
            entries=entries,
            step_shape=(StepShape(**d["step_shape"])
                        if d.get("step_shape") else None),
            model_parallel=int(d.get("model_parallel", 1)),
        )
