"""ABFT scheme definitions and their analytic overhead models.

Schemes (paper §2.4–§5.2, adapted to TPU per DESIGN.md §2):

* ``NONE``        — unprotected GEMM.
* ``GLOBAL``      — global ABFT (Hari et al.-style): one column checksum of A,
                    one (offline) row checksum of B, scalar/vector check over
                    the whole GEMM.  Minimal redundant FLOPs; adds HBM reads
                    for the output summation (XLA cannot fuse a reduction
                    into the dot's epilogue on TPU) and a fixed check op.
* ``BLOCK_1S``    — one-sided block-level ABFT fused into the Pallas matmul
                    kernel: per-block checksum of the B tile (VPU), weighted
                    row-sum of the A tile against it (VPU), zero extra HBM
                    traffic.  TPU-native analogue of the paper's one-sided
                    thread-level ABFT.  Residual is a length-bm vector per
                    block → locates the faulty output row.
* ``BLOCK_2S``    — two-sided block-level ABFT: checksums of both tiles plus
                    a scalar dot; fewer VPU FLOPs than one-sided on TPU but
                    scalar (non-locating) residual per block.
* ``REPLICA``     — thread-level replication baseline (paper §4, 'replicated
                    MMA, single accumulation'): the block matmul is re-issued
                    on the MXU accumulating into a single vector.  Doubles
                    MXU work; included as the paper's strawman.

The analytic overhead model mirrors paper Table 1, re-derived for the TPU
execution model (MXU/VPU co-issue, XLA fusion; see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core.hardware import HardwareSpec
from repro.core.intensity import GemmDims, roofline_time


class Scheme(enum.Enum):
    NONE = "none"
    GLOBAL = "global"
    BLOCK_1S = "block_1s"
    BLOCK_2S = "block_2s"
    REPLICA = "replica"
    AUTO = "auto"  # resolved by the intensity-guided selector

    @property
    def is_block_level(self) -> bool:
        return self in (Scheme.BLOCK_1S, Scheme.BLOCK_2S, Scheme.REPLICA)


@dataclasses.dataclass(frozen=True)
class BlockShape:
    """Pallas tile sizes for the fused kernel (MXU-aligned multiples of 128
    on the minor dims; see kernels/abft_matmul.py)."""

    bm: int = 256
    bk: int = 512
    bn: int = 256


@dataclasses.dataclass(frozen=True)
class SchemeCost:
    """Redundant work added by a scheme on top of the plain GEMM."""

    flops_mxu: float      # extra matmul-unit FLOPs
    flops_vpu: float      # extra vector-unit FLOPs (checksum math)
    bytes_hbm: float      # extra HBM traffic
    fixed_ops: int        # extra *unfused* dispatched ops (checks, reduces)


def _grid(dims: GemmDims, blocks: BlockShape) -> tuple:
    """Effective grid extents (ceil-div; thin GEMMs clamp to one block)."""
    gm = max(1, -(-dims.m // blocks.bm))
    gn = max(1, -(-dims.n // blocks.bn))
    return gm, gn


def cost_none(
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    return SchemeCost(0.0, 0.0, 0.0, 0)


def cost_global(
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    # Online: activation checksum colsum(A) (fused unless first layer),
    # checksum product a_sum @ B -> (1, n) [the vector check, which also
    # *locates* the faulty column], output column-summation of C, and a
    # residual compare.  Weight checksum rowsum(B) is built offline.
    #
    # ``first_layer``: the activation checksum of A normally fuses into
    # the previous layer's epilogue; the first protected layer has no
    # producer to fuse with and pays an extra read of A.
    b, m, k, n = dims.batch, dims.m, dims.k, dims.n
    flops_vpu = b * (m * k + m * n)         # colsum(A) + colsum(C)
    flops_mxu = b * 2.0 * k * n             # a_sum @ B on the MXU
    bytes_hbm = b * float(m * n * dims.out_dtype_bytes)  # re-read C
    if first_layer:
        bytes_hbm += dims.bytes_a
    # separate check op: the reduction over C does not fuse into the
    # dot custom-call; the compare itself is tiny but dispatched.
    return SchemeCost(flops_mxu, flops_vpu, bytes_hbm, 2)


def cost_block_1s(
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    # Per k-step per block: b_sum (bk*bn adds, recomputed gm times),
    # weighted row-sum acc += A_tile @ b_sum as VPU multiply-add
    # (2*bm*bk, recomputed gn times), plus the magnitude accumulator for
    # the principled threshold (same cost again), plus final row-sum of
    # the output tile (bm*bn once per block).
    b, m, k, n = dims.batch, dims.m, dims.k, dims.n
    gm, gn = _grid(dims, blocks)
    flops_vpu = b * (
        gm * (k * n)            # b_sum recomputation across block rows
        + 2.0 * m * k * gn * 2  # weighted row-sum + |.| bound accumulator
        + m * n                 # output-tile row sums
    )
    bytes_hbm = b * float(gm * gn * 4 * 2)  # per-block residual flags
    return SchemeCost(0.0, flops_vpu, bytes_hbm, 0)


def cost_block_2s(
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    # a_sum per block (bm*bk per step, recomputed gn times), b_sum
    # (recomputed gm times), scalar dot (2*bk per step per block),
    # output-tile total sum (bm*bn per block).
    b, m, k, n = dims.batch, dims.m, dims.k, dims.n
    gm, gn = _grid(dims, blocks)
    flops_vpu = b * (
        m * k * gn
        + k * n * gm
        + 2.0 * k * gm * gn
        + m * n
    )
    bytes_hbm = b * float(gm * gn * 4 * 2)
    return SchemeCost(0.0, flops_vpu, bytes_hbm, 0)


def cost_replica(
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    # Replicated block matmul accumulating to a single vector: the MXU
    # work doubles (paper §4); comparison is in-register.
    b, m, n = dims.batch, dims.m, dims.n
    return SchemeCost(dims.flops, b * float(m * n), 0.0, 0)


def scheme_cost(
    scheme,
    dims: GemmDims,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> SchemeCost:
    """Analytic redundant-work model, per DESIGN.md §2 / paper Table 1.

    ``scheme`` is a Scheme enum or a registered scheme name; dispatch goes
    through the SchemeRegistry (core/policy.py), so a newly registered
    scheme's cost model participates here — and therefore in the
    intensity-guided selection — without touching this module."""
    if scheme in (Scheme.AUTO, "auto"):
        return SchemeCost(0.0, 0.0, 0.0, 0)
    from repro.core.policy import default_registry

    return default_registry().get(scheme).cost(dims, blocks, first_layer)


def protected_time(
    scheme: Scheme,
    dims: GemmDims,
    hw: HardwareSpec,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> float:
    """Modeled execution time of the GEMM protected by ``scheme``."""
    cost = scheme_cost(scheme, dims, blocks, first_layer)
    return roofline_time(
        flops_mxu=dims.flops + cost.flops_mxu,
        flops_vpu=cost.flops_vpu,
        bytes_hbm=dims.bytes_total + cost.bytes_hbm,
        hw=hw,
        fixed_ops=cost.fixed_ops,
    )


def overhead_pct(
    scheme: Scheme,
    dims: GemmDims,
    hw: HardwareSpec,
    blocks: BlockShape = BlockShape(),
    first_layer: bool = False,
) -> float:
    """Execution-time overhead percentage ((T_r - T_o) / T_o * 100), the
    paper's primary metric (§6.2)."""
    t_o = roofline_time(dims.flops, 0.0, dims.bytes_total, hw)
    t_r = protected_time(scheme, dims, hw, blocks, first_layer)
    return (t_r - t_o) / t_o * 100.0
