"""Canonical mesh construction — THE one place (data, model) device
meshes are built.

Every driver that needs a mesh (serve, train dry-run, elastic re-mesh,
benchmarks) routes through ``build_mesh``; ``launch/mesh.py`` keeps its
historical entry points as thin wrappers.  Centralizing construction
means the axis names, the device-count validation, and the
devices→grid reshape cannot drift between drivers — the serving
executor and the training dry-run agree on what ``("data", "model")``
means by construction.

Functions only (never module-level constants): importing this module
must not touch jax device state, because drivers set ``XLA_FLAGS``
before the first jax call.
"""

from __future__ import annotations

import jax

AXES = ("data", "model")
POD_AXES = ("pod", "data", "model")


def build_mesh(*, model: int = 1, data: int | None = None,
               pod: int | None = None, devices=None):
    """Build a (data, model) — or (pod, data, model) — mesh.

    ``model``: tensor/expert-parallel width (the axis ABFT plans are
    keyed on — TP changes per-device GEMM shapes and therefore scheme
    selection).  ``data``: data-parallel width; ``None`` means "as many
    replicas as the devices allow" (``n // model``).  ``devices``: an
    explicit device list (elastic re-mesh after failures); ``None``
    uses ``jax.devices()``.

    Raises ``RuntimeError`` when the device set cannot host the
    requested shape — never silently clamps ``model`` (a clamped model
    axis would invalidate every parameter shard layout downstream).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    if model < 1:
        raise ValueError(f"model_parallel must be >= 1, got {model}")
    if n < model:
        raise RuntimeError(
            f"not enough devices ({n}) for model_parallel={model}")
    if data is None:
        data = n // model
    shape = (pod, data, model) if pod is not None else (data, model)
    axes = POD_AXES if pod is not None else AXES
    need = 1
    for s in shape:
        need *= s
    if need > n:
        raise RuntimeError(
            f"mesh shape {shape} needs {need} devices, have {n}")
    import numpy as np
    from jax.sharding import Mesh

    grid = np.array(devices[:need]).reshape(shape)
    return Mesh(grid, axes)


def make_hints(cfg, mesh):
    """ShardingHints for a model on this mesh — the layer-level
    ``with_sharding_constraint`` annotations (MoE dispatch buffers)
    that GSPMD propagation needs help with.  Shared by the serving
    executor and the training dry-run."""
    from repro.distributed import sharding as shd
    from repro.models.layers import ShardingHints

    ba = shd.batch_axes(mesh)
    dp_size = 1
    for a in ba:
        dp_size *= mesh.shape[a]
    ep_fits = (cfg.n_experts % mesh.shape["model"] == 0) \
        if cfg.n_experts else True
    return ShardingHints(
        dp=ba,
        dp_size=dp_size,
        ep=("model",),
        moe_mode="ep" if ep_fits else "tp",
    )
