"""Distribution: sharding rules, mesh construction, collectives helpers."""

from repro.distributed.sharding import (
    batch_axes,
    batch_specs,
    cache_specs,
    expert_axes,
    logits_spec,
    make_sharding,
    opt_state_specs,
    param_specs,
)

__all__ = [
    "batch_axes",
    "batch_specs",
    "cache_specs",
    "expert_axes",
    "logits_spec",
    "make_sharding",
    "opt_state_specs",
    "param_specs",
]
