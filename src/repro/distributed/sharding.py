"""Sharding rules: logical-parameter → PartitionSpec mapping for the
production mesh (DESIGN.md §5).

Axis roles:
  pod   — outer data parallelism across pods (multi-pod mesh only)
  data  — data parallelism; FSDP weight sharding for >=20B models; the
          second expert-parallel axis for deepseek's 256 experts
  model — tensor parallelism (heads / ffn / vocab) + expert parallelism

Specs are constructed by name-based rules over the params pytree, with the
stacked segment dim (scan) prepended as None.  GSPMD tolerates non-divisible
shardings (it pads), so kv_heads=8 over model=16 etc. are accepted.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD = 20e9   # params; above this, weights shard over 'data' too


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _is_fsdp(cfg: ModelConfig) -> bool:
    from repro.models.counting import count_params

    return count_params(cfg) >= FSDP_THRESHOLD


def expert_axes(cfg: ModelConfig, mesh: Mesh) -> tuple:
    """Expert-parallel axis.  Experts shard over 'model' (matching the
    group-local MoE dispatch buffer, whose group dim owns 'data'); large
    MoE configs (deepseek) additionally FSDP the expert D dim over 'data'
    via the fsdp flag, giving 256-way effective weight sharding."""
    return ("model",)


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes do not divide — explicit
    NamedShardings (unlike internal GSPMD propagation) require exact
    divisibility."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry if i < len(shape) else None)
            continue
        if shape[i] % _axes_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out[: len(shape)])


def _param_rule(path: str, ndim: int, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool) -> P:
    """Spec for one (unstacked) parameter leaf, by trailing path name."""
    name = path.split("/")[-1]
    e_ax = expert_axes(cfg, mesh)
    # experts that don't divide the EP axes fall back to intra-expert TP
    # (shard the expert FFN dim over 'model' — qwen2-moe's 60 experts)
    ep_fits = cfg.n_experts % _axes_size(mesh, e_ax) == 0 \
        if cfg.n_experts else True
    d = "data" if fsdp else None

    table = {
        # embeddings / head
        "embed": P("model", d),
        "lm_head": P(d, "model"),
        "vision_proj": P(None, None),
        # attention
        "wq": P(d, "model"), "wk": P(d, "model"), "wv": P(d, "model"),
        "wo": P("model", d),
        "bq": P("model"), "bk": P("model"), "bv": P("model"),
        "q_norm": P(None), "k_norm": P(None),
        # mla
        "wq_a": P(d, None), "wq_b": P(d, "model"),
        "wkv_a": P(d, None),
        "q_a_norm": P(None), "kv_a_norm": P(None),
        "w_uk": P("model", None, None), "w_uv": P("model", None, None),
        # mlp
        "up": P(d, "model"), "gate": P(d, "model"), "down": P("model", d),
        "up_b": P("model"), "down_b": P(None),
        # moe: EP when experts divide the model axis; else TP on the
        # expert ffn dim (qwen2-moe's 60 experts over a 16-wide axis)
        "router": P(None, None),
        "w_up": P(e_ax, d, None) if ep_fits else P(None, d, "model"),
        "w_gate": P(e_ax, d, None) if ep_fits else P(None, d, "model"),
        "w_down": P(e_ax, None, d) if ep_fits else P(None, "model", d),
        # mamba
        "in_z": P(d, "model"), "in_x": P(d, "model"),
        "in_bc": P(d, None), "in_dt": P(d, "model"),
        "conv_x_w": P(None, "model"), "conv_x_b": P("model"),
        "conv_bc_w": P(None, None), "conv_bc_b": P(None),
        "A_log": P("model"), "D": P("model"), "dt_bias": P("model"),
        "out_norm": P("model"), "out_proj": P("model", d),
        # misc
        "proj": P(None, None),        # mtp projection
        "cross_gate": P(),
    }
    if name in table:
        spec = table[name]
        # trim/extend to leaf rank (biases under mlp rules etc.)
        if len(spec) > ndim:
            spec = P(*spec[:ndim])
        return spec
    # norms and anything unmatched: replicate
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for pp in path:
        if hasattr(pp, "key"):
            parts.append(str(pp.key))
        elif hasattr(pp, "idx"):
            parts.append(str(pp.idx))
        else:
            parts.append(str(pp))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh,
                fsdp: bool | None = None):
    """PartitionSpec pytree matching a params (shape) pytree."""
    fsdp = _is_fsdp(cfg) if fsdp is None else fsdp

    def one(path, leaf):
        ps = _path_str(path)
        stacked = "segments" in ps
        ndim = len(leaf.shape) - (1 if stacked else 0)
        spec = _param_rule(ps, ndim, cfg, mesh, fsdp)
        if stacked:
            spec = P(None, *spec)
        return sanitize_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_specs(cfg: ModelConfig, params_shape, mesh: Mesh):
    """ZeRO-1: optimizer moments always carry the FSDP ('data') sharding,
    regardless of model size — distributed optimizer state."""
    return param_specs(cfg, params_shape, mesh, fsdp=True)


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    ba = batch_axes(mesh)
    specs = {"tokens": P(ba, None), "labels": P(ba, None)}
    if cfg.is_encoder_decoder:
        specs["enc_input"] = P(ba, None, None)
    if cfg.vision_dim:
        specs["images"] = P(ba, None, None)
    return specs


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh, batch: int,
                kv_fallback: str = "headdim", paged: bool = False):
    """KV/state cache specs.  If the batch cannot cover the data axes
    (long-context B=1), shard the cache *sequence* dim over 'data' instead
    (context parallelism for decode).

    ``kv_fallback`` picks the layout when kv_heads do not divide the model
    axis: 'headdim' shards head_dim (baseline; forces per-layer cache
    resharding in decode attention), 'replicate' leaves the cache
    model-replicated so attention runs fully local per q-head shard with
    one small all-reduce at the output projection (perf iteration A1).

    ``paged=True`` maps the BLOCK-POOL layout (serve/paged_cache.py):
    attention k/v pools are ``(num_blocks, block_size, KV, hd)`` — the
    leading dims are pool geometry, not batch, so they stay replicated
    and only the kv-head dim shards over 'model'.  Every device holds
    its head-shard of EVERY block; the host block table stays one
    logical table (replicated) indexing all of them — per-device KV
    shards behind one logical table.  Per-slot state leaves (mamba
    conv/ssm, MLA latent, cross KV) keep the dense rules: their leading
    dim really is the slot/batch dim."""
    ba = batch_axes(mesh)
    dsize = 1
    for a in ba:
        dsize *= mesh.shape[a]
    seq_shard = batch < dsize
    b_ax = None if seq_shard else ba
    s_ax = "data" if seq_shard else None

    def one(path, leaf):
        ps = _path_str(path)
        parts = ps.split("/")
        name = parts[-1]
        # pool leaves sit under an "attn" subtree; cross-attention KV
        # (also named k/v) is per-slot and keeps the dense rules even
        # on a paged engine
        pooled = paged and "attn" in parts[:-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):          # (B, S, KV, hd) | (NB, BS, KV, hd)
            kv = leaf.shape[-2]
            kb, ks = (None, None) if pooled else (b_ax, s_ax)
            if kv % mesh.shape["model"] == 0:
                core = P(kb, ks, "model", None)
            elif kv_fallback == "replicate":
                core = P(kb, ks, None, None)
            else:
                core = P(kb, ks, None, "model")
        elif name in ("c_kv", "k_pe", "latent"):  # (B|NB, S|BS, c)
            core = P(None, None, None) if pooled else P(b_ax, s_ax, None)
        elif name == "conv_x":          # (B, W-1, d_in) — per-slot
            core = P(b_ax, None, "model")
        elif name == "conv_bc":
            core = P(b_ax, None, None)
        elif name == "ssm":             # (B, H, P, N) — per-slot
            core = P(b_ax, "model", None, None)
        else:
            return P(*([None] * nd))
        if len(core) < nd:              # leading segment-stack dim
            core = P(*([None] * (nd - len(core))), *core)
        return sanitize_spec(core, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_spec(mesh: Mesh, batch: int) -> P:
    ba = batch_axes(mesh)
    dsize = 1
    for a in ba:
        dsize *= mesh.shape[a]
    if batch < dsize:
        return P(None, None, "model")
    return P(ba, None, "model")


def make_sharding(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
