"""Llama-3.2-1B — small llama3, GQA kv=8.  [hf:meta-llama/Llama-3.2-1B;
unverified]"""

from repro.configs.base import ModelConfig, register


@register("llama3.2-1b")
def llama3_2_1b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        source="hf:meta-llama/Llama-3.2-1B",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        norm="rmsnorm",
        rope_theta=500_000.0,
        tie_embeddings=True,
    )
