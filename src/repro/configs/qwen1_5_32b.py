"""Qwen1.5-32B — dense, MHA kv=40, QKV bias.  [hf:Qwen/Qwen1.5-32B; hf]"""

from repro.configs.base import ModelConfig, register


@register("qwen1.5-32b")
def qwen1_5_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        source="hf:Qwen/Qwen1.5-32B",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        norm="rmsnorm",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
