"""Assigned-architecture configs.  Importing this package registers all
architectures with the ``--arch`` registry in configs/base.py."""

from repro.configs import (  # noqa: F401
    deepseek_v3_671b,
    jamba_v0_1_52b,
    llama3_2_1b,
    llama3_2_vision_11b,
    mamba2_1_3b,
    qwen1_5_32b,
    qwen2_moe_a2_7b,
    qwen3_14b,
    stablelm_1_6b,
    whisper_tiny,
)
from repro.configs.base import ModelConfig, get_config, list_archs, scaled_down

ALL_ARCHS = [
    "qwen3-14b",
    "stablelm-1.6b",
    "llama3.2-1b",
    "qwen1.5-32b",
    "jamba-v0.1-52b",
    "whisper-tiny",
    "mamba2-1.3b",
    "deepseek-v3-671b",
    "qwen2-moe-a2.7b",
    "llama-3.2-vision-11b",
]

__all__ = [
    "ALL_ARCHS",
    "ModelConfig",
    "get_config",
    "list_archs",
    "scaled_down",
]
