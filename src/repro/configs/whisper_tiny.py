"""Whisper-tiny — encoder-decoder audio backbone.  The conv frontend
(two width-3 1-D convs over n_mels=80 log-mel frames) is real when the
batch carries ``audio``; precomputed ``enc_input`` frame embeddings
remain accepted as the stub path.  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig, register


@register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        source="arXiv:2212.04356",
        n_layers=4,            # decoder layers
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        norm="layernorm",
        norm_eps=1e-5,
        act="gelu",
        is_encoder_decoder=True,
        enc_seq_len=1500,
        n_mels=80,
        rope_theta=0.0,        # whisper uses learned/sinusoidal positions
    )
