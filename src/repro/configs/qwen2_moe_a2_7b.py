"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed experts top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.configs.base import ModelConfig, register


@register("qwen2-moe-a2.7b")
def qwen2_moe_a2_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=5632,               # shared-expert path width (4x1408)
        vocab_size=151936,
        norm="rmsnorm",
        n_experts=60,
        n_shared_experts=4,
        experts_per_token=4,
        moe_d_ff=1408,
        moe_every=1,
        rope_theta=1_000_000.0,
    )
