"""Mamba2-1.3B — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig, register


@register("mamba2-1.3b")
def mamba2_1_3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        source="arXiv:2405.21060",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,                # attention-free, no MLP blocks
        vocab_size=50280,
        norm="rmsnorm",
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        ssm_conv_width=4,
        tie_embeddings=True,
    )
