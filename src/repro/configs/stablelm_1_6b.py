"""StableLM-2-1.6B — dense, MHA (kv=32), LayerNorm, partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.configs.base import ModelConfig, register


@register("stablelm-1.6b")
def stablelm_1_6b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab_size=100352,
        norm="layernorm",
        norm_eps=1e-5,
        rope_pct=0.25,
        rope_theta=10000.0,
    )
