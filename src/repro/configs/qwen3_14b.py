"""Qwen3-14B — dense, GQA kv=8, qk_norm.  [hf:Qwen/Qwen3-14B; hf]"""

from repro.configs.base import ModelConfig, register


@register("qwen3-14b")
def qwen3_14b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        source="hf:Qwen/Qwen3-14B",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        norm="rmsnorm",
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )
