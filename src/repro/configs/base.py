"""Model configuration schema + registry for the assigned architectures.

One ``ModelConfig`` describes any architecture in the pool: dense decoder
LMs, MoE, hybrid SSM+attention, pure SSM, encoder-decoder, and VLM
backbones.  Every architecture registers itself via ``register``; the
launcher resolves ``--arch <id>`` through ``get_config``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    source: str = ""                # provenance note ([hf:...]/[arXiv:...])

    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"               # silu (SwiGLU) | gelu (plain MLP)
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope_theta: float = 10000.0
    rope_pct: float = 1.0           # stablelm-2: partial rotary (25%)
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    moe_every: int = 1              # every k-th layer is MoE (jamba: 2)
    first_dense_layers: int = 0     # deepseek-v3: 3
    capacity_factor: float = 1.25

    # attention flavor
    attention: str = "gqa"          # gqa | mla
    q_lora_rank: int = 0            # MLA
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0              # deepseek multi-token prediction heads

    # SSM (mamba2 / jamba mamba layers)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    attn_every: int = 0             # jamba: 1 attention layer per 8
    attn_offset: int = 0            # index within the period that is attn

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 1500         # whisper audio frames after conv stem
    n_mels: int = 0                 # log-mel bins feeding the conv stem
                                    # (0: stem disabled, enc_input stub)

    # VLM (llama-3.2-vision): cross-attention every k-th layer
    cross_attn_every: int = 0
    vision_dim: int = 0
    n_image_tokens: int = 1601      # 448/14 patches + cls, per tile

    # numerics
    dtype: str = "bfloat16"

    # TP head padding (perf feature, EXPERIMENTS.md §Perf): pad attention
    # heads with zero-weighted extras so head counts divide the model axis
    # — mathematically exact (padded wo rows are zero), eliminates
    # per-layer head-dim resharding when n_heads % tp != 0.
    pad_heads_to: int = 0
    pad_kv_heads_to: int = 0

    @property
    def eff_heads(self) -> int:
        return max(self.pad_heads_to, self.n_heads)

    @property
    def eff_kv_heads(self) -> int:
        kv = max(self.pad_kv_heads_to, self.n_kv_heads)
        # GQA requires eff_heads % eff_kv_heads == 0
        return kv

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, idx: int) -> str:
        """Static per-layer structure: 'attn' | 'mamba' for hybrid stacks,
        and 'dense' | 'moe' for the FFN slot."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid" and self.attn_every:
            return (
                "attn" if idx % self.attn_every == self.attn_offset
                else "mamba"
            )
        return "attn"

    def ffn_kind(self, idx: int) -> str:
        if not self.n_experts:
            return "dense"
        if idx < self.first_dense_layers:
            return "dense"
        if (idx - self.first_dense_layers) % max(self.moe_every, 1) == 0 \
                or self.moe_every == 1:
            return "moe"
        return "dense"

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and reporting)."""
        from repro.models.counting import count_params

        return count_params(self)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch registration)

    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    defaults = dict(
        n_layers=min(cfg.n_layers, 2 * max(cfg.moe_every, 1)
                     * max(cfg.attn_every, 1) if cfg.attn_every else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        vocab_size=256,
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq_len=16 if cfg.is_encoder_decoder else cfg.enc_seq_len,
        n_mels=8 if cfg.n_mels else 0,
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=8,
        vision_dim=32 if cfg.vision_dim else 0,
        n_image_tokens=8 if cfg.vision_dim else cfg.n_image_tokens,
        first_dense_layers=min(cfg.first_dense_layers, 1),
    )
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
