"""Jamba-v0.1 (52B) — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer.  [arXiv:2403.19887; hf]"""

from repro.configs.base import ModelConfig, register


@register("jamba-v0.1-52b")
def jamba_v0_1_52b() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        norm="rmsnorm",
        # MoE: 16 experts, top-2, every other layer
        n_experts=16,
        experts_per_token=2,
        moe_d_ff=14336,
        moe_every=2,
        # hybrid: 1 attention layer per 8 (offset 4 within each block)
        attn_every=8,
        attn_offset=4,
        # mamba sublayers (mamba-1-style params modeled with the SSD block)
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=128,
        rope_theta=10000.0,
    )
