"""Llama-3.2-11B-Vision — text backbone with cross-attention image layers
every 5th layer; vision frontend is a stub (input_specs provides patch
embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.configs.base import ModelConfig, register


@register("llama-3.2-vision-11b")
def llama3_2_vision_11b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        norm="rmsnorm",
        rope_theta=500_000.0,
        cross_attn_every=5,
        vision_dim=1280,
        n_image_tokens=1601,
    )
