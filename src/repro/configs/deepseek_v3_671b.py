"""DeepSeek-V3 (671B) — MLA attention, 1 shared + 256 routed experts top-8,
MTP.  [arXiv:2412.19437; hf]"""

from repro.configs.base import ModelConfig, register


@register("deepseek-v3-671b")
def deepseek_v3_671b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        source="arXiv:2412.19437",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,              # dense FFN width (first 3 layers)
        vocab_size=129280,
        norm="rmsnorm",
        # MoE
        n_experts=256,
        n_shared_experts=1,
        experts_per_token=8,
        moe_d_ff=2048,
        moe_every=1,
        first_dense_layers=3,
        # MLA
        attention="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        head_dim=192,            # qk_nope + qk_rope
        mtp_depth=1,
        rope_theta=10000.0,
    )
