"""jit-ready wrappers around the fused ABFT matmul kernel.

Handles shape padding to block multiples, block-size clamping for thin
GEMMs, fault-spec translation to block coordinates, residual thresholding,
and interpret-mode selection (interpret=True everywhere except a real TPU
backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.checksums import ATOL, CheckResult, flag_from, tolerance_scale
from repro.core.faults import FaultSpec
from repro.core.schemes import BlockShape
from repro.kernels.abft_matmul import F32, abft_matmul_kernel


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _clamp_block(dim: int, block: int, align: int = 8) -> int:
    """Shrink a block to the (aligned) problem size for thin GEMMs so we do
    not burn VMEM on padding."""
    return min(block, _round_up(dim, align))


def _pad2d(a: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    pm, pn = m - a.shape[0], n - a.shape[1]
    if pm == 0 and pn == 0:
        return a
    return jnp.pad(a, ((0, pm), (0, pn)))


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "bm", "bk", "bn", "out_dtype", "interpret", "c_factor"),
)
def _abft_matmul_padded(
    x, w, fault_idx, fault_val, *, mode, bm, bk, bn, out_dtype, interpret,
    c_factor,
):
    y, res, bnd = abft_matmul_kernel(
        x, w, fault_idx, fault_val,
        bm=bm, bk=bk, bn=bn, mode=mode, out_dtype=out_dtype,
        interpret=interpret,
    )
    k = x.shape[1]
    tau = ATOL + tolerance_scale(k, c=c_factor) * bnd
    flag = flag_from(res, tau)
    return y, res, tau, flag


def abft_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mode: str = "1s",
    blocks: BlockShape = BlockShape(),
    out_dtype=None,
    interpret: bool | None = None,
    fault: FaultSpec | None = None,
    c_factor: float = 16.0,
):
    """Fused-ABFT matmul: ``y = x @ w`` plus an in-kernel integrity check.

    x: (..., m, k) — leading dims are flattened into the GEMM M dim.
    w: (k, n).
    Returns (y, CheckResult).  ``CheckResult.residual`` is per (block, row)
    for one-sided mode — enough to locate the faulty output row.
    """
    if interpret is None:
        interpret = default_interpret()
    out_dtype = out_dtype or x.dtype

    *lead, m0, k0 = x.shape
    kw, n0 = w.shape
    assert k0 == kw, (x.shape, w.shape)
    x2 = x.reshape((-1, k0))
    m = x2.shape[0]

    bm = _clamp_block(m, blocks.bm)
    bk = _clamp_block(k0, blocks.bk)
    bn = _clamp_block(n0, blocks.bn)
    mp, kp, np_ = _round_up(m, bm), _round_up(k0, bk), _round_up(n0, bn)
    x2 = _pad2d(x2, mp, kp)
    wp = _pad2d(w, kp, np_)

    if fault is None:
        fault = FaultSpec.none()
    # Translate global output coordinates to (block, offset) pairs.
    fi = fault.row // bm
    fr = fault.row % bm
    fj = fault.col // bn
    fc = fault.col % bn
    fault_idx = jnp.stack(
        [fi, fj, fr, fc, fault.enabled, fault.bit,
         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)]
    ).astype(jnp.int32)
    fault_val = fault.delta.reshape((1,)).astype(F32)

    y, res, tau, flag = _abft_matmul_padded(
        x2, wp, fault_idx, fault_val,
        mode=mode, bm=bm, bk=bk, bn=bn,
        out_dtype=jnp.dtype(out_dtype), interpret=interpret,
        c_factor=c_factor,
    )
    y = y[:m, :n0].reshape((*lead, m0, n0))
    return y, CheckResult(flag=flag, residual=res, threshold=tau)
