"""Pallas TPU kernel: flash attention with *fused in-VMEM ABFT*.

This is the "shared next lever" identified by the §Perf iterations: every
train/prefill cell is memory-bound on attention score-chunk HBM round
trips, and the paper's design principle (§3.5: add no memory traffic)
applies to attention's two GEMMs exactly as it does to linear layers:

  S = Q K^T   — protected by a one-sided checksum of the K tile:
                 chk_s = Q @ rowsum(K_tile)  vs  rowsum(S_tile),
                 checked per (q_block, k_block) while S is in VMEM;
  O = P V     — protected through the online-softmax rescaling: the
                 checksum accumulator rescales with the same correction
                 factor as the output accumulator, so
                 chk_pv = Σ corr·(P @ rowsum(V_tile))  vs  rowsum(acc)
                 holds at the end of the K loop.

The softmax itself is nonlinear (ABFT does not traverse exp); the paper's
treatment (replicate nonlinear ops) applies — here the exp/max/sum chain
is a small VPU computation whose inputs and outputs are *both* covered by
the two GEMM checks, bounding undetected-fault propagation to the
elementwise stage.

Kernel structure: grid (num_q_blocks, num_k_blocks), K innermost; online
softmax state (m, l), f32 accumulators, ABFT accumulators and magnitude
bounds in VMEM scratch.  Causal masking by absolute block positions.
Single-head 2-D problem; ops.py wrappers vmap over (batch, heads).
Validated in interpret mode against ref.py (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, fault_ref,            # inputs
    o_ref, res_s_ref, bnd_s_ref, res_pv_ref, bnd_pv_ref,   # outputs
    m_ref, l_ref, acc_ref, chk_ref, bndc_ref, ress_ref, bnds_ref,  # scratch
    *, gk: int, bq: int, bk: int, causal: bool, scale: float,
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)
        bndc_ref[...] = jnp.zeros_like(bndc_ref)
        ress_ref[...] = jnp.zeros_like(ress_ref)
        bnds_ref[...] = jnp.zeros_like(bnds_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    qf = q.astype(F32)
    kf = k.astype(F32)
    vf = v.astype(F32)

    # ---- QK^T on the MXU, f32 accumulation
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * scale

    # ---- ABFT check #1: scores vs K-tile checksum (VPU)
    k_sum = jnp.sum(kf, axis=0)                    # (d,)
    k_abs = jnp.sum(jnp.abs(kf), axis=0)
    chk_s = jnp.sum(qf * k_sum[None, :], axis=1) * scale       # (bq,)
    bnd_s = jnp.sum(jnp.abs(qf) * k_abs[None, :], axis=1) * abs(scale)
    res_here = jnp.abs(chk_s - jnp.sum(s, axis=1))
    ress_ref[...] = jnp.maximum(ress_ref[...], res_here)
    bnds_ref[...] = jnp.maximum(bnds_ref[...], bnd_s)

    # ---- causal mask by absolute positions
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    # ---- online softmax update
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    # ---- PV on the MXU + ABFT check #2 accumulators (VPU), with the
    # same rescaling so the invariant survives the online softmax
    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    v_sum = jnp.sum(vf, axis=1)                    # (bk,)
    v_abs = jnp.sum(jnp.abs(vf), axis=1)
    chk_ref[...] = chk_ref[...] * corr + jnp.sum(p * v_sum[None, :], axis=1)
    bndc_ref[...] = bndc_ref[...] * corr + jnp.sum(p * v_abs[None, :],
                                                   axis=1)

    @pl.when(ki == gk - 1)
    def _finalize():
        acc = acc_ref[...]
        # optional fault: corrupt the output accumulator only (the ABFT
        # data path consumed the same tiles independently)
        fi = fault_ref[...]
        here = (fi[4] == 1) & (fi[0] == qi)
        rows = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
        mask = (rows == fi[2]) & (cols == fi[3]) & here
        acc = jnp.where(
            mask, acc + jax.lax.bitcast_convert_type(fi[5], F32), acc)

        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
        res_pv_ref[0, :] = jnp.abs(chk_ref[...] - jnp.sum(acc, axis=1))
        bnd_pv_ref[0, :] = bndc_ref[...]
        res_s_ref[0, :] = ress_ref[...]
        bnd_s_ref[0, :] = bnds_ref[...]


def _decode_kernel(
    q_ref, k_ref, v_ref, len_ref,                  # inputs
    o_ref, res_s_ref, bnd_s_ref, res_pv_ref, bnd_pv_ref,   # outputs
    m_ref, l_ref, acc_ref, chk_ref, bndc_ref, ress_ref, bnds_ref,  # scratch
    *, gk: int, bk: int, scale: float,
):
    """Single-query decode tile: one q row against a length-masked KV
    cache, K-blocks innermost, with the same two fused ABFT checks as the
    full kernel (scores vs K-tile checksum; PV via the rescaled checksum
    accumulator).  ``len_ref`` holds the per-row valid cache length — the
    vectorized serving cursor lands here, so slots with different prompt
    lengths read only their own prefix."""
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)
        bndc_ref[...] = jnp.zeros_like(bndc_ref)
        ress_ref[...] = jnp.zeros_like(ress_ref)
        bnds_ref[...] = jnp.zeros_like(bnds_ref)

    q = q_ref[...]                                 # (1, d)
    k = k_ref[...]                                 # (bk, d)
    v = v_ref[...]                                 # (bk, dv)
    qf = q.astype(F32)
    kf = k.astype(F32)
    vf = v.astype(F32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * scale

    # ABFT check #1 on the unmasked scores (masking is not part of the GEMM)
    k_sum = jnp.sum(kf, axis=0)
    k_abs = jnp.sum(jnp.abs(kf), axis=0)
    chk_s = jnp.sum(qf * k_sum[None, :], axis=1) * scale
    bnd_s = jnp.sum(jnp.abs(qf) * k_abs[None, :], axis=1) * abs(scale)
    res_here = jnp.abs(chk_s - jnp.sum(s, axis=1))
    ress_ref[...] = jnp.maximum(ress_ref[...], res_here)
    bnds_ref[...] = jnp.maximum(bnds_ref[...], bnd_s)

    # per-row length mask: only the slot's own valid prefix participates
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(k_pos < len_ref[0], s, NEG_INF)

    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    v_sum = jnp.sum(vf, axis=1)
    v_abs = jnp.sum(jnp.abs(vf), axis=1)
    chk_ref[...] = chk_ref[...] * corr + jnp.sum(p * v_sum[None, :], axis=1)
    bndc_ref[...] = bndc_ref[...] * corr + jnp.sum(p * v_abs[None, :],
                                                   axis=1)

    @pl.when(ki == gk - 1)
    def _finalize():
        acc = acc_ref[...]
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
        res_pv_ref[...] = jnp.abs(chk_ref[...] - jnp.sum(acc, axis=1))
        bnd_pv_ref[...] = bndc_ref[...]
        res_s_ref[...] = ress_ref[...]
        bnd_s_ref[...] = bnds_ref[...]


def flash_decode_kernel(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    bk: int,
    scale: float | None = None,
    out_dtype=None,
    interpret: bool = True,
):
    """Single-head fused-ABFT decode attention.

    q: (1, d); k: (S, d); v: (S, dv) — S padded to a bk multiple;
    length: (1,) int32 valid cache length for this row.
    Returns (o (1, dv), res_s, bnd_s, res_pv, bnd_pv), each check vector
    of shape (1,).
    """
    _, d = q.shape
    S, dv = v.shape
    assert S % bk == 0, (S, bk)
    gk = S // bk
    scale = scale if scale is not None else d ** -0.5
    out_dtype = out_dtype or q.dtype

    kernel = functools.partial(_decode_kernel, gk=gk, bk=bk, scale=scale)
    vec_spec = pl.BlockSpec((1,), lambda j: (0,))
    o, rs, bs, rp, bp = pl.pallas_call(
        kernel,
        grid=(gk,),
        in_specs=[
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((bk, d), lambda j: (j, 0)),
            pl.BlockSpec((bk, dv), lambda j: (j, 0)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, dv), lambda j: (0, 0)),
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, dv), out_dtype),
            jax.ShapeDtypeStruct((1,), F32),
            jax.ShapeDtypeStruct((1,), F32),
            jax.ShapeDtypeStruct((1,), F32),
            jax.ShapeDtypeStruct((1,), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1,), F32),        # m
            pltpu.VMEM((1,), F32),        # l
            pltpu.VMEM((1, dv), F32),     # acc
            pltpu.VMEM((1,), F32),        # pv checksum
            pltpu.VMEM((1,), F32),        # pv bound
            pltpu.VMEM((1,), F32),        # scores residual (max over k)
            pltpu.VMEM((1,), F32),        # scores bound
        ],
        interpret=interpret,
    )(q, k, v, length)
    return o, rs, bs, rp, bp


def flash_attention_kernel(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    fault: jnp.ndarray,
    *,
    bq: int,
    bk: int,
    causal: bool = True,
    scale: float | None = None,
    out_dtype=None,
    interpret: bool = True,
):
    """Single-head fused-ABFT flash attention.

    q: (Lq, d), k: (Lk, d), v: (Lk, dv) — padded to block multiples.
    fault: (6,) int32 [q_block, _, row, col, enabled, delta_bits].
    Returns (o (Lq, dv), res_s, bnd_s, res_pv, bnd_pv) with per-q-row
    residual/bound vectors of shape (gq, bq).
    """
    Lq, d = q.shape
    Lk, dv = v.shape
    assert Lq % bq == 0 and Lk % bk == 0, ((Lq, Lk), (bq, bk))
    gq, gk = Lq // bq, Lk // bk
    scale = scale if scale is not None else d ** -0.5
    out_dtype = out_dtype or q.dtype

    kernel = functools.partial(
        _kernel, gk=gk, bq=bq, bk=bk, causal=causal, scale=scale)
    vec_spec = pl.BlockSpec((1, bq), lambda i, j: (i, 0))
    o, rs, bs, rp, bp = pl.pallas_call(
        kernel,
        grid=(gq, gk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, dv), lambda i, j: (j, 0)),
            pl.BlockSpec((6,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, dv), lambda i, j: (i, 0)),
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lq, dv), out_dtype),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),       # m
            pltpu.VMEM((bq,), F32),       # l
            pltpu.VMEM((bq, dv), F32),    # acc
            pltpu.VMEM((bq,), F32),       # pv checksum
            pltpu.VMEM((bq,), F32),       # pv bound
            pltpu.VMEM((bq,), F32),       # scores residual (max over k)
            pltpu.VMEM((bq,), F32),       # scores bound
        ],
        interpret=interpret,
    )(q, k, v, fault)
    return o, rs, bs, rp, bp
