"""Pallas TPU kernel: flash attention with *fused in-VMEM ABFT*.

This is the "shared next lever" identified by the §Perf iterations: every
train/prefill cell is memory-bound on attention score-chunk HBM round
trips, and the paper's design principle (§3.5: add no memory traffic)
applies to attention's two GEMMs exactly as it does to linear layers:

  S = Q K^T   — protected by a one-sided checksum of the K tile:
                 chk_s = Q @ rowsum(K_tile)  vs  rowsum(S_tile),
                 checked per (q_block, k_block) while S is in VMEM;
  O = P V     — protected through the online-softmax rescaling: the
                 checksum accumulator rescales with the same correction
                 factor as the output accumulator, so
                 chk_pv = Σ corr·(P @ rowsum(V_tile))  vs  rowsum(acc)
                 holds at the end of the K loop.

The softmax itself is nonlinear (ABFT does not traverse exp); the paper's
treatment (replicate nonlinear ops) applies — here the exp/max/sum chain
is a small VPU computation whose inputs and outputs are *both* covered by
the two GEMM checks, bounding undetected-fault propagation to the
elementwise stage.

Kernel structure: grid (num_q_blocks, num_k_blocks), K innermost; online
softmax state (m, l), f32 accumulators, ABFT accumulators and magnitude
bounds in VMEM scratch.  Causal masking by absolute block positions.
Single-head 2-D problem; ops.py wrappers vmap over (batch, heads).
Validated in interpret mode against ref.py (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, fault_ref,            # inputs
    o_ref, res_s_ref, bnd_s_ref, res_pv_ref, bnd_pv_ref,   # outputs
    m_ref, l_ref, acc_ref, chk_ref, bndc_ref, ress_ref, bnds_ref,  # scratch
    *, gk: int, bq: int, bk: int, causal: bool, scale: float,
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)
        bndc_ref[...] = jnp.zeros_like(bndc_ref)
        ress_ref[...] = jnp.zeros_like(ress_ref)
        bnds_ref[...] = jnp.zeros_like(bnds_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    qf = q.astype(F32)
    kf = k.astype(F32)
    vf = v.astype(F32)

    # ---- QK^T on the MXU, f32 accumulation
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * scale

    # ---- ABFT check #1: scores vs K-tile checksum (VPU)
    k_sum = jnp.sum(kf, axis=0)                    # (d,)
    k_abs = jnp.sum(jnp.abs(kf), axis=0)
    chk_s = jnp.sum(qf * k_sum[None, :], axis=1) * scale       # (bq,)
    bnd_s = jnp.sum(jnp.abs(qf) * k_abs[None, :], axis=1) * abs(scale)
    res_here = jnp.abs(chk_s - jnp.sum(s, axis=1))
    ress_ref[...] = jnp.maximum(ress_ref[...], res_here)
    bnds_ref[...] = jnp.maximum(bnds_ref[...], bnd_s)

    # ---- causal mask by absolute positions
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    # ---- online softmax update
    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    # ---- PV on the MXU + ABFT check #2 accumulators (VPU), with the
    # same rescaling so the invariant survives the online softmax
    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    v_sum = jnp.sum(vf, axis=1)                    # (bk,)
    v_abs = jnp.sum(jnp.abs(vf), axis=1)
    chk_ref[...] = chk_ref[...] * corr + jnp.sum(p * v_sum[None, :], axis=1)
    bndc_ref[...] = bndc_ref[...] * corr + jnp.sum(p * v_abs[None, :],
                                                   axis=1)

    @pl.when(ki == gk - 1)
    def _finalize():
        acc = acc_ref[...]
        # optional fault: corrupt the output accumulator only (the ABFT
        # data path consumed the same tiles independently)
        fi = fault_ref[...]
        here = (fi[4] == 1) & (fi[0] == qi)
        rows = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1)
        mask = (rows == fi[2]) & (cols == fi[3]) & here
        acc = jnp.where(
            mask, acc + jax.lax.bitcast_convert_type(fi[5], F32), acc)

        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
        res_pv_ref[0, :] = jnp.abs(chk_ref[...] - jnp.sum(acc, axis=1))
        bnd_pv_ref[0, :] = bndc_ref[...]
        res_s_ref[0, :] = ress_ref[...]
        bnd_s_ref[0, :] = bnds_ref[...]


def _paged_decode_kernel(
    table_ref, len_ref,                            # scalar prefetch
    q_ref, k_ref, v_ref,                           # inputs
    o_ref, res_s_ref, bnd_s_ref, res_pv_ref, bnd_pv_ref,   # outputs
    m_ref, l_ref, acc_ref, chk_ref, bndc_ref, ress_ref, bnds_ref,  # scratch
    *, gk: int, bs: int, gq: int, scale: float,
):
    """Paged decode tile: the block table is a scalar-prefetch operand,
    so grid step ``j`` DMAs physical block ``table[j]`` of the KV pool
    straight into VMEM — no gathered (B, W*block_size) copy of the cache
    is ever materialized (the XLA reference path's ``paged_gather``).
    The q tile carries all ``gq`` query heads of ONE kv head (GQA
    grouping), so the pool is shared rather than head-replicated.

    Masking runs in LOGICAL coordinates (``j * block_size + offset``)
    against ``len_ref``, and — unlike the dense cache, which is zero
    beyond the row's length — invalid positions here may hold ALIEN data
    (sentinel tails clamped by the wrapper point at other requests'
    blocks; reused blocks keep stale KV).  Both ABFT score-check sides
    (checksum, residual, bound) are therefore restricted to the valid
    columns: the invalid columns' scores are discarded before softmax
    anyway, and letting alien magnitudes into the bound would inflate
    the detection threshold and mask real faults.  The PV check needs no
    extra masking (p == 0 at invalid columns)."""
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)
        bndc_ref[...] = jnp.zeros_like(bndc_ref)
        ress_ref[...] = jnp.zeros_like(ress_ref)
        bnds_ref[...] = jnp.zeros_like(bnds_ref)

    q = q_ref[...]                                 # (gq, d)
    k = k_ref[0]                                   # (bs, d)  one pool block
    v = v_ref[0]                                   # (bs, dv)
    qf = q.astype(F32)
    kf = k.astype(F32)
    vf = v.astype(F32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * scale

    # validity in logical token coordinates (see docstring)
    k_pos = ki * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    vmask = (k_pos < len_ref[0]).astype(F32)       # (1, bs)

    # ABFT check #1, restricted to the valid key columns
    k_sum = jnp.sum(kf * vmask.T, axis=0)
    k_abs = jnp.sum(jnp.abs(kf) * vmask.T, axis=0)
    chk_s = jnp.sum(qf * k_sum[None, :], axis=1) * scale
    bnd_s = jnp.sum(jnp.abs(qf) * k_abs[None, :], axis=1) * abs(scale)
    res_here = jnp.abs(chk_s - jnp.sum(s * vmask, axis=1))
    ress_ref[...] = jnp.maximum(ress_ref[...], res_here)
    bnds_ref[...] = jnp.maximum(bnds_ref[...], bnd_s)

    s = jnp.where(vmask > 0, s, NEG_INF)

    m_new = jnp.maximum(m_ref[...], jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_ref[...] - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    pv = jax.lax.dot_general(
        p, vf, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    v_sum = jnp.sum(vf, axis=1)
    v_abs = jnp.sum(jnp.abs(vf), axis=1)
    chk_ref[...] = chk_ref[...] * corr + jnp.sum(p * v_sum[None, :], axis=1)
    bndc_ref[...] = bndc_ref[...] * corr + jnp.sum(p * v_abs[None, :],
                                                   axis=1)

    @pl.when(ki == gk - 1)
    def _finalize():
        acc = acc_ref[...]
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
        res_pv_ref[...] = jnp.abs(chk_ref[...] - jnp.sum(acc, axis=1))
        bnd_pv_ref[...] = bndc_ref[...]
        res_s_ref[...] = ress_ref[...]
        bnd_s_ref[...] = bnds_ref[...]


def flash_decode_paged_kernel(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    table: jnp.ndarray,
    length: jnp.ndarray,
    *,
    scale: float | None = None,
    out_dtype=None,
    interpret: bool = True,
):
    """Fused-ABFT paged decode attention for one kv head.

    q: (gq, d) — the ``gq`` query heads sharing this kv head (GQA
    grouping keeps the pool un-replicated); k_pool: (NB, BS, d);
    v_pool: (NB, BS, dv) — the physical block pools; table: (W,) int32
    physical block ids for this row (tail entries must be clamped to a
    valid id — they are masked by ``length``); length: (1,) int32 valid
    logical cache length.
    Returns (o (gq, dv), res_s, bnd_s, res_pv, bnd_pv), checks of shape
    (gq,).
    """
    gq, d = q.shape
    NB, BS, dv = v_pool.shape
    W = table.shape[0]
    scale = scale if scale is not None else d ** -0.5
    out_dtype = out_dtype or q.dtype

    kernel = functools.partial(_paged_decode_kernel, gk=W, bs=BS, gq=gq,
                               scale=scale)
    vec_spec = pl.BlockSpec((gq,), lambda j, t, ln: (0,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W,),
        in_specs=[
            pl.BlockSpec((gq, d), lambda j, t, ln: (0, 0)),
            pl.BlockSpec((1, BS, d), lambda j, t, ln: (t[j], 0, 0)),
            pl.BlockSpec((1, BS, dv), lambda j, t, ln: (t[j], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((gq, dv), lambda j, t, ln: (0, 0)),
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((gq,), F32),       # m
            pltpu.VMEM((gq,), F32),       # l
            pltpu.VMEM((gq, dv), F32),    # acc
            pltpu.VMEM((gq,), F32),       # pv checksum
            pltpu.VMEM((gq,), F32),       # pv bound
            pltpu.VMEM((gq,), F32),       # scores residual (max over k)
            pltpu.VMEM((gq,), F32),       # scores bound
        ],
    )
    o, rs, bs_, rp, bp = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((gq, dv), out_dtype),
            jax.ShapeDtypeStruct((gq,), F32),
            jax.ShapeDtypeStruct((gq,), F32),
            jax.ShapeDtypeStruct((gq,), F32),
            jax.ShapeDtypeStruct((gq,), F32),
        ],
        interpret=interpret,
    )(table, length, q, k_pool, v_pool)
    return o, rs, bs_, rp, bp


def flash_decode_kernel(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    bk: int,
    scale: float | None = None,
    out_dtype=None,
    interpret: bool = True,
):
    """Single-head fused-ABFT decode attention against a CONTIGUOUS
    cache row — the degenerate paged problem with the identity block
    table, so one kernel body serves both layouts (a dense row is a pool
    whose s-th block is block s).

    q: (1, d); k: (S, d); v: (S, dv) — S padded to a bk multiple;
    length: (1,) int32 valid cache length for this row.
    Returns (o (1, dv), res_s, bnd_s, res_pv, bnd_pv), each check vector
    of shape (1,).
    """
    _, d = q.shape
    S, dv = v.shape
    assert S % bk == 0, (S, bk)
    gk = S // bk
    return flash_decode_paged_kernel(
        q, k.reshape(gk, bk, d), v.reshape(gk, bk, dv),
        jnp.arange(gk, dtype=jnp.int32), length,
        scale=scale, out_dtype=out_dtype, interpret=interpret)


def flash_attention_kernel(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    fault: jnp.ndarray,
    *,
    bq: int,
    bk: int,
    causal: bool = True,
    scale: float | None = None,
    out_dtype=None,
    interpret: bool = True,
):
    """Single-head fused-ABFT flash attention.

    q: (Lq, d), k: (Lk, d), v: (Lk, dv) — padded to block multiples.
    fault: (6,) int32 [q_block, _, row, col, enabled, delta_bits].
    Returns (o (Lq, dv), res_s, bnd_s, res_pv, bnd_pv) with per-q-row
    residual/bound vectors of shape (gq, bq).
    """
    Lq, d = q.shape
    Lk, dv = v.shape
    assert Lq % bq == 0 and Lk % bk == 0, ((Lq, Lk), (bq, bk))
    gq, gk = Lq // bq, Lk // bk
    scale = scale if scale is not None else d ** -0.5
    out_dtype = out_dtype or q.dtype

    kernel = functools.partial(
        _kernel, gk=gk, bq=bq, bk=bk, causal=causal, scale=scale)
    vec_spec = pl.BlockSpec((1, bq), lambda i, j: (i, 0))
    o, rs, bs, rp, bp = pl.pallas_call(
        kernel,
        grid=(gq, gk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, dv), lambda i, j: (j, 0)),
            pl.BlockSpec((6,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, dv), lambda i, j: (i, 0)),
            vec_spec, vec_spec, vec_spec, vec_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Lq, dv), out_dtype),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
            jax.ShapeDtypeStruct((gq, bq), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),       # m
            pltpu.VMEM((bq,), F32),       # l
            pltpu.VMEM((bq, dv), F32),    # acc
            pltpu.VMEM((bq,), F32),       # pv checksum
            pltpu.VMEM((bq,), F32),       # pv bound
            pltpu.VMEM((bq,), F32),       # scores residual (max over k)
            pltpu.VMEM((bq,), F32),       # scores bound
        ],
        interpret=interpret,
    )(q, k, v, fault)
    return o, rs, bs, rp, bp
