"""jit-ready wrapper for the fused-ABFT flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.checksums import ATOL, CheckResult, flag_from, tolerance_scale
from repro.core.faults import FaultSpec
from repro.kernels.flash_attention import (
    F32,
    flash_attention_kernel,
    flash_decode_kernel,
    flash_decode_paged_kernel,
)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _attn_check(rs, bs, rp, bp, d: int, s: int,
                c_factor: float) -> CheckResult:
    """Fold the per-tile residual/bound vectors of both attention GEMMs
    (scores: reduction depth ``d``; PV: reduction depth ``s``) into one
    CheckResult — shared by every flash entry point."""
    tau_s = ATOL + tolerance_scale(d, c=c_factor) * bs
    tau_pv = ATOL + tolerance_scale(s, c=c_factor) * bp
    flag = jnp.logical_or(flag_from(rs, tau_s), flag_from(rp, tau_pv))
    residual = jnp.stack([jnp.max(rs), jnp.max(rp)])
    threshold = jnp.stack([jnp.min(tau_s), jnp.min(tau_pv)])
    return CheckResult(flag=flag, residual=residual, threshold=threshold)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    fault: FaultSpec | None = None,
    c_factor: float = 16.0,
):
    """Fused-ABFT attention.  q: (B, Lq, H, D); k/v: (B, Lk, KV, D[v]).

    GQA: kv heads are repeated to H (view-level).  Returns
    (out (B, Lq, H, Dv), CheckResult) where the residuals cover both
    attention GEMMs (scores and PV).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Lq, H, D = q.shape
    KV, Dv = k.shape[2], v.shape[3]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    bq_eff = min(bq, _round_up(Lq, 8))
    bk_eff = min(bk, _round_up(k.shape[1], 8))
    pq = _round_up(Lq, bq_eff) - Lq
    pk = _round_up(k.shape[1], bk_eff) - k.shape[1]
    # pad K positions with -inf-free zeros; padded keys are masked by the
    # causal test (k_pos > any q_pos) or contribute exp(-large)≈... for
    # non-causal we mask via an extra key-position guard below.
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    assert causal or pk == 0, "non-causal padding not supported; pad caller"

    if fault is None:
        fault = FaultSpec.none()
    fi = jnp.stack([
        fault.row // bq_eff,
        jnp.zeros((), jnp.int32),
        fault.row % bq_eff,
        fault.col,
        fault.enabled,
        jax.lax.bitcast_convert_type(fault.delta.astype(F32), jnp.int32),
    ]).astype(jnp.int32)

    def one_head(qh, kh, vh):
        return flash_attention_kernel(
            qh, kh, vh, fi, bq=bq_eff, bk=bk_eff, causal=causal,
            interpret=interpret, out_dtype=q.dtype)

    # vmap over batch then heads (head axis moved in front of L)
    f = jax.vmap(jax.vmap(one_head, in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    o, rs, bs, rp, bp = f(
        jnp.moveaxis(qp, 2, 1), jnp.moveaxis(kp, 2, 1),
        jnp.moveaxis(vp, 2, 1))
    o = jnp.moveaxis(o, 1, 2)[:, :Lq]

    return o, _attn_check(rs, bs, rp, bp, D, k.shape[1], c_factor)


def flash_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    bk: int = 128,
    interpret: bool | None = None,
    c_factor: float = 16.0,
):
    """Fused-ABFT decode attention against a ragged KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D[v]); lengths: (B,)
    int32 per-row valid cache length (the serving engine's vectorized
    cursor + 1).  Each batch row attends only its own valid prefix, so
    mixed-length continuous batching is exact.  Returns
    (out (B, 1, H, Dv), CheckResult) covering both attention GEMMs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, H, D = q.shape
    S, KV, Dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[3]
    if KV != H:
        rep = H // KV
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)

    bk_eff = min(bk, _round_up(S, 8))
    pk = _round_up(S, bk_eff) - S
    kp = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (B,))[:, None]    # (B, 1)

    def one_head(qh, kh, vh, ln):
        return flash_decode_kernel(
            qh, kh, vh, ln, bk=bk_eff, interpret=interpret,
            out_dtype=q.dtype)

    f = jax.vmap(jax.vmap(one_head, in_axes=(0, 0, 0, None)),
                 in_axes=(0, 0, 0, 0))
    o, rs, bs, rp, bp = f(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(kp, 2, 1),
        jnp.moveaxis(vp, 2, 1), lengths)
    out = jnp.moveaxis(o, 1, 2)                            # (B, 1, H, Dv)

    return out, _attn_check(rs, bs, rp, bp, D, S, c_factor)


def flash_decode_paged(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    interpret: bool | None = None,
    c_factor: float = 16.0,
):
    """Fused-ABFT decode attention against a PAGED KV cache.

    q: (B, 1, H, D); k_pool/v_pool: (NB, BS, KV, D[v]) physical block
    pools shared by all rows (serve/paged_cache.py layout);
    block_tables: (B, W) int32 per-row physical block ids (sentinel-
    padded tails are clamped here — the per-row ``lengths`` mask makes
    their contribution exactly zero); lengths: (B,) valid logical cache
    lengths (the engine's vectorized cursor + 1).  The kernel takes the
    table as a scalar-prefetch index operand, so each grid step DMAs one
    physical block — the pool is never gathered to a dense copy, and GQA
    query heads are grouped per kv head (q tile (G, D)) so the pool is
    never head-replicated either.
    Returns (out (B, 1, H, Dv), CheckResult) covering both attention
    GEMMs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, H, D = q.shape
    NB, BS, KV, Dv = v_pool.shape
    W = block_tables.shape[1]
    G = H // KV

    tables = jnp.clip(
        jnp.asarray(block_tables, jnp.int32), 0, NB - 1)       # (B, W)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32), (B,))[:, None]        # (B, 1)
    # q heads are stored kv-major (kv, group): group them per kv head so
    # every kernel call shares one un-copied pool slice
    qg = q[:, 0].reshape(B, KV, G, D)

    def one_kv_head(qk, kh, vh, tb, ln):
        return flash_decode_paged_kernel(
            qk, kh, vh, tb, ln, interpret=interpret, out_dtype=q.dtype)

    # vmap batch (tables/lengths per-row, pools shared), then kv heads
    # (pool slice per kv head, table shared)
    f = jax.vmap(jax.vmap(one_kv_head, in_axes=(0, 0, 0, None, None)),
                 in_axes=(0, None, None, 0, 0))
    o, rs, bs, rp, bp = f(
        qg, jnp.moveaxis(k_pool, 2, 0), jnp.moveaxis(v_pool, 2, 0),
        tables, lengths)
    out = o.reshape(B, 1, H, Dv)             # (B, KV, G, Dv), kv-major

    return out, _attn_check(rs, bs, rp, bp, D, W * BS, c_factor)
