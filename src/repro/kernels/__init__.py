"""Pallas TPU kernels for the perf-critical fused-ABFT hot spots.

The paper's compute hot-spot is the ABFT-protected GEMM itself; the
block-level (thread-level-equivalent) scheme *requires* a custom kernel —
checksum generation must happen while the operand tiles are VMEM-resident
(DESIGN.md §2).

* abft_matmul.py — blocked matmul with fused one-/two-sided block ABFT and
  the replication baseline; ops.py is the jit'd wrapper, ref.py the oracle.
* flash_attention.py — flash attention with in-VMEM ABFT over both
  attention GEMMs (scores + PV, rescaled through the online softmax);
  flash_ops.py is the wrapper.  This is the §Perf-identified next lever
  for every memory-bound train/prefill cell.
"""

from repro.kernels.flash_ops import flash_attention
from repro.kernels.ops import abft_matmul, default_interpret

__all__ = ["abft_matmul", "default_interpret", "flash_attention"]
