"""Pure-jnp oracles for the fused ABFT matmul kernel.

``matmul_ref`` is the ground-truth GEMM.  ``abft_matmul_ref`` mirrors the
kernel's blocked accumulation order exactly (k-chunked f32 sums) so the
kernel's residual/bound outputs can be compared with tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    return jnp.matmul(
        x.astype(F32), w.astype(F32), precision="highest"
    ).astype(out_dtype)


def _pad_to(a, m, n):
    return jnp.pad(a, ((0, m - a.shape[0]), (0, n - a.shape[1])))


def abft_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mode: str = "1s",
    bm: int,
    bk: int,
    bn: int,
    out_dtype=None,
):
    """Oracle for the padded kernel: returns (y, res, bnd) with the same
    shapes and (chunked) accumulation structure as the kernel."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    kw, n = w.shape
    assert k == kw
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    gm, gk, gn = m // bm, k // bk, n // bn

    xf = x.astype(F32).reshape(gm, bm, gk, bk)
    wf = w.astype(F32).reshape(gk, bk, gn, bn)

    # Main GEMM: per-(i,j) block accumulated over k chunks.
    # (gm, bm, gk, bk) x (gk, bk, gn, bn) -> (gm, bm, gn, bn)
    acc = jnp.einsum("aikb,kbcn->aicn", xf, wf,
                     preferred_element_type=F32, precision="highest")
    y2 = acc.reshape(m, n)
    y_mat = jnp.swapaxes(acc, 1, 2)  # (gm, gn, bm, bn)

    if mode == "2s":
        a_sum = xf.sum(axis=1)                      # (gm, gk, bk)
        b_sum = wf.sum(axis=3)                      # (gk, bk, gn)
        a_abs = jnp.abs(xf).sum(axis=1)
        b_abs = jnp.abs(wf).sum(axis=3)
        chk = jnp.einsum("agk,gkc->ac", a_sum, b_sum)       # (gm, gn)
        bnd = jnp.einsum("agk,gkc->ac", a_abs, b_abs)
        total = y_mat.sum(axis=(2, 3))                      # (gm, gn)
        res = jnp.abs(chk - total)
        return y2.astype(out_dtype), res, bnd

    # one-sided / replica: per-(i,j) block, per-row residual.
    b_sum = wf.sum(axis=3)                          # (gk, bk, gn)
    b_abs = jnp.abs(wf).sum(axis=3)
    chk = jnp.einsum("aikb,kbc->aic", xf, b_sum)    # (gm, bm, gn)
    bnd = jnp.einsum("aikb,kbc->aic", jnp.abs(xf), b_abs)
    rowsum = y_mat.sum(axis=3)                      # (gm, gn, bm)
    res = jnp.abs(chk.transpose(0, 2, 1) - rowsum)  # (gm, gn, bm)
    if mode == "replica":
        # replica recomputes the same product — residual is (numerically)
        # zero; the oracle reports zero.
        res = jnp.zeros_like(res)
        bnd = jnp.abs(y_mat).sum(axis=3)
        return y2.astype(out_dtype), res, bnd
    return y2.astype(out_dtype), res, bnd.transpose(0, 2, 1)
