"""Pallas TPU kernel: blocked matmul with *fused block-level ABFT*.

This is the TPU-native adaptation of the paper's thread-level ABFT
(DESIGN.md §2).  The GPU scheme fuses checksum generation into each CUDA
thread's sub-GEMM so that no extra HBM traffic is generated; the TPU
analogue is the Pallas grid block: each grid cell owns a (bm × bn) output
tile, marches down K in (bm × bk) · (bk × bn) steps with both tiles resident
in VMEM, and accumulates its ABFT checksums from those same VMEM tiles —
zero additional HBM loads/stores, exactly the paper's §3.5 design principle.

Compute-unit mapping (the key hardware adaptation): the main GEMM runs on
the MXU; the redundant checksum math is expressed as VPU-friendly
reductions / weighted row-sums so that, on a bandwidth-bound GEMM, the
redundant work occupies the *idle* vector unit instead of competing for MXU
issue slots.  (`jnp.sum` / elementwise ops lower to VPU; only the REPLICA
baseline re-issues MXU work, mirroring paper §4.)

Modes (static):
  '1s'      one-sided block ABFT (default; paper §5.2.2).  Per K step:
              b_sum  = Σ_j B_tile[:, j]                  (VPU, (bk,))
              chk   += A_tile @ b_sum                    (VPU weighted rowsum)
              bnd   += |A_tile| @ Σ_j |B_tile[:, j]|     (threshold bound)
            Final:  residual = |chk − Σ_j acc[:, j]|  → locates faulty row.
  '2s'      two-sided block ABFT: scalar residual per block (paper Fig. 7
            left), fewer VPU FLOPs, no row location.
  'replica' replicated-MMA-single-accumulation baseline (paper §4): the
            block matmul is re-issued on the MXU, accumulated into one
            (bm,) vector and compared against the row-sums of the original.

Fault injection: an optional FaultSpec corrupts the **main accumulator
only**, after the checksum path has consumed the same operands — modeling a
soft error in the MXU that the independent VPU checksum data path does not
see (paper §2.3 fault model).

VMEM budget per grid cell (bf16 operands, f32 accumulators):
    bm·bk·2 + bk·bn·2 + bm·bn·4 + O(bm) bytes
with the default (bm, bk, bn) = (256, 512, 256): 0.25 + 0.25 + 0.25 MiB
≈ 0.78 MiB — comfortably inside a v5e core's VMEM even with double
buffering; all tile dims are multiples of the 128-lane MXU width.

The tiny per-block residual outputs are logical shape (gm, gn, bm); on a
real TPU these are metadata (≪ output bytes) and their layout is padded by
Mosaic.  Kernels are validated in interpret mode against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

MODES = ("1s", "2s", "replica")


def _apply_fault(acc, fault_idx, fault_val, block_i, block_j):
    """Corrupt one element of the f32 accumulator tile per the fault spec.

    fault_idx: (8,) int32 [block_i, block_j, row_in_block, col_in_block,
                           enabled, bit, _, _];  fault_val: (1,) f32 delta.
    """
    bm, bn = acc.shape
    here = (
        (fault_idx[4] == 1)
        & (fault_idx[0] == block_i)
        & (fault_idx[1] == block_j)
    )
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    mask = (rows == fault_idx[2]) & (cols == fault_idx[3]) & here

    bit = fault_idx[5]
    raw = jax.lax.bitcast_convert_type(acc, jnp.uint32)
    flip_mask = (jnp.ones((), jnp.uint32) << jnp.maximum(bit, 0).astype(
        jnp.uint32))
    flipped = jax.lax.bitcast_convert_type(raw ^ flip_mask, F32)
    corrupted = jnp.where(bit >= 0, flipped, acc + fault_val[0])
    return jnp.where(mask, corrupted, acc)


def _kernel(
    x_ref, w_ref, fault_idx_ref, fault_val_ref,   # inputs
    y_ref, res_ref, bnd_ref,                      # outputs
    acc_ref, chk_ref, bnd_acc_ref,                # scratch
    *, gk: int, mode: str, out_dtype,
):
    # program_id must be read at kernel top level (not inside pl.when
    # bodies) for interpret-mode compatibility.
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        chk_ref[...] = jnp.zeros_like(chk_ref)
        bnd_acc_ref[...] = jnp.zeros_like(bnd_acc_ref)

    a = x_ref[...]
    b = w_ref[...]
    # Main GEMM contribution — MXU, f32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )

    af = a.astype(F32)
    bf = b.astype(F32)
    if mode == "1s":
        b_sum = jnp.sum(bf, axis=1)                     # (bk,)  VPU
        b_abs = jnp.sum(jnp.abs(bf), axis=1)            # (bk,)  VPU
        # Weighted row-sum: Σ_k A[:, k] * b_sum[k] — VPU multiply-reduce,
        # NOT an MXU matvec (DESIGN.md §2).
        chk_ref[...] += jnp.sum(af * b_sum[None, :], axis=1)
        bnd_acc_ref[...] += jnp.sum(jnp.abs(af) * b_abs[None, :], axis=1)
    elif mode == "2s":
        a_sum = jnp.sum(af, axis=0)                     # (bk,)
        b_sum = jnp.sum(bf, axis=1)                     # (bk,)
        a_abs = jnp.sum(jnp.abs(af), axis=0)
        b_abs = jnp.sum(jnp.abs(bf), axis=1)
        chk_ref[0] += jnp.sum(a_sum * b_sum)
        bnd_acc_ref[0] += jnp.sum(a_abs * b_abs)
    elif mode == "replica":
        # Redundant MXU pass, single-vector accumulation (paper §4).
        redo = jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=F32
        )
        chk_ref[...] += jnp.sum(redo, axis=1)
        bnd_acc_ref[...] += jnp.sum(jnp.abs(redo), axis=1)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    @pl.when(k == gk - 1)
    def _finalize():
        acc = _apply_fault(
            acc_ref[...], fault_idx_ref[...], fault_val_ref[...], i, j
        )
        y_ref[...] = acc.astype(out_dtype)
        if mode == "2s":
            total = jnp.sum(acc)
            res_ref[0, 0] = jnp.abs(chk_ref[0] - total)
            bnd_ref[0, 0] = bnd_acc_ref[0]
        else:
            rowsum = jnp.sum(acc, axis=1)               # (bm,) VPU
            res_ref[0, 0, :] = jnp.abs(chk_ref[...] - rowsum)
            bnd_ref[0, 0, :] = bnd_acc_ref[...]


def abft_matmul_kernel(
    x: jnp.ndarray,
    w: jnp.ndarray,
    fault_idx: jnp.ndarray,
    fault_val: jnp.ndarray,
    *,
    bm: int,
    bk: int,
    bn: int,
    mode: str = "1s",
    out_dtype=jnp.bfloat16,
    interpret: bool = True,
):
    """Raw kernel entry; shapes must already be padded to block multiples.

    x: (M, K), w: (K, N) -> y (M, N) in out_dtype,
    res/bnd: (gm, gn, bm) f32 ('1s'/'replica') or (gm, gn) f32 ('2s').
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        (m, k, n), (bm, bk, bn))
    gm, gk, gn = m // bm, k // bk, n // bn

    if mode == "2s":
        res_shape = jax.ShapeDtypeStruct((gm, gn), F32)
        res_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (i, j))
        chk_shape = (1,)
    else:
        res_shape = jax.ShapeDtypeStruct((gm, gn, bm), F32)
        res_spec = pl.BlockSpec((1, 1, bm), lambda i, j, kk: (i, j, 0))
        chk_shape = (bm,)

    kernel = functools.partial(_kernel, gk=gk, mode=mode, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((8,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            res_spec,
            res_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            res_shape,
            res_shape,
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), F32),   # main f32 accumulator tile
            pltpu.VMEM(chk_shape, F32),  # ABFT checksum accumulator
            pltpu.VMEM(chk_shape, F32),  # magnitude-bound accumulator
        ],
        interpret=interpret,
    )(x, w, fault_idx, fault_val)
