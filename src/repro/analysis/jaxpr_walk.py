"""Recursive ClosedJaxpr walker: inventory every FLOP-carrying primitive.

``flop_ops`` walks a traced entry point — through ``pjit``, ``scan``,
``cond`` branches, ``remat``, ``custom_jvp/vjp`` — and returns one
``TracedOp`` per ``dot_general`` / ``conv_general_dilated`` equation, with:

* exact FLOPs from the equation's dimension numbers and operand avals
  (2*M*K*N per batched GEMM element; 2 * out_elems * K_eff per conv),
  multiplied by the enclosing scan trip counts (a scanned stack of R
  repeats traces ONE layer body — the walker restores the xR factor);
* the equation's ``name_stack`` string, which carries the auditor's
  ``abft[...]``/``flops[...]`` markers (markers.py);
* a human-readable path (``prefill/pjit:fn/scan[x4]/dot_general``) for
  pinpointing unprotected ops in reports.

``pallas_call`` equations are surfaced as ``TracedOp``s too (flops=0 —
kernel internals are opaque to tracing) so fused-kernel dispatch sites
stay visible to the classifier instead of vanishing.
"""

from __future__ import annotations

import dataclasses
import math

import jax

FLOP_PRIMITIVES = ("dot_general", "conv_general_dilated")


@dataclasses.dataclass(frozen=True)
class TracedOp:
    """One FLOP-carrying equation found by the walk."""

    primitive: str
    flops: float               # repeats included
    m: int                     # lhs free size (batch folded out)
    k: int                     # contraction size
    n: int                     # rhs free size / out channels
    name_stack: str
    path: str
    repeats: int = 1           # product of enclosing scan lengths


def _prod(xs) -> int:
    return int(math.prod(int(x) for x in xs)) if xs else 1


def _dot_geometry(eqn):
    """(batch, m, k, n) of a dot_general from its dimension numbers."""
    (lhs_c, rhs_c), (lhs_b, rhs_b) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    batch = _prod([lhs[i] for i in lhs_b])
    k = _prod([lhs[i] for i in lhs_c])
    m = _prod([d for i, d in enumerate(lhs) if i not in lhs_c + lhs_b])
    n = _prod([d for i, d in enumerate(rhs)
               if i not in tuple(rhs_c) + tuple(rhs_b)])
    return batch, m, k, n


def _conv_geometry(eqn):
    """(m, k, n) of a conv: m = batch*out_spatial, k = in_per_group *
    prod(kernel_spatial), n = out_channels."""
    dn = eqn.params["dimension_numbers"]
    out_shape = eqn.outvars[0].aval.shape
    rhs_shape = eqn.invars[1].aval.shape
    n = int(rhs_shape[dn.rhs_spec[0]])          # out feature dim
    k = _prod(rhs_shape) // max(n, 1)           # in_per_group * spatial
    m = _prod(out_shape) // max(n, 1)           # batch * out positions
    return m, k, n


def _sub_jaxprs(eqn):
    """Every jaxpr-valued object hiding in an equation's params."""
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if isinstance(item, jax.core.ClosedJaxpr):
                subs.append(item.jaxpr)
            elif isinstance(item, jax.core.Jaxpr):
                subs.append(item)
    return subs


def _eqn_label(eqn) -> str:
    prim = eqn.primitive.name
    if prim == "pjit":
        name = eqn.params.get("name")
        return f"pjit:{name}" if name else prim
    if prim == "scan":
        return f"scan[x{eqn.params.get('length', '?')}]"
    return prim


def flop_ops(traced, entry: str = "trace") -> list:
    """Walk a ClosedJaxpr (or anything with ``.jaxpr``) and return the
    ``TracedOp`` inventory.  ``entry`` labels the path root."""
    jaxpr = getattr(traced, "jaxpr", traced)
    out: list = []
    _walk(jaxpr, (entry,), 1, out, "")
    return out


def _walk(jaxpr, path: tuple, repeats: int, out: list,
          prefix: str) -> None:
    """``prefix``: accumulated name-stack string of the ENCLOSING
    equations.  A scope opened around ``lax.scan``/``pjit`` lands on the
    wrapping equation itself — body eqns carry only their local stacks —
    so markers must be read off the concatenation."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        local = str(eqn.source_info.name_stack)
        ns = "/".join(s for s in (prefix, local) if s)
        if prim == "dot_general":
            batch, m, k, n = _dot_geometry(eqn)
            out.append(TracedOp(
                primitive=prim,
                flops=2.0 * batch * m * k * n * repeats,
                m=batch * m, k=k, n=n,
                name_stack=ns,
                path="/".join(path + (prim,)),
                repeats=repeats,
            ))
        elif prim == "conv_general_dilated":
            m, k, n = _conv_geometry(eqn)
            out.append(TracedOp(
                primitive=prim,
                flops=2.0 * m * k * n * repeats,
                m=m, k=k, n=n,
                name_stack=ns,
                path="/".join(path + (prim,)),
                repeats=repeats,
            ))
        elif prim == "pallas_call":
            # fused kernel: internals opaque; visible for classification
            out.append(TracedOp(
                primitive=prim, flops=0.0, m=0, k=0, n=0,
                name_stack=ns,
                path="/".join(path + (prim,)),
                repeats=repeats,
            ))
        sub = _sub_jaxprs(eqn)
        if sub:
            mult = repeats
            if prim == "scan":
                mult *= int(eqn.params.get("length", 1))
            label = _eqn_label(eqn)
            for s in sub:
                _walk(s, path + (label,), mult, out, ns)
