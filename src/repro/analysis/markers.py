"""The auditor's tagging protocol: ``jax.named_scope`` markers that survive
tracing.

JAX records the active name-scope stack into every equation's
``source_info.name_stack`` — including equations inside ``pjit``/``scan``
sub-jaxprs, where the *inner* primitives carry the full scope string even
though the wrapping pjit/scan eqn itself does not.  That makes a scope
opened around a dispatch site a reliable static marker: the walker
(jaxpr_walk.py) reads it back off each ``dot_general`` without running any
code.

Two marker families:

``abft[<scheme>][<site>]``
    Opened by ``protected_matmul`` around the registered executor — every
    dot the executor emits (the protected GEMM *and* its check einsums) is
    stamped with the resolved scheme name and the plan-facing site tag
    (``attn.q``, ``mlp.down``, ...).

``flops[<kind>]``
    Coverage annotations for FLOP-carrying regions that are deliberately
    outside the matmul-ABFT surface: the attention softmax path
    (``softmax`` — allowlisted, replaced by the fused flash-ABFT kernels
    when ``flash_attention=True``), the MLA absorb einsums (``mla``), the
    SSD scan einsums (``ssm_scan``), and the whisper conv stem
    (``conv_stem``).  The audit classifies these explicitly instead of
    reporting them as silent gaps.

Scope names may not contain '/', so the bracket syntax doubles as the
parse delimiter.
"""

from __future__ import annotations

import re
from typing import NamedTuple

import jax

_ABFT_RE = re.compile(r"abft\[([^\]]*)\]\[([^\]]*)\]")
_FLOPS_RE = re.compile(r"flops\[([^\]]*)\]")

# kinds the audit recognizes (see audit.py for their dispositions)
COVERAGE_KINDS = ("softmax", "mla", "ssm_scan", "conv_stem")


def protection_scope(scheme_name: str, site: str):
    """Scope marking 'ops in here belong to the <scheme> executor
    protecting plan site <site>'."""
    return jax.named_scope(f"abft[{scheme_name}][{site}]")


def coverage_scope(kind: str):
    """Scope marking a known non-GEMM-ABFT FLOP region (see module doc)."""
    if kind not in COVERAGE_KINDS:
        raise ValueError(
            f"unknown coverage kind {kind!r}; known: {COVERAGE_KINDS}")
    return jax.named_scope(f"flops[{kind}]")


class Marker(NamedTuple):
    """Parsed marker state of one equation's name stack."""

    scheme: str | None          # abft[...] scheme, if inside one
    site: str | None            # abft[...] site tag, if inside one
    kinds: tuple                # flops[...] kinds, outermost first

    @property
    def protected(self) -> bool:
        return self.scheme is not None


def parse_name_stack(name_stack: str) -> Marker:
    """Read the marker state back out of an eqn's name-stack string.

    Innermost ``abft`` marker wins (nested protected calls would be a
    bug, but the innermost is the one actually executing the op); all
    ``flops`` kinds are collected since regions nest (an SSD scan inside
    a softmax-annotated caller must classify as ``ssm_scan``)."""
    abft = _ABFT_RE.findall(name_stack)
    kinds = tuple(_FLOPS_RE.findall(name_stack))
    if abft:
        scheme, site = abft[-1]
        return Marker(scheme=scheme, site=site, kinds=kinds)
    return Marker(scheme=None, site=None, kinds=kinds)
