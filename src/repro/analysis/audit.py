"""The protection-coverage auditor: trace real entry points, walk every
FLOP, prove each flows through a registered ABFT scheme.

``audit_model`` traces the model's ACTUAL serving entry points —
``Model.prefill``, ``Model.decode``, and (for chunked-prefill-capable
stacks) the engine's jitted ``_prefill_chunk`` step — to ClosedJaxprs,
walks them recursively (jaxpr_walk.py), and classifies every
FLOP-carrying primitive by its trace markers (markers.py):

``protected``
    Inside an ``abft[<scheme>][<site>]`` scope — emitted by
    ``protected_matmul``'s executor dispatch.  Includes the check
    einsums: they are part of the protected surface.
``allowlisted``
    Inside ``flops[softmax]``: the attention score/PV contractions that
    the fused flash-ABFT kernels replace when ``flash_attention=True``.
    ``flash_allowlist_check`` validates the allowlist against the
    model's real flash routing: re-tracing decode with flash enabled
    must make these dots vanish.
``known_unprotected``
    Inside ``flops[mla|ssm_scan|conv_stem]``: FLOP regions with no
    registered ABFT scheme yet, tracked explicitly (with a note) instead
    of failing the audit — the whisper conv frontend (ROADMAP item 5a),
    the MLA absorb einsums, the SSD scan contractions.
``unprotected``
    Everything else.  A dot_general with no marker is exactly the drift
    this auditor exists to catch; it fails ``--fail-under 1.0``.

The protected fraction is ``protected / (protected + unprotected)`` —
allowlisted and known-unprotected FLOPs are excluded from the
denominator because they are *accounted for*, not silently missing.

A second pass (crosscheck.py) proves the compiled ``ProtectionPlan``
and the traced site set are bijective.

CLI: ``python -m repro.launch.audit``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis.crosscheck import CrossCheckResult, crosscheck_plan
from repro.analysis.jaxpr_walk import TracedOp, flop_ops
from repro.analysis.markers import parse_name_stack

PHASES = ("prefill", "decode", "mixed")

KNOWN_UNPROTECTED_KINDS = ("mla", "ssm_scan", "conv_stem")
ALLOWLISTED_KINDS = ("softmax",)

# one-line dispositions surfaced next to every known-unprotected bucket
KNOWN_GAP_NOTES = {
    "conv_stem": (
        "whisper conv frontend: no conv ABFT scheme registered; "
        "ROADMAP item 5a tracks a checksummed im2col GEMM"),
    "mla": (
        "MLA absorb einsums + absorbed attention core: no fused ABFT "
        "kernel (flash routing never reaches MLA)"),
    "ssm_scan": (
        "SSD scan / decode recurrence contractions: weight-free "
        "data-data einsums outside the matmul-ABFT surface"),
}


@dataclasses.dataclass(frozen=True)
class ClassifiedOp:
    """One traced op with its audit disposition."""

    op: TracedOp
    status: str                 # protected|allowlisted|known_unprotected|
                                # unprotected|kernel
    scheme: str | None = None   # when protected
    site: str | None = None     # when protected
    kind: str | None = None     # when allowlisted / known_unprotected


def classify(ops) -> tuple:
    """Marker-based classification of a traced-op inventory.

    Precedence: an ``abft`` marker wins outright (a protected dense call
    inside a ``flops[...]`` region is still protected); among coverage
    kinds, a known-unprotected kind (innermost first) beats the softmax
    allowlist, so an SSD scan nested under a softmax-annotated caller is
    reported as the gap it is."""
    out = []
    for op in ops:
        m = parse_name_stack(op.name_stack)
        if m.protected:
            out.append(ClassifiedOp(op, "protected",
                                    scheme=m.scheme, site=m.site))
            continue
        kind = next((k for k in reversed(m.kinds)
                     if k in KNOWN_UNPROTECTED_KINDS), None)
        if kind is not None:
            out.append(ClassifiedOp(op, "known_unprotected", kind=kind))
        elif any(k in ALLOWLISTED_KINDS for k in m.kinds):
            out.append(ClassifiedOp(op, "allowlisted", kind="softmax"))
        elif op.primitive == "pallas_call":
            # an unmarked fused kernel (e.g. flash attention) carries its
            # own in-kernel check; 0 traced FLOPs either way
            out.append(ClassifiedOp(op, "kernel"))
        else:
            out.append(ClassifiedOp(op, "unprotected"))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PhaseCoverage:
    """FLOP accounting of one traced phase."""

    phase: str
    ops: tuple                         # full ClassifiedOp inventory

    def _sum(self, status: str) -> float:
        return sum(c.op.flops for c in self.ops if c.status == status)

    @property
    def protected_flops(self) -> float:
        return self._sum("protected")

    @property
    def allowlisted_flops(self) -> float:
        return self._sum("allowlisted")

    @property
    def unprotected_flops(self) -> float:
        return self._sum("unprotected")

    @property
    def known_unprotected(self) -> dict:
        out: dict = {}
        for c in self.ops:
            if c.status == "known_unprotected":
                out[c.kind] = out.get(c.kind, 0.0) + c.op.flops
        return out

    @property
    def unprotected_ops(self) -> tuple:
        return tuple(c for c in self.ops if c.status == "unprotected")

    @property
    def protected_fraction(self) -> float:
        """Protected share of the FLOPs that are SUPPOSED to be on the
        matmul-ABFT surface (allowlisted / known-unprotected excluded —
        they are accounted for, not missing)."""
        denom = self.protected_flops + self.unprotected_flops
        return 1.0 if denom == 0 else self.protected_flops / denom

    def to_json(self) -> dict:
        return {
            "phase": self.phase,
            "n_ops": len(self.ops),
            "protected_flops": self.protected_flops,
            "allowlisted_flops": self.allowlisted_flops,
            "unprotected_flops": self.unprotected_flops,
            "known_unprotected": {
                kind: {"flops": fl, "note": KNOWN_GAP_NOTES.get(kind, "")}
                for kind, fl in sorted(self.known_unprotected.items())
            },
            "protected_fraction": self.protected_fraction,
            "unprotected": [
                {"path": c.op.path, "primitive": c.op.primitive,
                 "flops": c.op.flops,
                 "m": c.op.m, "k": c.op.k, "n": c.op.n}
                for c in self.unprotected_ops
            ],
        }


@dataclasses.dataclass(frozen=True)
class AuditReport:
    """One model's full audit: per-phase coverage + plan crosscheck."""

    model: str
    phases: dict                       # phase -> PhaseCoverage
    crosscheck: CrossCheckResult
    flash_consistent: bool | None      # None: not applicable / untraceable

    @property
    def protected_fraction(self) -> float:
        return min(p.protected_fraction for p in self.phases.values())

    @property
    def known_unprotected(self) -> dict:
        out: dict = {}
        for p in self.phases.values():
            for kind, fl in p.known_unprotected.items():
                out[kind] = max(out.get(kind, 0.0), fl)
        return out

    def to_json(self) -> dict:
        return {
            "model": self.model,
            "protected_fraction": self.protected_fraction,
            "phases": {ph: cov.to_json()
                       for ph, cov in sorted(self.phases.items())},
            "crosscheck": self.crosscheck.to_json(),
            "flash_consistent": self.flash_consistent,
        }

    def summary(self) -> str:
        lines = [f"coverage audit: {self.model}"]
        for ph, cov in sorted(self.phases.items()):
            gaps = ", ".join(
                f"{k}={v:.3g}" for k, v in sorted(
                    cov.known_unprotected.items())) or "none"
            lines.append(
                f"  {ph:8s} protected={cov.protected_fraction:.4f} "
                f"({cov.protected_flops:.3g} flops; "
                f"allowlisted={cov.allowlisted_flops:.3g}; "
                f"known gaps: {gaps})")
            for c in cov.unprotected_ops:
                lines.append(
                    f"    UNPROTECTED {c.op.primitive} "
                    f"m={c.op.m} k={c.op.k} n={c.op.n} "
                    f"flops={c.op.flops:.3g} at {c.op.path}")
        lines.append("  " + self.crosscheck.report().replace("\n", "\n  "))
        if self.flash_consistent is not None:
            lines.append(
                f"  flash allowlist consistent: {self.flash_consistent}")
        return "\n".join(lines)


# ------------------------------------------------------------ entry tracing

def _audit_abft(flash: bool = False):
    from repro.core.protected import ABFTConfig

    # XLA emulation path: the fused kernel's internals are opaque to the
    # walker, the emulation exposes the same semantics as real dots
    return ABFTConfig(use_pallas=False, flash_attention=flash)


def _zero_params(model, dtype):
    """Parameter pytree of zeros with init_params' exact structure —
    ``eval_shape`` keeps the audit from paying real RNG init."""
    shapes = jax.eval_shape(
        lambda k: model.init_params(k, dtype), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def _example_batch(model, batch: int, seq: int):
    cfg = model.cfg
    out = {"tokens": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.is_encoder_decoder:
        if cfg.n_mels:
            # stride-2 SAME conv halves T: 2*enc_seq_len frames in
            out["audio"] = jnp.zeros(
                (batch, 2 * cfg.enc_seq_len, cfg.n_mels), jnp.float32)
        else:
            out["enc_input"] = jnp.zeros(
                (batch, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.vision_dim:
        out["images"] = jnp.zeros(
            (batch, cfg.n_image_tokens, cfg.vision_dim), jnp.float32)
    return out


def trace_prefill(model, params, abft, *, batch=2, seq=8,
                  max_len=16, dtype=jnp.float32) -> list:
    from repro.models.layers import LayerCtx

    ctx = LayerCtx(abft=abft)
    cache = model.init_cache(batch, max_len, dtype)
    ex = _example_batch(model, batch, seq)
    closed = jax.make_jaxpr(
        lambda p, b, c: model.prefill(p, b, c, ctx))(params, ex, cache)
    return flop_ops(closed, entry="prefill")


def trace_decode(model, params, abft, *, batch=2, max_len=16,
                 dtype=jnp.float32) -> list:
    from repro.models.layers import LayerCtx

    ctx = LayerCtx(abft=abft)
    cache = model.init_cache(batch, max_len, dtype)
    token = jnp.zeros((batch, 1), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    closed = jax.make_jaxpr(
        lambda p, t, c, q: model.decode(p, t, c, q, ctx))(
            params, token, cache, pos)
    return flop_ops(closed, entry="decode")


def trace_engine_chunk(model, params, abft, *, batch=2, seq=8,
                       max_len=16, dtype=jnp.float32) -> list:
    """Trace the engine's REAL jitted ``_prefill_chunk`` step — the mixed
    prefill+decode serving path — not a hand-rolled approximation."""
    from repro.models.layers import ModelFault
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(model, params, slots=batch, max_len=max_len,
                      abft=abft, dtype=dtype, chunk_tokens=seq)
    toks = jnp.zeros((batch, seq), jnp.int32)
    slot_ids = jnp.arange(batch, dtype=jnp.int32)
    lengths = jnp.full((batch,), seq, jnp.int32)
    starts = jnp.zeros((batch,), jnp.int32)
    final = jnp.ones((batch,), bool)
    keys = eng.keys[:batch]
    closed = jax.make_jaxpr(
        lambda *a: eng._prefill_chunk(*a))(
            eng.params, toks, eng.cache, slot_ids, lengths, keys,
            None, starts, final, ModelFault.none())
    return flop_ops(closed, entry="engine._prefill_chunk")


def trace_engine_verify(model, params, abft, *, batch=2, draft_len=3,
                        max_len=16, dtype=jnp.float32) -> list:
    """Trace the engine's REAL jitted ``_verify`` step — the speculative
    K+1-token batched verify path.  Verify sites reuse the decode
    ``LayerSpec`` names with K-scaled token dims, so the plan crosscheck
    (which ignores the M dim) keeps its bijection with zero plan
    edits — exactly the property that lets scheme selection flip with K
    while the coverage proof stays closed."""
    from repro.models.layers import ModelFault
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(model, params, slots=batch, max_len=max_len,
                      abft=abft, dtype=dtype, spec_decode="ngram",
                      draft_len=draft_len)
    t = draft_len + 1
    toks = jnp.zeros((batch, t), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    mask = jnp.ones((batch,), bool)
    valid = jnp.full((batch,), t, jnp.int32)
    closed = jax.make_jaxpr(
        lambda *a: eng._verify(*a))(
            eng.params, toks, eng.cache, pos, mask, valid, eng.keys,
            None, ModelFault.none())
    return flop_ops(closed, entry="engine._verify")


def flash_allowlist_check(model, params, *, batch=2, max_len=16,
                          dtype=jnp.float32):
    """Validate the softmax allowlist against the model's real flash
    routing: re-trace decode with ``flash_attention=True`` — the
    allowlisted score/PV dots must vanish (the fused kernel replaces
    them).  Returns None when the model has no flash-routed attention
    (MLA never routes to flash; cross-attention is not flash-routed) or
    the kernel wrapper rejects the audit shapes."""
    from repro.models.model import layer_tags

    cfg = model.cfg
    if cfg.attention != "gqa" or cfg.cross_attn_every:
        return None
    if not any(t.split(":")[0] == "attn" for t in layer_tags(cfg)):
        return None
    try:
        ops = trace_decode(model, params, _audit_abft(flash=True),
                           batch=batch, max_len=max_len, dtype=dtype)
    except Exception:
        return None                    # kernel wrapper rejected shapes
    leftovers = [
        op for op in ops
        if op.primitive == "dot_general"
        and not parse_name_stack(op.name_stack).protected
        and "softmax" in parse_name_stack(op.name_stack).kinds
    ]
    return not leftovers


# ------------------------------------------------------------------ audits

def audit_model(model, phase: str = "mixed", *, plan=None, batch=2,
                seq=8, max_len=16, dtype=jnp.float32,
                check_flash: bool = True) -> AuditReport:
    """Audit one built Model.  ``phase``: prefill | decode | mixed
    (mixed traces the engine's jitted ``_prefill_chunk`` when the stack
    supports chunked prefill, else the prefill+decode union).  The plan
    crosscheck always runs over the union of all traced phases — some
    sites (``cross.k``, ``vision.proj``, ``enc.*``) execute only during
    prefill."""
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; one of {PHASES}")
    abft = _audit_abft()
    params = _zero_params(model, dtype)

    pre = trace_prefill(model, params, abft, batch=batch, seq=seq,
                        max_len=max_len, dtype=dtype)
    dec = trace_decode(model, params, abft, batch=batch,
                       max_len=max_len, dtype=dtype)
    traces = {"prefill": pre, "decode": dec}
    if phase == "mixed":
        if model.supports_chunked_prefill:
            # chunked-prefill mixed step + plain decode + the speculative
            # K+1-token verify step: with speculation on, EVERY serving
            # FLOP still flows through a registered scheme
            traces["mixed"] = trace_engine_chunk(
                model, params, abft, batch=batch, seq=seq,
                max_len=max_len, dtype=dtype) + dec + \
                trace_engine_verify(model, params, abft, batch=batch,
                                    max_len=max_len, dtype=dtype)
        else:
            traces["mixed"] = pre + dec

    want = {"mixed": ("prefill", "decode", "mixed")}.get(phase, (phase,))
    phases = {ph: PhaseCoverage(phase=ph, ops=classify(traces[ph]))
              for ph in want}

    union = [op for ops in traces.values() for op in ops]
    plan = plan if plan is not None else model.protection_plan()
    xc = crosscheck_plan(plan, union, model=model.cfg.name)

    flash = (flash_allowlist_check(
        model, params, batch=batch, max_len=max_len, dtype=dtype)
        if check_flash else None)
    return AuditReport(model=model.cfg.name, phases=phases,
                       crosscheck=xc, flash_consistent=flash)


def resolve_arch(name: str) -> str:
    """Registry name for a CLI-friendly alias (dashes/dots/underscores
    used interchangeably: ``llama3_2_1b`` -> ``llama3.2-1b``)."""
    from repro.configs import list_archs

    archs = list_archs()
    if name in archs:
        return name

    def canon(s: str) -> str:
        return s.replace("-", "_").replace(".", "_")

    hits = [a for a in archs if canon(a) == canon(name)]
    if len(hits) != 1:
        raise KeyError(
            f"unknown arch {name!r}; available: {archs}")
    return hits[0]


def audit_config(name: str, phase: str = "mixed", **kw) -> AuditReport:
    """Audit one registered architecture (scaled-down build: the audit
    is a static shape-level property — site structure, not weights — so
    the CPU-feasible config proves the same bijection)."""
    from repro.configs import get_config, scaled_down
    from repro.models.model import build_model

    cfg = scaled_down(get_config(resolve_arch(name)))
    return audit_model(build_model(cfg), phase=phase, **kw)
