"""Plan <-> trace cross-validation: the compiled ``ProtectionPlan`` and
the traced computation must agree site-for-site.

The plan (core/policy.py) is the deployment artifact that *claims* which
GEMM sites exist and how each is protected; the trace is what the model
*actually* executes.  ``crosscheck_plan`` proves the two describe the same
set of GEMMs:

* every plan ``LayerSpec`` name matches at least one traced ``abft[...]``
  site marker (else the plan lists a layer the model never runs — stale
  artifact);
* every traced site matches exactly one plan entry (else a GEMM was added
  to the model without a plan descriptor — silent coverage drift);
* the (k, n) GEMM class traced under a site equals the plan entry's
  descriptor dims (else the plan was compiled for different shapes).

Scheme equality is deliberately NOT required: the audit traces with one
backend config while a deployment plan may be compiled for another, and
the selection itself is the policy's job — the bijection is about the
*surface*, not the decision.

The M dim is likewise ignored: the plan's representative token count and
the trace's example batch are independent choices; k and n are the
weight-determined class identity.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.markers import parse_name_stack


@dataclasses.dataclass(frozen=True)
class CrossCheckResult:
    """Outcome of one plan <-> trace comparison."""

    model: str
    matched: tuple                  # site names present and agreeing
    plan_only: tuple                # plan layers never traced
    trace_only: tuple               # traced sites missing from the plan
    dim_mismatches: tuple           # (site, plan_kn, traced_kns)

    @property
    def bijective(self) -> bool:
        return not (self.plan_only or self.trace_only
                    or self.dim_mismatches)

    def report(self) -> str:
        """Diff-style report: one line per disagreement."""
        if self.bijective:
            return (f"plan <-> trace bijective for {self.model!r} "
                    f"({len(self.matched)} sites)")
        lines = [f"plan <-> trace MISMATCH for {self.model!r}:"]
        for name in self.plan_only:
            lines.append(
                f"  - plan-only layer {name!r}: listed in the plan but "
                f"never traced (stale plan, or the site was removed)")
        for name in self.trace_only:
            lines.append(
                f"  + trace-only site {name!r}: executed by the model "
                f"but absent from the plan (counting.layer_gemms drift)")
        for name, plan_kn, traced in self.dim_mismatches:
            lines.append(
                f"  ! dims differ at {name!r}: plan (k,n)={plan_kn}, "
                f"traced {sorted(traced)}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "bijective": self.bijective,
            "n_sites": len(self.matched),
            "matched": sorted(self.matched),
            "plan_only": sorted(self.plan_only),
            "trace_only": sorted(self.trace_only),
            "dim_mismatches": [
                {"site": s, "plan_kn": list(p),
                 "traced_kns": sorted(list(t) for t in ts)}
                for s, p, ts in self.dim_mismatches
            ],
        }


def traced_sites(ops) -> dict:
    """site tag -> set of traced (k, n) GEMM classes, from the PRIMARY
    protected dots only.  Check einsums contract against a rank-1
    checksum vector (n == 1); the protected GEMM itself always has
    n > 1, so the n > 1 filter isolates the op the site tag names."""
    sites: dict = {}
    for op in ops:
        if op.primitive != "dot_general" or op.n <= 1:
            continue
        m = parse_name_stack(op.name_stack)
        if m.protected:
            sites.setdefault(m.site, set()).add((op.k, op.n))
    return sites


def crosscheck_plan(plan, ops, model: str = "") -> CrossCheckResult:
    """Compare a compiled ProtectionPlan against a traced-op inventory
    (``jaxpr_walk.flop_ops`` output, typically the union of prefill and
    decode traces — some sites, e.g. ``cross.k``/``vision.proj``, only
    execute during prefill)."""
    traced = traced_sites(ops)
    plan_kn = {e.layer.name: (e.layer.dims.k, e.layer.dims.n)
               for e in plan.entries}

    plan_only = tuple(sorted(set(plan_kn) - set(traced)))
    trace_only = tuple(sorted(set(traced) - set(plan_kn)))
    matched, mismatches = [], []
    for name in sorted(set(plan_kn) & set(traced)):
        if traced[name] == {plan_kn[name]}:
            matched.append(name)
        else:
            mismatches.append(
                (name, plan_kn[name], frozenset(traced[name])))
    return CrossCheckResult(
        model=model or plan.model,
        matched=tuple(matched),
        plan_only=plan_only,
        trace_only=trace_only,
        dim_mismatches=tuple(mismatches),
    )
