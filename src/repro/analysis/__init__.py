"""Static protection-coverage analysis (the auditor).

Proves — by tracing the engine's real entry points to jaxprs and walking
every FLOP-carrying primitive — that each GEMM in the compiled step
functions flows through a registered ABFT scheme, and that the compiled
``ProtectionPlan`` and the traced computation agree site-for-site.

Modules:
  markers      — the ``jax.named_scope`` tagging protocol (survives
                 tracing through jit/scan into ``eqn.source_info``).
  jaxpr_walk   — recursive ClosedJaxpr walker + per-op FLOP accounting.
  crosscheck   — plan <-> trace bijection (LayerSpec <-> protected site).
  audit        — classification, coverage report, entry-point tracing.

CLI: ``python -m repro.launch.audit --config <name> [--phase ...]``.

Attribute access is lazy: core/protected.py imports the marker protocol
at dispatch time, so this package must not eagerly import the model zoo
(audit.py) back into core.
"""

_EXPORTS = {
    "AuditReport": "repro.analysis.audit",
    "ClassifiedOp": "repro.analysis.audit",
    "PhaseCoverage": "repro.analysis.audit",
    "audit_config": "repro.analysis.audit",
    "audit_model": "repro.analysis.audit",
    "classify": "repro.analysis.audit",
    "flash_allowlist_check": "repro.analysis.audit",
    "resolve_arch": "repro.analysis.audit",
    "CrossCheckResult": "repro.analysis.crosscheck",
    "crosscheck_plan": "repro.analysis.crosscheck",
    "TracedOp": "repro.analysis.jaxpr_walk",
    "flop_ops": "repro.analysis.jaxpr_walk",
    "coverage_scope": "repro.analysis.markers",
    "parse_name_stack": "repro.analysis.markers",
    "protection_scope": "repro.analysis.markers",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(
            f"module 'repro.analysis' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), name)
