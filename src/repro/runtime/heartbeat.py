"""Failure detection + straggler mitigation primitives.

Real multi-host TPU deployments detect failures via heartbeat timeouts at
the coordinator; this module implements the same control logic against a
pluggable clock/transport so it is deterministic under test (this container
has one host).  The trainer consumes:

* ``HeartbeatMonitor`` — per-worker liveness with a deadline; workers that
  miss the deadline are declared dead, triggering elastic re-mesh
  (runtime/elastic.py).
* ``StragglerPolicy`` — per-step duration tracking; a worker persistently
  slower than median * threshold is flagged for replacement with a hot
  spare *before* it fails hard (tail-latency mitigation at scale).

Pass a ``repro.obs.MetricsRegistry`` to ``HeartbeatMonitor`` to export
``worker_alive{worker=}`` and ``worker_heartbeat_staleness_seconds``
gauges alongside the serving telemetry.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, workers, timeout_s: float = 60.0, clock=time.monotonic,
                 registry=None):
        self.timeout = timeout_s
        self.clock = clock
        self.workers = {
            w: WorkerState(last_beat=self.clock()) for w in workers}
        self._g_alive = self._g_stale = None
        if registry is not None:
            self._g_alive = registry.gauge(
                "worker_alive", "1 while the worker meets its heartbeat "
                "deadline, 0 once declared dead", labels=("worker",))
            self._g_stale = registry.gauge(
                "worker_heartbeat_staleness_seconds",
                "seconds since the worker's last heartbeat, as of the "
                "last beat()/check()", labels=("worker",))
        self._publish()

    def _publish(self) -> None:
        if self._g_alive is None:
            return
        now = self.clock()
        for w, st in self.workers.items():
            self._g_alive.labels(worker=str(w)).set(1 if st.alive else 0)
            self._g_stale.labels(worker=str(w)).set(now - st.last_beat)

    def beat(self, worker) -> None:
        st = self.workers.get(worker)
        if st is not None:
            st.last_beat = self.clock()
            st.alive = True
        self._publish()

    def check(self) -> list:
        """Returns newly-dead workers (deadline exceeded)."""
        now = self.clock()
        dead = []
        for w, st in self.workers.items():
            if st.alive and now - st.last_beat > self.timeout:
                st.alive = False
                dead.append(w)
        self._publish()
        return dead

    @property
    def alive(self) -> list:
        return [w for w, st in self.workers.items() if st.alive]

    def remove(self, worker) -> None:
        self.workers.pop(worker, None)
        if self._g_alive is not None:
            self._g_alive.remove(worker=str(worker))
            self._g_stale.remove(worker=str(worker))

    def add(self, worker) -> None:
        self.workers[worker] = WorkerState(last_beat=self.clock())
        self._publish()


class StragglerPolicy:
    """Flags workers whose step time is persistently above
    median * threshold over a sliding window."""

    def __init__(self, threshold: float = 1.5, window: int = 8,
                 min_samples: int = 4):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.times: dict = defaultdict(lambda: deque(maxlen=window))

    def record(self, worker, step_time_s: float) -> None:
        self.times[worker].append(step_time_s)

    def stragglers(self) -> list:
        medians = {}
        for w, ts in self.times.items():
            if len(ts) >= self.min_samples:
                s = sorted(ts)
                medians[w] = s[len(s) // 2]
        if len(medians) < 2:
            return []
        global_median = sorted(medians.values())[len(medians) // 2]
        return [
            w for w, m in medians.items()
            if m > self.threshold * max(global_median, 1e-9)
        ]
