"""Elastic scaling: rebuild the mesh from surviving workers and reshard
state from the latest checkpoint.

Failure-recovery flow (trainer integrates all of it):

  1. HeartbeatMonitor declares worker(s) dead (or StragglerPolicy demotes a
     persistent straggler and promotes a hot spare).
  2. ``plan_remesh`` computes the largest usable (data, model) mesh from
     the surviving device set — model-parallel width is preserved (param
     layout compatibility); the data axis shrinks/grows.
  3. The global batch is re-split over the new data axis
     (``rescale_batch``) so optimization semantics are preserved.
  4. Checkpointer.restore(..., shardings=new) re-shards state onto the new
     mesh (jax.device_put handles arbitrary re-layout).

On this single-host container the device set is simulated; the logic and
tests exercise the control plane, and the same code drives
jax.distributed-backed device sets on real clusters.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    devices_used: int
    devices_idle: int

    @property
    def shape(self) -> tuple:
        return (self.data, self.model)


def plan_remesh(n_devices: int, model_parallel: int,
                min_data: int = 1) -> MeshPlan:
    """Largest (data, model) mesh from ``n_devices`` keeping the
    model-parallel width fixed (param shard layout stays valid)."""
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel} — a "
            f"degenerate mesh would invalidate every parameter shard")
    if n_devices < model_parallel * min_data:
        raise RuntimeError(
            f"not enough devices ({n_devices}) for model_parallel="
            f"{model_parallel} (min_data={min_data})")
    data = n_devices // model_parallel
    used = data * model_parallel
    return MeshPlan(
        data=data, model=model_parallel,
        devices_used=used, devices_idle=n_devices - used)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> dict:
    """Keep the global batch constant across re-meshes: per-replica batch
    changes; if new_data does not divide the global batch, pad with repeats
    and mask in the loss (returned as metadata)."""
    per = -(-global_batch // new_data)
    padded = per * new_data
    return {
        "per_replica": per,
        "padded_global": padded,
        "pad": padded - global_batch,
        "grad_scale": global_batch / padded,
    }


@dataclasses.dataclass
class ElasticState:
    """Bookkeeping the trainer keeps about the fleet."""

    model_parallel: int
    spares: list
    active: list

    def on_failure(self, dead: list) -> MeshPlan:
        self.active = [d for d in self.active if d not in set(dead)]
        # promote spares to replace dead workers when available
        while self.spares and len(self.active) % self.model_parallel:
            self.active.append(self.spares.pop())
        while self.spares:
            # absorb remaining spares only in full model-parallel groups
            if len(self.spares) >= self.model_parallel:
                for _ in range(self.model_parallel):
                    self.active.append(self.spares.pop())
            else:
                break
        if len(self.active) < self.model_parallel:
            # even one model-parallel group is unreachable: surface the
            # fleet state instead of planning a degenerate mesh (data=0)
            # the caller would only discover at reshard time
            raise RuntimeError(
                f"cannot re-mesh: {len(self.active)} surviving workers "
                f"(+{len(self.spares)} spares) cannot fill one "
                f"model_parallel={self.model_parallel} group after "
                f"losing {len(dead)} worker(s)")
        return plan_remesh(len(self.active), self.model_parallel)

    def on_straggler(self, worker) -> MeshPlan:
        """Replace a straggler with a spare if possible; otherwise demote
        it out of the mesh entirely."""
        if worker in self.active:
            self.active.remove(worker)
            if self.spares:
                self.active.append(self.spares.pop())
        return plan_remesh(len(self.active), self.model_parallel)
