"""repro: arithmetic-intensity-guided ABFT for NN inference/training on TPU.

Reproduction + extension of Kosaian & Rashmi, SC '21, as a multi-pod JAX
framework.  See DESIGN.md for the system inventory.
"""

__version__ = "0.1.0"
