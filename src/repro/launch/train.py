"""Production training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 200 --batch 8 --seq 256 [--scale full|smoke|100m] \
      --abft auto|global|block_1s|off [--ckpt-dir /tmp/ck]

Single-host it runs on local devices; on a real cluster the same driver is
launched per host after jax.distributed.initialize (flag --distributed).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core.policy import FixedPolicy, IntensityGuidedPolicy
from repro.core.protected import ABFTConfig
from repro.core.schemes import Scheme
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.models.counting import count_params
from repro.train import OptConfig, TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def scale_config(cfg, scale: str):
    if scale == "full":
        return cfg
    if scale == "smoke":
        return scaled_down(cfg)
    if scale == "100m":
        # ~100M-param member of the same family (example (b) driver)
        return scaled_down(
            cfg, d_model=768, n_layers=12, n_heads=12,
            n_kv_heads=min(cfg.n_kv_heads, 12) if cfg.n_kv_heads else 0,
            head_dim=64, d_ff=2048, vocab_size=32768)
    raise ValueError(scale)


def abft_config(mode: str) -> ABFTConfig:
    """Mode string -> ABFT config via the ProtectionPolicy API (the
    ABFTConfig facade only carries execution knobs)."""
    if mode == "off":
        return ABFTConfig.off()
    if mode == "auto":
        return ABFTConfig.from_policy(IntensityGuidedPolicy(),
                                      use_pallas=False)
    return ABFTConfig.from_policy(FixedPolicy(Scheme(mode)),
                                  use_pallas=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="llama3.2-1b")
    ap.add_argument("--scale", choices=["full", "smoke", "100m"],
                    default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--abft", default="auto",
                    choices=["auto", "global", "block_1s", "off"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = scale_config(get_config(args.arch), args.scale)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    n_params = count_params(cfg)
    print(f"arch={cfg.name} scale={args.scale} params~{n_params/1e6:.1f}M "
          f"abft={args.abft}")

    tcfg = TrainConfig(opt=OptConfig(lr=args.lr),
                       microbatches=args.microbatches)
    dcfg = DataConfig(global_batch=args.batch, seq_len=args.seq,
                      vocab_size=cfg.vocab_size)
    rcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(model, params, tcfg, dcfg, rcfg,
                      abft=abft_config(args.abft))
    if args.resume:
        trainer.maybe_restore()

    t0 = time.perf_counter()
    hist = trainer.run()
    dt = time.perf_counter() - t0
    toks = args.steps * args.batch * args.seq
    print(json.dumps({
        "first_loss": hist[0]["loss"] if hist else None,
        "last_loss": hist[-1]["loss"] if hist else None,
        "steps": len(hist),
        "tokens_per_s": toks / dt,
        "events": trainer.events,
    }, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
