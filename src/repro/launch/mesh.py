"""Historical mesh entry points — thin wrappers over the canonical
constructor in ``repro.distributed.mesh`` (one helper shared by the
serve, train-dryrun, and elastic drivers; see that module).

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
sets XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

from repro.distributed.mesh import build_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one pod = 16x16 (256 chips, v5e pod),
    multi-pod = 2 pods = 512 chips with a leading 'pod' DP axis."""
    if multi_pod:
        return build_mesh(pod=2, data=16, model=16)
    return build_mesh(data=16, model=16)


def make_host_mesh():
    """Single-process debug mesh over whatever devices exist (tests)."""
    return build_mesh(data=1, model=len(jax.devices()))


def make_mesh_from_devices(devices, *, model_parallel: int):
    """Elastic variant: build a (data, model) mesh from a surviving
    device list (runtime/elastic.py re-meshes after failures).  Raises
    when the survivors cannot host ``model_parallel`` — a silently
    narrowed model axis would invalidate every parameter shard."""
    return build_mesh(model=model_parallel, devices=devices)
