"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run sets
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one pod = 16x16 (256 chips, v5e pod),
    multi-pod = 2 pods = 512 chips with a leading 'pod' DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process debug mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_mesh_from_devices(devices, *, model_parallel: int):
    """Elastic variant: build a (data, model) mesh from a surviving device
    list (runtime/elastic.py re-meshes after failures)."""
    import numpy as np

    n = len(devices)
    mp = min(model_parallel, n)
    dp = n // mp
    usable = devices[: dp * mp]
    arr = np.array(usable).reshape(dp, mp)
    from jax.sharding import Mesh

    return Mesh(arr, ("data", "model"))
