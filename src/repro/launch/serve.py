"""Production serving driver: continuous batching + ABFT recovery stats.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --scale smoke --requests 8 --new-tokens 16 [--inject-faults] \
      [--fault-rate 0.2 --fault-kind transient --adaptive] \
      [--metrics-out m.json] [--trace-out t.json] [--log-events]

Fault-campaign flags: ``--fault-rate`` attaches a seeded ``FaultModel``
(continuous Bernoulli-per-step injection; ``--fault-kind permanent``
makes faults sticky across steps until ``--fault-duration`` expires),
and every injected fault is classified by the engine's shadow-stream
harness as corrected / uncorrected / SDC / masked.  ``--adaptive``
wraps the base policy in an ``ErrorAdaptivePolicy`` that escalates to
``global`` protection when the observed detection rate crosses
``--escalate-threshold`` and de-escalates with hysteresis when quiet.

Telemetry flags (repro/obs): ``--metrics-out`` writes the metrics
snapshot + fault-rate surface + final engine stats as one JSON artifact
(``benchmarks/check_telemetry_schema.py`` validates it);
``--trace-out`` writes a Chrome-trace/Perfetto JSON (load it at
https://ui.perfetto.dev); ``--log-events`` streams every trace event as
a JSON line to stderr while serving.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core.faults import FaultModel, FaultSpec
from repro.core.policy import (
    ErrorAdaptivePolicy,
    FixedPolicy,
    IntensityGuidedPolicy,
)
from repro.core.protected import ABFTConfig
from repro.core.schemes import Scheme
from repro.models import ModelFault, build_model
from repro.obs import ENGINE_COUNTERS, EngineTelemetry
from repro.runtime.heartbeat import HeartbeatMonitor
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine


def _chunk_tokens(v: str):
    """--chunk-tokens value: an int budget or 'auto' (roofline-tuned)."""
    if str(v).lower() == "auto":
        return "auto"
    return int(v)


def _draft_len(v: str):
    """--draft-len value: an int K or 'auto' (roofline-tuned)."""
    if str(v).lower() == "auto":
        return "auto"
    return int(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="llama3.2-1b")
    ap.add_argument("--scale", choices=["full", "smoke"], default="smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--abft", default="auto",
                    choices=["auto", "global", "block_1s", "off"])
    ap.add_argument("--inject-faults", action="store_true")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-step Bernoulli fault probability: attaches "
                         "a seeded FaultModel for continuous campaign "
                         "injection (0 = no campaign)")
    ap.add_argument("--fault-kind", default="transient",
                    choices=["transient", "permanent"],
                    help="campaign fault class: one-step transients or "
                         "sticky permanent faults that corrupt every "
                         "matching GEMM output until cleared")
    ap.add_argument("--fault-duration", type=int, default=8,
                    help="steps a sticky permanent fault persists")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="FaultModel RNG seed (same seed -> identical "
                         "injection schedule and classification)")
    ap.add_argument("--fault-magnitude", type=float, default=1e4,
                    help="injected value delta (0 = random exponent-bit "
                         "flips in the target dtype instead)")
    ap.add_argument("--adaptive", action="store_true",
                    help="wrap the base policy in ErrorAdaptivePolicy: "
                         "escalate to global protection when observed "
                         "detection/hard-fault rates cross thresholds, "
                         "de-escalate with hysteresis when quiet")
    ap.add_argument("--escalate-threshold", type=float, default=0.05,
                    help="windowed/EWMA detections-per-step rate that "
                         "triggers escalation (--adaptive)")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="clean recomputes after an ABFT detection")
    ap.add_argument("--raise-on-hard-fault", action="store_true",
                    help="crash instead of evicting on persistent faults")
    ap.add_argument("--cache", choices=["dense", "paged"], default="dense",
                    help="KV-cache layout (paged: block pool + tables)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged cache block size (tokens)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool size (default: dense-equivalent)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted prefix sharing + copy-on-write "
                         "(paged cache, attention-only models)")
    ap.add_argument("--mesh", type=int, default=None,
                    help="tensor-parallel width: shard params + paged KV "
                         "over a (data=1, model=N) device mesh and "
                         "compile the protection plan from the "
                         "POST-sharding per-device GEMM shapes (use "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=K to simulate devices on CPU)")
    ap.add_argument("--admit-lookahead", type=int, default=8,
                    help="bounded admission lookahead past a deferred "
                         "head request (HOL-blocking fix)")
    ap.add_argument("--chunk-tokens", type=_chunk_tokens, default=None,
                    help="chunked-prefill step token budget: decode "
                         "tokens pack first, the remainder is filled "
                         "with prompt chunks, so admission never stalls "
                         "decode (attention-only models).  'auto' picks "
                         "the smallest budget whose mixed-step intensity "
                         "clears the device CMR (roofline autotuning) "
                         "and re-tunes as occupancy drifts")
    ap.add_argument("--spec-decode", default=None,
                    choices=["ngram", "self-draft"],
                    help="speculative decoding proposer: 'ngram' "
                         "(prompt-lookup, zero model cost) or "
                         "'self-draft' (truncated-depth greedy draft "
                         "from the same weights).  Drafts run "
                         "unprotected; the K+1-token verify step goes "
                         "through the ABFT-checked path and greedy "
                         "streams stay byte-identical to the unsped "
                         "engine")
    ap.add_argument("--draft-len", type=_draft_len, default="auto",
                    help="draft tokens per verify step: an int K or "
                         "'auto' (largest K whose modeled per-emitted-"
                         "token time beats plain decode on the roofline;"
                         " re-tuned as occupancy drifts, shrunk by the "
                         "adaptive policy under escalation)")
    ap.add_argument("--draft-model", default=None, metavar="UNITS@WINDOW",
                    help="self-draft truncation spec 'units@window' "
                         "(e.g. '2@16'): how many scan units of the "
                         "serving weights the draft forward keeps and "
                         "how much trailing context it sees (only with "
                         "--spec-decode self-draft)")
    ap.add_argument("--plan-out", default=None,
                    help="dump the engine's compiled ProtectionPlan "
                         "(per-layer selections + step fast path) as a "
                         "JSON deployment artifact")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples per slot")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None,
                    help="write the telemetry metrics snapshot "
                         "(registry + fault-rate monitor + final engine "
                         "stats) as a JSON artifact")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the "
                         "serving run (spans: admit/prefill/decode_step/"
                         "abft_retry/...; instants: scheme flips, "
                         "evictions, fault detections)")
    ap.add_argument("--log-events", action="store_true",
                    help="stream every trace event as a JSON line to "
                         "stderr (structured event log)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = scaled_down(cfg)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
    if args.abft == "off":
        abft = ABFTConfig.off()
    else:
        base = (IntensityGuidedPolicy() if args.abft == "auto"
                else FixedPolicy(Scheme(args.abft)))
        if args.adaptive:
            base = ErrorAdaptivePolicy(
                base, detection_threshold=args.escalate_threshold)
        abft = ABFTConfig.from_policy(base, use_pallas=False)
    fault_model = None
    if args.fault_rate > 0:
        fault_model = FaultModel(
            transient_rate=(args.fault_rate
                            if args.fault_kind == "transient" else 0.0),
            permanent_rate=(args.fault_rate
                            if args.fault_kind == "permanent" else 0.0),
            permanent_duration=args.fault_duration,
            seed=args.fault_seed, layers=cfg.n_layers,
            dtype=jnp.float32,
            magnitude=args.fault_magnitude or None)
    policy = RecoveryPolicy(
        max_retries=args.max_retries,
        evict_on_hard_fault=not args.raise_on_hard_fault)
    draft_units, draft_window = 1, 8
    if args.draft_model:
        if args.spec_decode != "self-draft":
            ap.error("--draft-model requires --spec-decode self-draft")
        u, _, w = args.draft_model.partition("@")
        draft_units, draft_window = int(u), int(w or 8)
    telemetry = None
    if args.metrics_out or args.trace_out or args.log_events:
        sink = None
        if args.log_events:
            def sink(ev):
                print(json.dumps(ev), file=sys.stderr)
        telemetry = EngineTelemetry(
            trace=bool(args.trace_out or args.log_events),
            trace_sink=sink)
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.max_len, abft=abft,
                         dtype=jnp.float32, policy=policy, mesh=args.mesh,
                         cache_kind=args.cache, block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         prefix_sharing=args.prefix_sharing,
                         admit_lookahead=args.admit_lookahead,
                         chunk_tokens=args.chunk_tokens,
                         temperature=args.temperature, top_k=args.top_k,
                         seed=args.seed, telemetry=telemetry,
                         fault_model=fault_model,
                         spec_decode=(args.spec_decode.replace("-", "_")
                                      if args.spec_decode else None),
                         draft_len=(args.draft_len
                                    if args.spec_decode else None),
                         draft_units=draft_units, draft_window=draft_window)
    heartbeats = None
    if engine.mesh is not None:
        # liveness surface for the sharded fleet: one worker per mesh
        # device, exported as worker_alive / staleness gauges on the
        # telemetry registry (runtime/heartbeat.py) — a stalled shard
        # shows up in the same metrics artifact as the engine counters
        heartbeats = HeartbeatMonitor(
            [str(d) for d in engine.mesh.devices.flat],
            registry=telemetry.registry if telemetry is not None
            else None)
    if args.plan_out:
        with open(args.plan_out, "w") as fh:
            fh.write(engine.plan.to_json())
        print(f"wrote protection plan -> {args.plan_out}")
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    size=rng.integers(4, 12)).astype(
                    np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    fault_at = None
    if args.inject_faults:
        fault_at = (3, ModelFault.at(
            0, "mlp_down", FaultSpec.value(0, 1, 1e5)))
    # monotonic clock everywhere latency is derived: wall-clock
    # adjustments must never produce negative TTFT/ITL
    t0 = time.perf_counter()
    results = engine.run(reqs, fault_at=fault_at)
    dt = time.perf_counter() - t0
    if heartbeats is not None:
        # the in-process shards all progressed iff run() returned: beat
        # every worker once, then publish staleness as of completion
        for w in list(heartbeats.workers):
            heartbeats.beat(w)
        assert not heartbeats.check()
    if telemetry is not None:
        # TTFT/ITL histograms: the driver owns arrival time, so the
        # per-token engine stamps become latency observations here
        for r in reqs:
            if r.times:
                telemetry.observe_ttft(r.times[0] - t0)
            for a, b in zip(r.times, r.times[1:]):
                telemetry.observe_itl(b - a)
    print(json.dumps({
        "requests": len(results),
        "tokens": engine.stats.tokens,
        "tokens_per_s": engine.stats.tokens / dt,
        "faults_detected": engine.stats.faults_detected,
        "retries": engine.stats.retries,
        "hard_faults": engine.stats.hard_faults,
        "evictions": engine.stats.evictions,
        "rejections": engine.stats.rejections,
        "prefix_hit_rate": engine.stats.prefix_hit_rate,
        "cow_copies": engine.stats.cow_copies,
        "prefill_chunks": engine.stats.prefill_chunks,
        "mixed_steps": engine.stats.mixed_steps,
        "decode_only_steps": engine.stats.decode_only_steps,
        "campaign": ({
            "faults_injected": engine.stats.faults_injected,
            "faults_corrected": engine.stats.faults_corrected,
            "faults_uncorrected": engine.stats.faults_uncorrected,
            "sdc_faults": engine.stats.sdc_faults,
            "masked_faults": engine.stats.masked_faults,
            "schedule": fault_model.schedule,
        } if fault_model is not None else None),
        "protection_level": engine.protection_level,
        "protection_escalations": engine.stats.protection_escalations,
        "protection_deescalations":
            engine.stats.protection_deescalations,
        "chunk_tokens": engine.chunk_tokens,
        "chunk_budget_retunes": engine.stats.chunk_budget_retunes,
        "spec_decode": ({
            "proposer": engine.spec.name,
            "draft_len": engine.draft_len,
            "draft_proposed": engine.stats.draft_proposed,
            "draft_accepted": engine.stats.draft_accepted,
            "accept_rate": (engine.stats.draft_accepted
                            / engine.stats.draft_proposed
                            if engine.stats.draft_proposed else None),
            "verify_retries": engine.stats.verify_retries,
        } if engine.spec is not None else None),
        "model_parallel": engine.model_parallel,
        "shard_plan": ([{"layer": r["layer"], "scheme": r["scheme"],
                         "ai": r["ai"], "bound": r["bound"]}
                        for r in engine.plan.report_rows()]
                       if engine.mesh is not None else None),
        "errors": {r.uid: r.error for r in reqs if r.error},
        "cache": engine.cache_stats(),
        "telemetry": (telemetry.faults.snapshot()
                      if telemetry is not None else None),
    }))
    if args.metrics_out:
        stats = engine.stats
        artifact = telemetry.snapshot()
        artifact["engine_stats"] = {
            k: getattr(stats, a) for k, a in ENGINE_COUNTERS.items()}
        artifact["counters_match_stats"] = telemetry.counters_match(stats)
        with open(args.metrics_out, "w") as fh:
            json.dump(artifact, fh, indent=2)
        print(f"wrote metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        telemetry.tracer.write(args.trace_out)
        print(f"wrote trace ({len(telemetry.tracer.events)} events) -> "
              f"{args.trace_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
