"""Protection-coverage audit CLI: prove every FLOP in the traced entry
points flows through a registered ABFT scheme (analysis/audit.py).

  PYTHONPATH=src python -m repro.launch.audit --config llama3.2-1b \
      --phase mixed --fail-under 1.0
  PYTHONPATH=src python -m repro.launch.audit --all \
      --json results/AUDIT_coverage.json

Exit status: nonzero when any audited config's protected fraction falls
below ``--fail-under``, or when any plan <-> trace crosscheck is not
bijective (stale / drifted ProtectionPlan) — both are CI-gate failures.
Config names accept dash/dot/underscore aliases (``llama3_2_1b``).
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.analysis.audit import PHASES, audit_config, resolve_arch
from repro.configs import ALL_ARCHS

SCHEMA = "repro/audit_coverage/v1"


def run_audits(names, phase: str) -> dict:
    """name -> AuditReport, printing each summary as it lands."""
    reports = {}
    for name in names:
        rep = audit_config(name, phase=phase)
        reports[name] = rep
        print(rep.summary())
        print()
    return reports


def to_payload(reports: dict, phase: str) -> dict:
    return {
        "schema": SCHEMA,
        "phase": phase,
        "configs": {name: rep.to_json()
                    for name, rep in sorted(reports.items())},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="jaxpr-level ABFT protection-coverage audit")
    ap.add_argument("--config", default=None,
                    help="architecture to audit (alias-friendly: "
                         "llama3_2_1b == llama3.2-1b)")
    ap.add_argument("--all", action="store_true",
                    help="audit every registered architecture")
    ap.add_argument("--phase", choices=PHASES, default="mixed")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full machine-readable report here")
    ap.add_argument("--fail-under", type=float, default=None,
                    metavar="FRAC",
                    help="exit nonzero if any protected fraction is "
                         "below FRAC (e.g. 1.0)")
    args = ap.parse_args(argv)

    if args.all:
        names = list(ALL_ARCHS)
    elif args.config:
        names = [resolve_arch(args.config)]
    else:
        ap.error("one of --config <name> or --all is required")

    reports = run_audits(names, args.phase)

    if args.json:
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(to_payload(reports, args.phase),
                                   indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}")

    failed = False
    for name, rep in reports.items():
        if not rep.crosscheck.bijective:
            print(f"FAIL {name}: plan <-> trace not bijective")
            failed = True
        if (args.fail_under is not None
                and rep.protected_fraction < args.fail_under):
            print(f"FAIL {name}: protected fraction "
                  f"{rep.protected_fraction:.4f} < {args.fail_under}")
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
