import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis per cell.

This is the proof that the distribution config is coherent without real
hardware (512 placeholder host devices).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.
"""

import argparse
import gzip
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.core.hardware import TPU_V5E
from repro.core.policy import FixedPolicy, IntensityGuidedPolicy
from repro.core.protected import ABFTConfig
from repro.core.schemes import Scheme
from repro.distributed import sharding as shd
from repro.distributed.mesh import make_hints
from repro.launch.mesh import make_production_mesh
from repro.models import LayerCtx, build_model
from repro.models.counting import model_flops
from repro.roofline.analysis import analyze_compiled
from repro.train import OptConfig, TrainConfig, init_opt_state, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

# Perf-iteration variants (EXPERIMENTS.md §Perf): --set key=value tweaks
# one aspect of the cell build; baseline is the empty dict.
VARIANT: dict = {}

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (decode state is O(1) / KV-linear); skip for pure full-attention archs
# (DESIGN.md §Arch-applicability).
LONG_OK = {"mamba2-1.3b", "jamba-v0.1-52b"}


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        return "long_500k skipped: pure full-attention arch (DESIGN.md §4)"
    return None


def dryrun_abft(arch: str) -> ABFTConfig:
    """ABFT policy used inside the dry-run graph: intensity-guided
    selection (ProtectionPolicy API) with the XLA emulation of the fused
    kernel (use_pallas=False; see core/protected.py — a custom-call's
    internals are opaque to cost_analysis either way)."""
    mode = VARIANT.get("abft", "auto")
    if mode == "off":
        return ABFTConfig.off()
    if mode == "auto":
        return ABFTConfig.from_policy(IntensityGuidedPolicy(),
                                      use_pallas=False)
    return ABFTConfig.from_policy(FixedPolicy(Scheme(mode)),
                                  use_pallas=False)


def _moment_dtype(cfg) -> str:
    from repro.models.counting import count_params

    return "bfloat16" if count_params(cfg) >= 100e9 else "float32"


# make_hints moved to repro.distributed.mesh (shared with the serving
# MeshExecutor); imported above for the cell builders below.


def build_cell(arch: str, shape: str, mesh):
    """Returns (fn, args_structs, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    if "pad_heads" in VARIANT:
        import dataclasses as _dc

        hp = int(VARIANT["pad_heads"])
        kvp = int(VARIANT.get("pad_kv_heads", hp))
        cfg = _dc.replace(cfg, pad_heads_to=hp, pad_kv_heads_to=kvp)
    spec = SHAPES[shape]
    model = build_model(cfg)
    abft = dryrun_abft(arch)
    B, S = spec["batch"], spec["seq"]
    dt = jnp.bfloat16
    hints = make_hints(cfg, mesh)

    params_struct = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), dtype=dt))
    fsdp = None
    if "fsdp" in VARIANT:
        fsdp = VARIANT["fsdp"] != "off"
    p_spec = shd.param_specs(cfg, params_struct, mesh, fsdp=fsdp)
    p_shard = shd.make_sharding(mesh, p_spec)
    ba = shd.batch_axes(mesh)

    def _batch_struct(b, s):
        d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if spec["kind"] == "train":
            d["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            d["enc_input"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq_len, cfg.d_model), dt)
        if cfg.vision_dim:
            d["images"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.vision_dim), dt)
        return d

    if spec["kind"] == "train":
        ocfg = OptConfig(moment_dtype=_moment_dtype(cfg))
        tcfg = TrainConfig(
            opt=ocfg, microbatches=int(VARIANT.get("microbatches", 1)))
        opt_struct = jax.eval_shape(
            lambda p: init_opt_state(p, ocfg), params_struct)
        o_spec = shd.opt_state_specs(cfg, opt_struct, mesh)
        o_shard = shd.make_sharding(mesh, o_spec)
        batch = _batch_struct(B, S)
        b_spec = {k: (P(ba, None) if v.ndim == 2 else P(ba, None, None))
                  for k, v in batch.items()}
        b_shard = shd.make_sharding(mesh, b_spec)
        step = make_train_step(model, abft, tcfg, hints=hints)

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        args = (params_struct, opt_struct, batch)
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard,
                  jax.tree_util.tree_map(
                      lambda _: NamedSharding(mesh, P()), {
                          "loss": 0, "aux_loss": 0, "abft_flag": 0,
                          "grad_norm": 0, "total_loss": 0}))
        meta = dict(tokens=B * S, training=True)
        return fn, args, in_sh, out_sh, meta

    cache_struct = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=dt))
    c_spec = shd.cache_specs(
        cfg, cache_struct, mesh, B,
        kv_fallback=VARIANT.get("kv_fallback", "headdim"))
    c_shard = shd.make_sharding(mesh, c_spec)
    lg_spec = shd.sanitize_spec(
        shd.logits_spec(mesh, B), (B, 1, cfg.vocab_size), mesh)
    lg_shard = NamedSharding(mesh, lg_spec)
    fl_shard = NamedSharding(mesh, P())
    ctx = LayerCtx(abft=abft, hints=hints)

    if spec["kind"] == "prefill":
        batch = _batch_struct(B, S)
        b_spec = {k: (P(ba, None) if v.ndim == 2 else P(ba, None, None))
                  for k, v in batch.items()}
        b_shard = shd.make_sharding(mesh, b_spec)

        def fn(params, batch, cache):
            return model.prefill(params, batch, cache, ctx)

        args = (params_struct, batch, cache_struct)
        in_sh = (p_shard, b_shard, c_shard)
        out_sh = (lg_shard, c_shard, fl_shard)
        meta = dict(tokens=B * S, training=False)
        return fn, args, in_sh, out_sh, meta

    # decode: one new token against a seq_len-deep cache
    tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = P(ba, None) if B >= mesh.devices.size // mesh.shape[
        "model"] else P(None, None)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, token, cache, pos):
        return model.decode(params, token, cache, pos, ctx)

    args = (params_struct, tok_struct, cache_struct, pos_struct)
    in_sh = (p_shard, NamedSharding(mesh, tok_spec), c_shard,
             NamedSharding(mesh, P()))
    out_sh = (lg_shard, c_shard, fl_shard)
    meta = dict(tokens=B, training=False)
    return fn, args, in_sh, out_sh, meta


def run_cell(arch: str, shape: str, mesh_kind: str, outdir: pathlib.Path,
             force: bool = False) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = ""
    if VARIANT:
        suffix = "__" + "-".join(f"{k}={v}" for k, v in sorted(
            VARIANT.items()))
    path = outdir / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if rec.get("status") != "error":
            print(f"[skip-cached] {path.name}")
            return rec

    reason = skip_reason(arch, shape)
    if reason:
        rec = dict(arch=arch, shape=shape, mesh=mesh_kind, status="skipped",
                   reason=reason)
        path.write_text(json.dumps(rec, indent=2))
        print(f"[skipped] {arch} {shape}: {reason}")
        return rec

    t0 = time.perf_counter()
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind)
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
        fn, args, in_sh, out_sh, meta = build_cell(arch, shape, mesh)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        hlo_text = compiled.as_text()
        if os.environ.get("DRYRUN_SAVE_HLO", "1") == "1":
            hlo_path = path.with_suffix(".hlo.txt.gz")
            with gzip.open(hlo_path, "wt") as f:
                f.write(hlo_text)
        print(compiled.memory_analysis())
        cost = compiled.cost_analysis()
        print({k: v for k, v in (cost[0] if isinstance(cost, (list, tuple))
                                 else cost).items()
               if k in ("flops", "bytes accessed")})
        analysis = analyze_compiled(compiled, TPU_V5E)
        cfg = get_config(arch)
        mf = model_flops(cfg, meta["tokens"], meta["training"])
        chips = mesh.devices.size
        hlo_flops_global = analysis["flops_per_device"] * chips
        rec.update(
            status="ok",
            variant=dict(VARIANT),
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            model_flops=mf,
            hlo_flops_global=hlo_flops_global,
            useful_flops_ratio=(
                mf / hlo_flops_global if hlo_flops_global else 0.0),
            **analysis,
        )
        print(f"[ok] {arch} {shape} {mesh_kind}: "
              f"compute={analysis['compute_s']:.4f}s "
              f"memory={analysis['memory_s']:.4f}s "
              f"collective={analysis['collective_s']:.4f}s "
              f"bound={analysis['bottleneck']} "
              f"hbm/dev={analysis['hbm_per_device_gib']:.2f}GiB "
              f"(compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record failures per cell
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[error] {arch} {shape} {mesh_kind}: {e}")
    path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "pod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="perf-variant knob key=value (repeatable)")
    args = ap.parse_args()
    for kv in args.set:
        k, _, v = kv.partition("=")
        VARIANT[k] = v

    outdir = pathlib.Path(args.out)
    meshes = ["single", "pod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    n_ok = n_err = 0
    for arch, shape, mk in cells:
        rec = run_cell(arch, shape, mk, outdir, force=args.force)
        n_ok += rec.get("status") in ("ok", "skipped")
        n_err += rec.get("status") == "error"
    print(f"done: {n_ok} ok/skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
