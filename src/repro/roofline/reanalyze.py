"""Re-derive roofline terms for every saved dry-run cell from its gzipped
HLO — lets parser improvements apply without recompiling.

  PYTHONPATH=src python -m repro.roofline.reanalyze [results/dryrun ...]
"""

from __future__ import annotations

import gzip
import json
import pathlib
import sys

from repro.core.hardware import TPU_V5E
from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_parser import analyze_hlo


def reanalyze_dir(d: pathlib.Path) -> int:
    n = 0
    for hlo_path in sorted(d.glob("*.hlo.txt.gz")):
        json_path = hlo_path.with_name(
            hlo_path.name.replace(".hlo.txt.gz", ".json"))
        if not json_path.exists():
            continue
        rec = json.loads(json_path.read_text())
        if rec.get("status") != "ok":
            continue
        parsed = analyze_hlo(gzip.open(hlo_path, "rt").read())
        flops = max(parsed["flops"], rec.get("xla_cost_flops", 0.0))
        bytes_ = max(parsed["bytes"], rec.get("xla_cost_bytes", 0.0))
        coll = parsed["collective_bytes"]
        rec.update(
            flops_per_device=flops,
            bytes_per_device=bytes_,
            collective_bytes_per_device=coll,
            collectives=dict(parsed["collectives"],
                             _counts=parsed["collective_op_counts"]),
            **roofline_terms(flops, bytes_, coll, TPU_V5E),
        )
        chips = rec.get("chips", 256)
        rec["hlo_flops_global"] = flops * chips
        if rec.get("model_flops"):
            rec["useful_flops_ratio"] = (
                rec["model_flops"] / rec["hlo_flops_global"])
        json_path.write_text(json.dumps(rec, indent=2, default=str))
        n += 1
    return n


def main():
    dirs = [pathlib.Path(p) for p in (sys.argv[1:] or ["results/dryrun",
                                                       "results/perf"])]
    total = 0
    for d in dirs:
        if d.exists():
            n = reanalyze_dir(d)
            print(f"{d}: reanalyzed {n} cells")
            total += n
    return 0 if total else 1


if __name__ == "__main__":
    raise SystemExit(main())
