"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh):

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

cost_analysis() reports the per-device partitioned module, so the per-chip
division is already applied; collective bytes are parsed from the post-SPMD
HLO text (per-device payloads of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).
"""

from __future__ import annotations

import re


from repro.core.hardware import TPU_V5E, HardwareSpec

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op per-device payload bytes, summed over the module.

    Counts the *result* shapes of each collective op start (handles both
    sync ops and -start/-done async pairs, counting starts only)."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for op in COLLECTIVE_OPS:
            # match ` = <type> op(` and async starts; skip -done ops
            if f" {op}(" in s or f" {op}-start(" in s:
                lhs, _, rhs = s.partition("=")
                # result type(s): between '=' and the op name
                idx = rhs.find(op)
                result_seg = rhs[:idx]
                nbytes = sum(
                    _shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(result_seg)
                )
                out[op] += nbytes
                counts[op] += 1
                break
    out["_counts"] = counts
    return out


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    hw: HardwareSpec = TPU_V5E,
) -> dict:
    t_compute = flops_per_device / hw.peak_flops
    t_memory = bytes_per_device / hw.hbm_bw
    t_collective = collective_bytes_per_device / hw.ici_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dom = max(terms, key=terms.get)
    bound = {"compute_s": "compute", "memory_s": "memory",
             "collective_s": "collective"}[dom]
    t_bound = max(terms.values())
    total = sum(terms.values())
    return dict(
        terms,
        bottleneck=bound,
        t_bound_s=t_bound,
        # roofline fraction: how much of the step the dominant term is of a
        # perfectly-overlapped ideal (1.0 = at the dominant roof)
        roofline_fraction=(t_bound / total) if total > 0 else 0.0,
    )


def analyze_compiled(compiled, hw: HardwareSpec = TPU_V5E) -> dict:
    """Extract flops / bytes / collective payloads from one compiled
    executable (per-device post-SPMD module).

    Primary numbers come from the scan-corrected HLO parser
    (roofline/hlo_parser.py) — XLA's cost_analysis counts while bodies
    once, undercounting scanned layer stacks; both are recorded."""
    from repro.roofline.hlo_parser import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    parsed = analyze_hlo(hlo)
    flops = max(parsed["flops"], xla_flops)
    bytes_ = max(parsed["bytes"], xla_bytes)
    colls = dict(parsed["collectives"])
    colls["_counts"] = parsed["collective_op_counts"]
    coll_total = parsed["collective_bytes"]

    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))

    rec = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll_total,
        "collectives": colls,
        "xla_cost_flops": xla_flops,      # raw (while bodies counted once)
        "xla_cost_bytes": xla_bytes,
        "memory": mem_rec,
        "hbm_per_device_gib": (
            (mem_rec.get("argument_size_in_bytes", 0)
             + mem_rec.get("output_size_in_bytes", 0)
             + mem_rec.get("temp_size_in_bytes", 0)
             - mem_rec.get("alias_size_in_bytes", 0)) / 2**30
        ),
    }
    rec.update(roofline_terms(flops, bytes_, coll_total, hw))
    return rec
