"""Per-op HBM-traffic profile of a compiled dry-run cell — the "profiler"
of the perf loop (§Perf): ranks byte/flop contributors with loop
multiplicities applied.

  PYTHONPATH=src python -m repro.roofline.profile \
      results/dryrun/llama3.2-1b__train_4k__single.hlo.txt.gz [topN]
"""

from __future__ import annotations

import gzip
import re
import sys
from collections import defaultdict

from repro.roofline import hlo_parser as hp


def top_contributors(text: str, n: int = 20) -> list:
    comps, entry = hp.parse_module(text)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))

    mult = defaultdict(float)

    def visit(name, k, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        mult[name] += k
        for op in comp.ops:
            if op.kind == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                trips = op.trip_count
                if not trips and cm and cm.group(1) in comps:
                    trips = hp._trip_from_condition(comps[cm.group(1)])
                trips = max(trips, 1)
                if bm:
                    visit(bm.group(1), k * trips, depth + 1)
                if cm:
                    visit(cm.group(1), k * trips, depth + 1)
            else:
                for c in op.callees:
                    visit(c, k, depth + 1)

    visit(entry, 1.0)
    fusion_children = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                fusion_children.update(op.callees)

    rows = []
    for name, comp in comps.items():
        k = mult.get(name, 0.0)
        if k == 0 or name in fusion_children:
            continue
        for op in comp.ops:
            if op.kind in hp._FREE_OPS:
                continue
            if any(op.kind.startswith(c) for c in hp.COLLECTIVES):
                rows.append((k * op.result_bytes, k, f"[coll]{op.kind}",
                             name, op.line))
                continue
            b = (op.traffic_override if op.traffic_override >= 0
                 else op.result_bytes + op.operand_bytes)
            rows.append((k * b, k, op.kind, name, op.line))
    rows.sort(reverse=True)
    return rows[:n]


def main():
    path = sys.argv[1]
    n = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    opener = gzip.open if path.endswith(".gz") else open
    text = opener(path, "rt").read()
    rows = top_contributors(text, n)
    total = sum(r[0] for r in rows)
    print(f"top-{n} contributors (sum {total:.3e} B):")
    for b, k, kind, comp, line in rows:
        print(f"{b:10.3e}  x{k:7.0f}  {kind:20s} {comp[:28]:28s} "
              f"{line[:80]}")


if __name__ == "__main__":
    main()
