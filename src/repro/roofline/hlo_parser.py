"""Scan-corrected HLO cost analysis.

XLA's built-in HloCostAnalysis counts each ``while`` body ONCE, which
undercounts scanned layer stacks by the trip count (a 61-layer scan counts
as one layer).  This parser rebuilds the cost from the post-SPMD HLO text:

  1. split the module into computations;
  2. build the call graph with multiplicities — ``while`` bodies multiply
     by their trip count (XLA annotates ``known_trip_count`` in
     backend_config; fallback: the constant bound in the loop condition),
     fusions/calls/conditionals multiply by 1;
  3. cost each computation:
       * FLOPs: dot ops (2 * output_elems * contraction_size), found in any
         computation (including fused ones);
       * bytes: at *fusion granularity* for top-level ops (operands +
         outputs of fusions, dots, copies, slices — elementwise chains
         inside a fusion are free, which is the fusion memory model);
         plumbing ops (tuple/gte/bitcast/parameter/while) are free;
       * collective payloads: result bytes of all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute starts;
  4. total = sum over computations of cost x path multiplicity from entry.

All shapes in the post-SPMD module are per-device, so totals are per-chip.
Validated against hand-computed scanned-GEMM modules in
tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_KIND_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_CALL_ATTR = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count.....n...(\d+)')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operand/result bytes do not represent HBM traffic
_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "call", "opt-barrier",
}


def _dims(s: str):
    return [int(d) for d in s.split(",")] if s else []


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    n = 1
    for d in _dims(dims):
        n *= d
    return n * bpe


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    operand_bytes: int
    flops: float
    callees: list
    trip_count: int
    line: str
    result_dims: list = dataclasses.field(default_factory=list)
    operand_names: list = dataclasses.field(default_factory=list)
    is_root: bool = False
    traffic_override: float = -1.0   # >=0: use this instead of res+ops


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


def parse_module(text: str):
    """Returns ({computation_name: Computation}, entry_name)."""
    comps: dict = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped and (
                    stripped.startswith("%") or stripped.startswith("ENTRY")):
                is_entry = stripped.startswith("ENTRY")
                name = stripped.split()[1 if is_entry else 0]
                name = name.lstrip("%").split("(")[0].rstrip()
                cur = Computation(name=name, ops=[])
                if is_entry:
                    entry = name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        op = _parse_op(name, rhs, stripped)
        op.is_root = stripped.startswith("ROOT")
        cur.ops.append(op)
    for comp in comps.values():
        _resolve_flops(comp)
        _resolve_dus(comp)
    return comps, entry


def _resolve_dus(comp: "Computation") -> None:
    """dynamic-update-slice writes only the update slice in place; traffic
    is ~2x the update operand, not the full aliased buffer."""
    by_name = {op.name: op for op in comp.ops}
    for op in comp.ops:
        if op.kind != "dynamic-update-slice" or len(op.operand_names) < 2:
            continue
        upd = by_name.get(op.operand_names[1])
        if upd is not None:
            op.traffic_override = 2.0 * upd.result_bytes


def _resolve_flops(comp: "Computation") -> None:
    """Second pass: dot FLOPs need the lhs operand's shape, which in
    scheduled HLO lives on the operand's *defining op*, not inline."""
    by_name = {op.name: op for op in comp.ops}
    for op in comp.ops:
        if op.kind != "dot":
            continue
        out_elems = 1
        for d in (op.result_dims[0] if op.result_dims else []):
            out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        lhs = by_name.get(op.operand_names[0]) if op.operand_names else None
        contract = 1
        if m and lhs is not None and lhs.result_dims:
            lhs_dims = lhs.result_dims[0]
            for ci in _dims(m.group(1)):
                if ci < len(lhs_dims):
                    contract *= lhs_dims[ci]
        op.flops = 2.0 * out_elems * contract


def _parse_op(name: str, rhs: str, line: str) -> Op:
    km = _KIND_RE.search(" " + rhs)
    kind = km.group(1) if km else rhs.split("(")[0].split()[-1]
    idx = rhs.find(f"{kind}(") if km else -1
    result_seg = rhs[:idx] if idx >= 0 else rhs
    result_bytes = sum(
        _shape_bytes(d, s) for d, s in _SHAPE_TOKEN.findall(result_seg))

    operand_bytes = 0
    if idx >= 0:
        paren = rhs.find("(", idx)
        depth, end = 0, paren
        for i in range(paren, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_bytes = sum(
            _shape_bytes(d, s)
            for d, s in _SHAPE_TOKEN.findall(rhs[paren:end]))

    callees = _CALL_ATTR.findall(line)
    bm = _BRANCH_ATTR.search(line)
    if bm:
        callees += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
    tm = _TRIP_RE.search(line)
    trips = int(tm.group(1)) if tm else 0
    result_dims = [
        _dims(s_) for _, s_ in _SHAPE_TOKEN.findall(result_seg)]
    operand_names = []
    if idx >= 0:
        operand_names = re.findall(r"%([\w\.\-]+)", rhs[idx:end + 1])
    return Op(name=name, kind=kind, result_bytes=result_bytes,
              operand_bytes=operand_bytes, flops=0.0, callees=callees,
              trip_count=trips, line=line, result_dims=result_dims,
              operand_names=operand_names)


def _trip_from_condition(cond: Computation) -> int:
    consts = {}
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if "direction=LT" in op.line:
            for cname, val in consts.items():
                if re.search(rf"%{re.escape(cname)}\b", op.line):
                    return val
    if len(consts) == 1:
        return next(iter(consts.values()))
    return 1


def analyze_hlo(text: str) -> dict:
    comps, entry = parse_module(text)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))

    mult = defaultdict(float)
    fusion_children = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                fusion_children.update(
                    c for c in op.callees if c != comp.name)

    def visit(name: str, k: float, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        mult[name] += k
        for op in comp.ops:
            if op.kind == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                trips = op.trip_count
                if not trips and cm and cm.group(1) in comps:
                    trips = _trip_from_condition(comps[cm.group(1)])
                trips = max(trips, 1)
                if bm:
                    visit(bm.group(1), k * trips, depth + 1)
                if cm:
                    visit(cm.group(1), k * trips, depth + 1)
            else:
                for c in op.callees:
                    visit(c, k, depth + 1)

    visit(entry, 1.0)

    # Effective per-parameter read bytes for fused computations: a
    # parameter consumed ONLY by dynamic-slice/gather ops is read at the
    # slice size per call, not the full buffer (layer-stacked weights in a
    # scan, embedding tables) — charging the whole buffer per iteration
    # would overcount weight traffic by the layer count.  Consumption is
    # chased through convert/bitcast/copy chains: the CPU backend wraps
    # bf16 buffers in f32 converts around slice/update ops.
    _CHAIN = ("convert", "bitcast", "copy", "reshape")

    def _eff_consumers(comp, pname):
        """Ops that actually consume pname, transitively through chains.
        Returns list of (op, via) where via is the immediate operand name
        feeding the consumer."""
        out, frontier, seen = [], [pname], set()
        while frontier:
            cur = frontier.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for o in comp.ops:
                if cur in o.operand_names and o.kind != "parameter":
                    if o.kind in _CHAIN:
                        frontier.append(o.name)
                    else:
                        out.append((o, cur))
        return out

    eff_params: dict = {}
    for name, comp in comps.items():
        params = {}
        for op in comp.ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    params[op.name] = (int(m.group(1)), op.result_bytes)
        if not params:
            continue
        eff = {}
        for pname, (pidx, pbytes) in params.items():
            consumers = _eff_consumers(comp, pname)
            if consumers and all(
                    o.kind in ("dynamic-slice", "gather")
                    for o, _ in consumers):
                eff[pidx] = sum(o.result_bytes for o, _ in consumers)
            elif consumers and all(
                    o.kind == "dynamic-update-slice"
                    and o.operand_names and o.operand_names[0] == via
                    for o, via in consumers):
                eff[pidx] = 0.0   # aliased in-place carry (cache buffer)
            else:
                eff[pidx] = pbytes
        eff_params[name] = eff

    def _fusion_bytes(op: Op) -> float:
        """result + effective operand reads for a fusion op."""
        target = next((c for c in op.callees if c in eff_params), None)
        if target is None:
            return op.result_bytes + op.operand_bytes
        comp = comps[target]
        result = op.result_bytes
        # in-place stacked-buffer update: if the fusion contains a
        # dynamic-update-slice whose destination is a parameter (the
        # aliased carry/stack) and whose result is (close to) the fusion
        # result size, the write is only the update slice — even when a
        # convert/bitcast sits between the DUS and the root.
        by_name = {o.name: o for o in comp.ops}

        def _origin(nm, depth=0):
            o = by_name.get(nm)
            while o is not None and o.kind in _CHAIN and o.operand_names \
                    and depth < 16:
                o = by_name.get(o.operand_names[0])
                depth += 1
            return o

        dus = []
        for o in comp.ops:
            if o.kind != "dynamic-update-slice" or o.traffic_override < 0 \
                    or not o.operand_names:
                continue
            dst = _origin(o.operand_names[0])
            if dst is not None and dst.kind == "parameter":
                dus.append(o)
        if dus:
            biggest = max(dus, key=lambda o: o.result_bytes)
            if biggest.result_bytes >= 0.5 * max(result, 1):
                result = biggest.traffic_override / 2.0
        return result + sum(eff_params[target].values())

    flops = 0.0
    bytes_ = 0.0
    coll = {c: 0.0 for c in COLLECTIVES}
    coll_counts = {c: 0.0 for c in COLLECTIVES}

    for name, comp in comps.items():
        k = mult.get(name, 0.0)
        if k == 0.0:
            continue
        in_fusion = name in fusion_children
        for op in comp.ops:
            if op.flops:
                flops += k * op.flops
            is_coll = False
            for c in COLLECTIVES:
                if op.kind.startswith(c) and not op.kind.endswith("-done"):
                    coll[c] += k * op.result_bytes
                    coll_counts[c] += k
                    is_coll = True
                    break
            if in_fusion or is_coll or op.kind in _FREE_OPS:
                continue
            if op.traffic_override >= 0:
                bytes_ += k * op.traffic_override
            elif op.kind == "fusion":
                bytes_ += k * _fusion_bytes(op)
            elif op.kind in ("dynamic-slice", "gather"):
                bytes_ += k * 2.0 * op.result_bytes
            else:
                bytes_ += k * (op.result_bytes + op.operand_bytes)

    return {
        "flops": flops,
        "bytes": bytes_,
        "collectives": coll,
        "collective_bytes": sum(coll.values()),
        "collective_op_counts": coll_counts,
        "n_computations": len(comps),
        "entry": entry,
    }
