"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONs.

  PYTHONPATH=src python -m repro.roofline.report [results/dryrun]
"""

from __future__ import annotations

import json
import pathlib
import sys


def _fmt_s(x: float) -> str:
    if x >= 0.1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.2f}ms"


def load(dirpath: str = "results/dryrun") -> list:
    recs = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def dryrun_table(recs: list) -> str:
    lines = [
        "| arch | shape | mesh | chips | status | HBM/dev | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                f"SKIP ({r['reason'].split(':')[0]}) | - | - |")
        elif r.get("status") == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
                f"| ok | {r['hbm_per_device_gib']:.1f} GiB | "
                f"{r['compile_s']:.0f}s |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | ERROR | "
                f"- | - |")
    return "\n".join(lines)


def roofline_table(recs: list, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        note = _bottleneck_note(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{note} |")
    return "\n".join(lines)


def _bottleneck_note(r: dict) -> str:
    b = r["bottleneck"]
    if b == "compute":
        return ("reduce HLO/MODEL flop gap (remat policy, causal-block "
                "skipping)")
    if b == "memory":
        return ("cut activation traffic: larger attention chunks, fused "
                "kernels, bf16 residuals")
    return "reshard to cut all-gathers (kv layout, fsdp bucket size)"


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per-chip terms)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "pod"))


if __name__ == "__main__":
    main()
