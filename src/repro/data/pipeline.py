"""Data pipeline: deterministic, shardable, resumable token streams.

Production posture: each data-parallel replica reads only its shard of the
global batch (``host_slice``); the stream is keyed by (seed, step) so any
step can be regenerated exactly after a restart — data state lives in the
checkpoint as a single integer.  Backends:

* ``SyntheticLM`` — zipf-distributed token stream with a fixed-size
  "document" structure (realistic padding/mask patterns) for training and
  benchmarks without external datasets.
* ``MemmapCorpus`` — a binary token file memory-mapped per host; each host
  reads its slice only (no global shuffle buffer at scale — shuffling is
  index-based).
* ``prefetch`` — double-buffered host->device pipeline so input copy
  overlaps the previous step's compute.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    pad_id: int = -1
    mean_doc_len: int = 512


class SyntheticLM:
    """Deterministic synthetic LM stream: batch(step) is a pure function of
    (seed, step) — restart-safe by construction."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        per = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        # zipf-ish marginal over the vocab (realistic embedding access)
        z = rng.zipf(1.3, size=(per, cfg.seq_len + 1))
        toks = (z % (cfg.vocab_size - 2)) + 1
        # document boundaries: insert EOS(=0) with geometric spacing
        eos_mask = rng.random((per, cfg.seq_len + 1)) < (
            1.0 / cfg.mean_doc_len)
        toks = np.where(eos_mask, 0, toks).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }


class MemmapCorpus:
    """Token corpus in a flat binary file (np.int32), sharded by host."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.n_tokens = len(self.data)

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        per = cfg.global_batch // n_hosts
        span = cfg.seq_len + 1
        n_seqs = self.n_tokens // span
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        idx = rng.integers(0, n_seqs, size=per)
        rows = np.stack([
            self.data[i * span: (i + 1) * span] for i in idx])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:].copy()}


class Prefetcher:
    """Double-buffered background prefetch (host->device copy overlap)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 host_id: int = 0, n_hosts: int = 1, put_fn=None):
        self.source = source
        self.put_fn = put_fn or (lambda x: x)
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._host = (host_id, n_hosts)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            b = self.source.batch(self._step, *self._host)
            self.q.put((self._step, self.put_fn(b)))
            self._step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue_mod.Empty:
            pass
