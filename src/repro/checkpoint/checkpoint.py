"""Sharded, checksummed, async checkpointing with restart/reshard support.

Design (DESIGN.md §5, 1000+-node posture):

* Each host writes only its *addressable* shards (np arrays) — no single
  writer bottleneck; layout is one .npy blob per leaf per step plus a JSON
  manifest with the pytree structure, global shapes, and per-leaf CRC32
  checksums (the ABFT theme applied to storage integrity).
* Writes go to a temp directory, fsync'd, then atomically renamed — a crash
  mid-write never corrupts the latest checkpoint.
* ``save_async`` offloads serialization to a background thread so the train
  loop overlaps checkpoint I/O with compute (wait() joins before the next
  save).
* ``restore`` validates checksums and re-shards onto the *current* mesh via
  jax.device_put — restoring onto a smaller/larger surviving mesh after a
  failure is exactly the elastic-restart path (runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import zlib

import jax
import numpy as np

_SEP = "\x1e"  # record separator: path key join


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree) -> pathlib.Path:
        """Synchronous sharded save with checksums + atomic rename."""
        flat, treedef = _flatten(tree)
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": int(step), "leaves": {}, "treedef": str(treedef)}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.npy"
            with open(tmp / fname, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": _crc(arr),
            }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = self.dir / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def save_async(self, step: int, tree) -> None:
        """Overlap checkpoint I/O with training: snapshot to host, write in
        a background thread."""
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(self.dir.glob("step_*"))
        if not steps:
            return None
        return int(steps[-1].name.split("_")[1])

    def restore(self, tree_like, step: int | None = None,
                shardings=None, validate: bool = True):
        """Restore into the structure of ``tree_like``; placement follows
        ``shardings`` (pytree of NamedSharding) when given — this is the
        reshard-on-restore path used by elastic restart."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = _flatten(tree_like)
        flat_sh, _ = _flatten(shardings) if shardings is not None else (
            None, None)
        out = {}
        for key, like in flat_like.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(d / meta["file"])
            if validate and _crc(arr) != meta["crc32"]:
                raise IOError(
                    f"checksum mismatch for {key!r} in step {step} "
                    "(corrupted checkpoint)")
            if flat_sh is not None:
                arr = jax.device_put(arr, flat_sh[key])
            out[key] = arr
        leaves = [out[k] for k, _ in sorted(flat_like.items())]
        # rebuild in tree order
        keys_sorted = sorted(flat_like)
        key_to_leaf = dict(zip(keys_sorted, leaves))
        ordered = [key_to_leaf[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, ordered), step
