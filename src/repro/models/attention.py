"""Attention sublayers: GQA (llama/qwen/stablelm/jamba/vlm), absorbed MLA
(deepseek-v3), and cross-attention (whisper decoder / vlm image layers).

All projections run through the ABFT-protected dense().  Decode paths
write/read a KV cache passed explicitly (functional style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.markers import coverage_scope
from repro.configs.base import ModelConfig
from repro.models.layers import (
    LayerCtx,
    apply_rope,
    chunked_attention,
    decode_attention,
    dense,
    or_flags,
    rms_norm,
    rope_tables,
    verify_attention,
)

F32 = jnp.float32


def _init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (scale * jax.random.normal(key, shape, F32)).astype(dtype)


# ================================================================ GQA

def eff_counts(cfg: ModelConfig) -> tuple:
    """(H_eff, KV_eff): head counts after TP padding (DESIGN/§Perf).
    Padding preserves the kv-major (kv, group) head layout so the padded
    model is mathematically identical to the logical one (padded wo rows
    are zero)."""
    H, KV = cfg.n_heads, cfg.n_kv_heads
    Hp = max(cfg.pad_heads_to, H)
    KVp = max(cfg.pad_kv_heads_to, KV)
    G = H // max(KV, 1)
    Gp = Hp // max(KVp, 1)
    assert KVp * Gp == Hp and Gp >= G, (
        f"invalid head padding H={H}->{Hp}, KV={KV}->{KVp}")
    return Hp, KVp


def _pad_heads_in(w, d, KV, G, hd, KVp, Gp):
    """(d, KV*G*hd) -> (d, KVp*Gp*hd), zero-padding in kv-major layout."""
    if KV == KVp and G == Gp:
        return w
    w4 = w.reshape(d, KV, G, hd)
    w4 = jnp.pad(w4, ((0, 0), (0, KVp - KV), (0, Gp - G), (0, 0)))
    return w4.reshape(d, KVp * Gp * hd)


def _pad_heads_out(w, KV, G, hd, d, KVp, Gp):
    """(KV*G*hd, d) -> (KVp*Gp*hd, d) with ZERO rows for padded heads —
    padded-head attention garbage never reaches the residual stream."""
    if KV == KVp and G == Gp:
        return w
    w4 = w.reshape(KV, G, hd, d)
    w4 = jnp.pad(w4, ((0, KVp - KV), (0, Gp - G), (0, 0), (0, 0)))
    return w4.reshape(KVp * Gp * hd, d)


def _pad_bias(b, KV, G, hd, KVp, Gp):
    if KV == KVp and G == Gp:
        return b
    b3 = b.reshape(KV, G, hd)
    b3 = jnp.pad(b3, ((0, KVp - KV), (0, Gp - G), (0, 0)))
    return b3.reshape(KVp * Gp * hd)


def init_gqa(cfg: ModelConfig, key, dtype) -> dict:
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    Hp, KVp = eff_counts(cfg)
    G, Gp = H // KV, Hp // KVp
    ks = jax.random.split(key, 5)
    p = {
        "wq": _pad_heads_in(
            _init(ks[0], (cfg.d_model, H * hd), dtype=dtype),
            cfg.d_model, KV, G, hd, KVp, Gp),
        "wk": _pad_heads_in(
            _init(ks[1], (cfg.d_model, KV * hd), dtype=dtype),
            cfg.d_model, KV, 1, hd, KVp, 1),
        "wv": _pad_heads_in(
            _init(ks[2], (cfg.d_model, KV * hd), dtype=dtype),
            cfg.d_model, KV, 1, hd, KVp, 1),
        "wo": _pad_heads_out(
            _init(ks[3], (H * hd, cfg.d_model), dtype=dtype),
            KV, G, hd, cfg.d_model, KVp, Gp),
    }
    if cfg.qkv_bias:
        p["bq"] = _pad_bias(jnp.zeros((H * hd,), dtype), KV, G, hd, KVp, Gp)
        p["bk"] = _pad_bias(jnp.zeros((KV * hd,), dtype), KV, 1, hd, KVp, 1)
        p["bv"] = _pad_bias(jnp.zeros((KV * hd,), dtype), KV, 1, hd, KVp, 1)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(x, p, cfg: ModelConfig, ctx: LayerCtx, positions):
    B, L, _ = x.shape
    hd = cfg.resolved_head_dim
    Hp, KVp = eff_counts(cfg)
    q, f1 = dense(x, p["wq"], ctx, "qkv", b=p.get("bq"), tag="attn.q")
    k, f2 = dense(x, p["wk"], ctx, "qkv", b=p.get("bk"), tag="attn.k")
    v, f3 = dense(x, p["wv"], ctx, "qkv", b=p.get("bv"), tag="attn.v")
    q = q.reshape(B, L, Hp, hd)
    k = k.reshape(B, L, KVp, hd)
    v = v.reshape(B, L, KVp, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        cos, sin, rot = rope_tables(
            positions, hd, cfg.rope_theta, cfg.rope_pct)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    return q, k, v, or_flags(f1, f2, f3)


def _attend_full(q, k, v, ctx: LayerCtx, causal: bool):
    """Full-sequence attention core: fused-ABFT flash kernel when the
    policy enables it (protects the attention GEMMs themselves), else the
    XLA chunked path (GEMM projections still ABFT-protected)."""
    if ctx.abft.flash_attention:
        from repro.kernels.flash_ops import flash_attention

        out, chk = flash_attention(q, k, v, causal=causal)
        return out, chk.flag
    return chunked_attention(q, k, v, causal=causal), jnp.zeros((), bool)


def gqa_forward(x, p, cfg: ModelConfig, ctx: LayerCtx, positions,
                causal: bool = True):
    """Full-sequence attention (train / encoder).  x: (B, L, D)."""
    B, L, _ = x.shape
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    out, f_attn = _attend_full(q, k, v, ctx, causal)
    out = out.reshape(B, L, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, or_flags(flag, f_attn, f)


def _row_scatter(cache_leaf, new, pos):
    """Per-row KV scatter: write ``new[b]`` into ``cache_leaf[b]`` at its
    own row position ``pos[b]`` (vectorized decode cursor)."""
    def one(c, n, p):
        start = (p,) + (0,) * (c.ndim - 1)
        return jax.lax.dynamic_update_slice(c, n, start)

    return jax.vmap(one)(cache_leaf, new.astype(cache_leaf.dtype), pos)


def _slot_prefill_write(cache_leaf, new, slots, L):
    """Write ``new`` (A, L, ...) into rows ``slots`` of the engine cache
    (B_engine, S_max, ...) at positions [0, L)."""
    return cache_leaf.at[slots, :L].set(new.astype(cache_leaf.dtype))


def _slot_prefill_write_at(cache_leaf, new, slots, starts, lengths):
    """Write ``new`` (A, L, ...) into rows ``slots`` of the engine cache at
    per-row start offsets: ``new[a, t]`` lands at position ``starts[a] + t``
    for ``t < lengths[a]`` (the chunked-prefill resume path — earlier chunks
    already occupy ``[0, starts[a])``).  Padding positions are routed past
    the cache depth and dropped, so a bucketed pad near ``max_len`` can
    never clamp backwards onto previously written chunks the way a
    ``dynamic_update_slice`` would."""
    S = cache_leaf.shape[1]
    A, L = new.shape[0], new.shape[1]
    t = jnp.arange(L, dtype=jnp.int32)
    pos = starts[:, None].astype(jnp.int32) + t[None, :]
    pos = jnp.where(t[None, :] < lengths[:, None], pos, S)   # drop padding
    rows = jnp.broadcast_to(slots[:, None], (A, L))
    return cache_leaf.at[rows, pos].set(
        new.astype(cache_leaf.dtype), mode="drop")


def _vec_positions(pos, B):
    """Normalize a decode cursor to a (B,) vector of positions."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))


def gqa_prefill(x, p, cfg: ModelConfig, ctx: LayerCtx, positions, cache,
                slots=None, lengths=None, starts=None):
    """Prefill: run full attention AND fill the cache.  cache: dict with
    'k','v' of shape (B, S_max, KV, hd).

    ``slots``/``lengths`` (continuous-batching path): x is the admission
    batch (A, L, D) padded to a common L; k/v rows are scattered into the
    engine cache rows ``slots`` and attention is masked per-row at
    ``lengths`` so ragged prompts never attend into padding.

    ``starts`` (A,) int32 selects the RESUMABLE-CHUNK path (the dense-cache
    mirror of the paged suffix prefill): x holds one mid-prompt chunk per
    row, whose logical positions begin at ``starts[a]`` (``positions``
    already carries the offset, so rotary embeddings match the monolithic
    prefill bit for bit).  The chunk's k/v scatter in behind the already-
    resident prefix and attention runs over the slot's cache rows (prefix
    + fresh chunk) with a per-row causal ``q_offset`` and total-length key
    masking — byte-identical streams to the unchunked engine are the
    correctness contract."""
    B, L, _ = x.shape
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    if starts is None:
        out = chunked_attention(q, k, v, causal=True, lengths=lengths)
        if slots is None:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            }
        else:
            new_cache = {
                "k": _slot_prefill_write(cache["k"], k, slots, L),
                "v": _slot_prefill_write(cache["v"], v, slots, L),
            }
    else:
        assert slots is not None, "chunked prefill needs slot targets"
        new_cache = {
            "k": _slot_prefill_write_at(cache["k"], k, slots, starts,
                                        lengths),
            "v": _slot_prefill_write_at(cache["v"], v, slots, starts,
                                        lengths),
        }
        out = chunked_attention(
            q, jnp.take(new_cache["k"], slots, axis=0),
            jnp.take(new_cache["v"], slots, axis=0),
            causal=True, q_offset=starts, lengths=starts + lengths)
    out = out.reshape(B, L, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, new_cache, or_flags(flag, f)


def gqa_decode(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache):
    """One-token decode.  x: (B, 1, D); pos: scalar or (B,) per-slot
    position vector; cache k/v: (B, S_max, KV, hd).  Each row writes its
    new k/v at its own cursor and attends its own valid prefix."""
    B = x.shape[0]
    pos = _vec_positions(pos, B)
    positions = pos[:, None]
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    ck = _row_scatter(cache["k"], k, pos)
    cv = _row_scatter(cache["v"], v, pos)
    if ctx.abft.flash_attention:
        from repro.kernels.flash_ops import flash_decode

        out, chk = flash_decode(q, ck, cv, pos + 1)
        f_attn = chk.flag
    else:
        out = decode_attention(q, ck, cv, pos + 1)
        f_attn = jnp.zeros((), bool)
    out = out.reshape(B, 1, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, {"k": ck, "v": cv}, or_flags(flag, f_attn, f)


def _window_scatter(cache_leaf, new, pos, valid):
    """Write ``new`` (B, T, ...) into ``cache_leaf`` (B, S, ...) at rows
    ``pos[b] .. pos[b] + T - 1``, keeping only the first ``valid[b]``
    rows.  Out-of-window rows route past the cache depth and DROP — a
    ``dynamic_update_slice`` would clamp a near-budget window backwards
    onto committed keys (the padded verify T is uniform across slots;
    per-slot draft budgets are not)."""
    S = cache_leaf.shape[1]
    B, T = new.shape[0], new.shape[1]
    t = jnp.arange(T, dtype=jnp.int32)
    posns = pos[:, None].astype(jnp.int32) + t[None, :]
    posns = jnp.where(t[None, :] < valid[:, None], posns, S)
    rows = jnp.broadcast_to(
        jnp.arange(B, dtype=jnp.int32)[:, None], (B, T))
    return cache_leaf.at[rows, posns].set(
        new.astype(cache_leaf.dtype), mode="drop")


def gqa_verify(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache, valid):
    """Speculative verify: x (B, T, D) holds each row's last committed
    token followed by its draft window; row b writes its first
    ``valid[b]`` k/v rows at positions ``pos[b]..`` and every query
    attends its own causal prefix (verify_attention).  Rows beyond
    ``valid`` are shape ballast (uniform T across slots) — their writes
    drop and their logits are discarded host-side."""
    B, T, _ = x.shape
    pos = _vec_positions(pos, B)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    ck = _window_scatter(cache["k"], k, pos, valid)
    cv = _window_scatter(cache["v"], v, pos, valid)
    out = verify_attention(q, ck, cv, pos + 1)
    out = out.reshape(B, T, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, {"k": ck, "v": cv}, or_flags(flag, f)


# ---------------------------------------------------------------- paged GQA

def gqa_paged_prefill(x, p, cfg: ModelConfig, ctx: LayerCtx, positions,
                      cache, tables, lengths, starts=None):
    """Paged prefill: same ragged attention as the dense continuous-
    batching path (prompts attend only themselves), but k/v scatter into
    the block pool at ``tables[a, t // block_size]`` instead of dense
    engine rows.  cache k/v: (NB, BS, KV, hd); tables: (A, W).

    ``starts`` (A,) int32 selects the prefix-sharing SUFFIX path: x holds
    only each row's unshared suffix, whose logical positions begin at
    ``starts[a]`` (``positions`` already carries the offset, so rotary
    embeddings are computed from the true logical position — getting this
    wrong is silent corruption, which is why the shared-vs-unshared
    equivalence tests demand byte-identical streams).  The suffix k/v are
    scattered behind the resident prefix, then attention runs over the
    slot's GATHERED logical KV (prefix blocks + fresh suffix) with a
    per-row causal offset and total-length key masking."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_prefill

    B, L, _ = x.shape
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    if starts is None:
        out = chunked_attention(q, k, v, causal=True, lengths=lengths)
        new_cache = {
            "k": paged_scatter_prefill(cache["k"], k, tables, lengths),
            "v": paged_scatter_prefill(cache["v"], v, tables, lengths),
        }
    else:
        new_cache = {
            "k": paged_scatter_prefill(cache["k"], k, tables, lengths,
                                       starts=starts),
            "v": paged_scatter_prefill(cache["v"], v, tables, lengths,
                                       starts=starts),
        }
        out = chunked_attention(
            q, paged_gather(new_cache["k"], tables),
            paged_gather(new_cache["v"], tables),
            causal=True, q_offset=starts, lengths=starts + lengths)
    out = out.reshape(B, L, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, new_cache, or_flags(flag, f)


def gqa_paged_decode(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache,
                     tables):
    """Paged one-token decode: scatter the new k/v entry at
    ``tables[b, pos[b] // block_size]``, then attend the slot's own
    prefix — via the block-table-indexed Pallas flash kernel when the
    policy enables it, else gather + length-masked reference attention."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_decode

    B = x.shape[0]
    pos = _vec_positions(pos, B)
    positions = pos[:, None]
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    ck = paged_scatter_decode(cache["k"], k[:, 0], tables, pos)
    cv = paged_scatter_decode(cache["v"], v[:, 0], tables, pos)
    if ctx.abft.flash_attention:
        from repro.kernels.flash_ops import flash_decode_paged

        out, chk = flash_decode_paged(q, ck, cv, tables, pos + 1)
        f_attn = chk.flag
    else:
        out = decode_attention(
            q, paged_gather(ck, tables), paged_gather(cv, tables), pos + 1)
        f_attn = jnp.zeros((), bool)
    out = out.reshape(B, 1, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, {"k": ck, "v": cv}, or_flags(flag, f_attn, f)


def gqa_paged_verify(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache,
                     valid, tables):
    """Paged speculative verify: the draft window's k/v scatter behind
    the committed prefix via the block tables (the prefix-sharing suffix
    scatter generalizes — per-row starts at the cursor, padding routed
    to the sentinel), then each query attends the gathered logical KV
    with its own per-query length mask."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_prefill

    B, T, _ = x.shape
    pos = _vec_positions(pos, B)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q, k, v, flag = _qkv(x, p, cfg, ctx, positions)
    ck = paged_scatter_prefill(cache["k"], k, tables, valid, starts=pos)
    cv = paged_scatter_prefill(cache["v"], v, tables, valid, starts=pos)
    out = verify_attention(
        q, paged_gather(ck, tables), paged_gather(cv, tables), pos + 1)
    out = out.reshape(B, T, -1)
    out, f = dense(out, p["wo"], ctx, "attn_out", tag="attn.o")
    return out, {"k": ck, "v": cv}, or_flags(flag, f)


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    _, KVp = eff_counts(cfg)
    return {
        "k": jnp.zeros((batch, max_len, KVp, hd), dtype),
        "v": jnp.zeros((batch, max_len, KVp, hd), dtype),
    }


# ================================================================ cross-attn

def init_cross(cfg: ModelConfig, key, dtype, kv_dim: int | None = None):
    hd = cfg.resolved_head_dim
    kv_dim = kv_dim or cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype=dtype),
        "wk": _init(ks[1], (kv_dim, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": _init(ks[2], (kv_dim, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": _init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype=dtype),
    }


def cross_kv(mem, p, cfg: ModelConfig, ctx: LayerCtx):
    """Project encoder/vision memory to K/V once (reused every decode)."""
    B, S, _ = mem.shape
    hd = cfg.resolved_head_dim
    k, f1 = dense(mem, p["wk"], ctx, "cross_qkv", tag="cross.k")
    v, f2 = dense(mem, p["wv"], ctx, "cross_qkv", tag="cross.v")
    return (
        k.reshape(B, S, cfg.n_kv_heads, hd),
        v.reshape(B, S, cfg.n_kv_heads, hd),
        or_flags(f1, f2),
    )


def cross_forward(x, k, v, p, cfg: ModelConfig, ctx: LayerCtx):
    """Cross-attention: queries from x, K/V precomputed from memory."""
    B, L, _ = x.shape
    hd = cfg.resolved_head_dim
    q, f1 = dense(x, p["wq"], ctx, "cross_qkv", tag="cross.q")
    q = q.reshape(B, L, cfg.n_heads, hd)
    out = chunked_attention(q, k, v, causal=False)
    out = out.reshape(B, L, -1)
    out, f2 = dense(out, p["wo"], ctx, "cross_out", tag="cross.o")
    return out, or_flags(f1, f2)


# ================================================================ MLA
# Absorbed formulation (DESIGN.md §4): attention becomes MQA with one
# shared latent key space  k' = [c_kv ; k_pe]  (dim kv_lora + rope),
# v' = c_kv, per-head query  q' = [q_nope @ W_uk ; q_pe].

def init_mla(cfg: ModelConfig, key, dtype) -> dict:
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": _init(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype=dtype),
        "q_a_norm": jnp.ones((cfg.q_lora_rank,), dtype),
        "wq_b": _init(ks[1], (cfg.q_lora_rank, H * (dn + dr)), dtype=dtype),
        "wkv_a": _init(
            ks[2], (cfg.d_model, cfg.kv_lora_rank + dr), dtype=dtype),
        "kv_a_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        # up-projections, stored head-major for the absorbed form
        "w_uk": _init(ks[3], (H, dn, cfg.kv_lora_rank), dtype=dtype),
        "w_uv": _init(ks[4], (H, cfg.kv_lora_rank, dv), dtype=dtype),
        "wo": _init(ks[5], (H * dv, cfg.d_model), dtype=dtype),
    }


def _mla_q(x, p, cfg: ModelConfig, ctx: LayerCtx, positions):
    """Absorbed queries: (B, L, H, kv_lora + rope)."""
    B, L, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    qa, f1 = dense(x, p["wq_a"], ctx, "q_a", tag="mla.q_a")
    qa = rms_norm(qa, p["q_a_norm"], cfg.norm_eps)
    q, f2 = dense(qa, p["wq_b"], ctx, "qkv", tag="mla.q_b")
    q = q.reshape(B, L, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    cos, sin, rot = rope_tables(positions, dr, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin, rot)
    # absorb W_uk:  (B,L,H,dn) @ (H,dn,c) -> (B,L,H,c).  A weight-bearing
    # einsum outside the matmul-ABFT surface: flops[mla] marks it for the
    # auditor as a known gap (no fused MLA ABFT kernel yet).
    with coverage_scope("mla"):
        q_abs = jnp.einsum(
            "blhd,hdc->blhc", q_nope.astype(F32), p["w_uk"].astype(F32),
            preferred_element_type=F32).astype(x.dtype)
    q_full = jnp.concatenate([q_abs, q_pe], axis=-1)
    # scale uses the *pre-absorption* head dim (dn + dr)
    scale = (dn + dr) ** -0.5
    return q_full, scale, or_flags(f1, f2)


def _mla_latent_kv(x, p, cfg: ModelConfig, ctx: LayerCtx, positions):
    """Latent K/V: c_kv (B, L, c) + roped k_pe (B, L, dr)."""
    dr = cfg.qk_rope_head_dim
    kv, f = dense(x, p["wkv_a"], ctx, "kv_a", tag="mla.kv_a")
    c_kv, k_pe = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    cos, sin, rot = rope_tables(positions, dr, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin, rot)[:, :, 0, :]
    return c_kv, k_pe, f


def _mla_attend(q_full, scale, latent, p, cfg, ctx, B, L, decode_len=None,
                lengths=None, q_offset=0, verify_len=None):
    """latent: concatenated [c_kv ; k_pe] (B, S, c+dr).  Values are the
    first c dims of the same buffer — attention reads ONE cache tensor
    (no per-step concat of the 32k-deep cache; §Perf iteration C2).
    ``verify_len``: speculative-verify path — L consecutive queries per
    row, query t masked at ``verify_len[b] + t`` (see verify_attention)."""
    c = cfg.kv_lora_rank
    kv = latent[:, :, None, :]                       # KV=1 (MQA)
    vv = latent[:, :, None, :c]
    # flops[mla]: the absorbed attention core + value un-absorption have
    # no fused ABFT kernel (flash routing never reaches MLA) — the
    # auditor reports this whole region as known_unprotected['mla']
    with coverage_scope("mla"):
        if verify_len is not None:
            ctxv = verify_attention(q_full, kv, vv, verify_len,
                                    scale=scale)
        elif decode_len is None:
            ctxv = chunked_attention(
                q_full, kv, vv, causal=True, scale=scale, lengths=lengths,
                q_offset=q_offset)
        else:
            ctxv = decode_attention(q_full, kv, vv, decode_len,
                                    scale=scale)
        # un-absorb values: (B,L,H,c) @ (H,c,dv) -> (B,L,H,dv)
        out = jnp.einsum(
            "blhc,hcv->blhv", ctxv.astype(F32), p["w_uv"].astype(F32),
            preferred_element_type=F32).astype(q_full.dtype)
    out = out.reshape(B, L, -1)
    return dense(out, p["wo"], ctx, "attn_out", tag="mla.out")


def mla_forward(x, p, cfg: ModelConfig, ctx: LayerCtx, positions):
    B, L, _ = x.shape
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent = jnp.concatenate([c_kv, k_pe], axis=-1)
    out, f3 = _mla_attend(q_full, scale, latent, p, cfg, ctx, B, L)
    return out, or_flags(f1, f2, f3)


def mla_prefill(x, p, cfg: ModelConfig, ctx: LayerCtx, positions, cache,
                slots=None, lengths=None, starts=None):
    """``starts``: resumable-chunk path (see gqa_prefill) — the chunk's
    latents land behind the resident prefix rows and attention runs over
    the slot's cache with per-row causal offsets."""
    B, L, _ = x.shape
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent = jnp.concatenate([c_kv, k_pe], axis=-1)
    if starts is None:
        out, f3 = _mla_attend(
            q_full, scale, latent, p, cfg, ctx, B, L, lengths=lengths)
        if slots is None:
            new_latent = jax.lax.dynamic_update_slice(
                cache["latent"], latent.astype(cache["latent"].dtype),
                (0, 0, 0))
        else:
            new_latent = _slot_prefill_write(
                cache["latent"], latent, slots, L)
    else:
        assert slots is not None, "chunked prefill needs slot targets"
        new_latent = _slot_prefill_write_at(
            cache["latent"], latent, slots, starts, lengths)
        out, f3 = _mla_attend(
            q_full, scale, jnp.take(new_latent, slots, axis=0), p, cfg,
            ctx, B, L, lengths=starts + lengths, q_offset=starts)
    return out, {"latent": new_latent}, or_flags(f1, f2, f3)


def mla_decode(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache):
    B = x.shape[0]
    pos = _vec_positions(pos, B)
    positions = pos[:, None]
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent_new = jnp.concatenate([c_kv, k_pe], axis=-1)  # (B, 1, c+dr)
    lat = _row_scatter(cache["latent"], latent_new, pos)
    out, f3 = _mla_attend(
        q_full, scale, lat, p, cfg, ctx, B, 1, decode_len=pos + 1)
    return out, {"latent": lat}, or_flags(f1, f2, f3)


def mla_verify(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache, valid):
    """Speculative verify (dense MLA): the draft window's latents land
    behind the committed prefix (drop-safe window scatter) and each
    query attends its own causal prefix (see gqa_verify)."""
    B, T, _ = x.shape
    pos = _vec_positions(pos, B)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent_new = jnp.concatenate([c_kv, k_pe], axis=-1)  # (B, T, c+dr)
    lat = _window_scatter(cache["latent"], latent_new, pos, valid)
    out, f3 = _mla_attend(
        q_full, scale, lat, p, cfg, ctx, B, T, verify_len=pos + 1)
    return out, {"latent": lat}, or_flags(f1, f2, f3)


def mla_paged_prefill(x, p, cfg: ModelConfig, ctx: LayerCtx, positions,
                      cache, tables, lengths, starts=None):
    """Paged MLA prefill: latent rows scatter into the (NB, BS, c+dr)
    pool via the admission batch's block tables.  ``starts``: prefix-
    sharing suffix path — suffix latents land behind the resident shared
    prefix and attention runs over the gathered logical latent buffer
    with per-row causal offsets (see gqa_paged_prefill)."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_prefill

    B, L, _ = x.shape
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent = jnp.concatenate([c_kv, k_pe], axis=-1)
    if starts is None:
        out, f3 = _mla_attend(
            q_full, scale, latent, p, cfg, ctx, B, L, lengths=lengths)
        new_latent = paged_scatter_prefill(
            cache["latent"], latent, tables, lengths)
    else:
        new_latent = paged_scatter_prefill(
            cache["latent"], latent, tables, lengths, starts=starts)
        out, f3 = _mla_attend(
            q_full, scale, paged_gather(new_latent, tables), p, cfg, ctx,
            B, L, lengths=starts + lengths, q_offset=starts)
    return out, {"latent": new_latent}, or_flags(f1, f2, f3)


def mla_paged_decode(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache,
                     tables):
    """Paged MLA decode: scatter the new latent at the cursor's block,
    gather the slot's blocks, attend with per-row length masking."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_decode

    B = x.shape[0]
    pos = _vec_positions(pos, B)
    positions = pos[:, None]
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent_new = jnp.concatenate([c_kv, k_pe], axis=-1)  # (B, 1, c+dr)
    lat = paged_scatter_decode(cache["latent"], latent_new[:, 0], tables,
                               pos)
    out, f3 = _mla_attend(
        q_full, scale, paged_gather(lat, tables), p, cfg, ctx, B, 1,
        decode_len=pos + 1)
    return out, {"latent": lat}, or_flags(f1, f2, f3)


def mla_paged_verify(x, p, cfg: ModelConfig, ctx: LayerCtx, pos, cache,
                     valid, tables):
    """Paged speculative verify (MLA): draft latents scatter behind the
    committed prefix via the block tables, then every query attends the
    gathered logical latent buffer with its own per-query mask."""
    from repro.serve.paged_cache import paged_gather, paged_scatter_prefill

    B, T, _ = x.shape
    pos = _vec_positions(pos, B)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    q_full, scale, f1 = _mla_q(x, p, cfg, ctx, positions)
    c_kv, k_pe, f2 = _mla_latent_kv(x, p, cfg, ctx, positions)
    latent_new = jnp.concatenate([c_kv, k_pe], axis=-1)  # (B, T, c+dr)
    lat = paged_scatter_prefill(cache["latent"], latent_new, tables,
                                valid, starts=pos)
    out, f3 = _mla_attend(
        q_full, scale, paged_gather(lat, tables), p, cfg, ctx, B, T,
        verify_len=pos + 1)
    return out, {"latent": lat}, or_flags(f1, f2, f3)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "latent": jnp.zeros(
            (batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
            dtype),
    }
