"""Shared NN building blocks.  Every GEMM routes through the ABFT-protected
matmul (core/protected.py) — the paper's technique as a first-class layer
feature.  All functions are pure; params are plain pytrees (dicts).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.markers import coverage_scope
from repro.core.faults import FaultSpec
from repro.core.protected import ABFTConfig, protected_matmul

F32 = jnp.float32

# ---------------------------------------------------------------- fault plumbing
# Injection sites (static ids) — where in a layer a campaign can corrupt a
# GEMM output.  The paper's fault model is one faulty output value per
# linear layer; campaigns pick (layer, site, row, col).

SITES = {
    "qkv": 0, "attn_out": 1, "mlp_up": 2, "mlp_down": 3,
    "router": 4, "expert_up": 5, "expert_down": 6,
    "lm_head": 7, "ssm_in": 8, "ssm_out": 9,
    "cross_qkv": 10, "cross_out": 11, "q_a": 12, "kv_a": 13,
}


class ModelFault(NamedTuple):
    """A single-fault campaign target inside a full model."""

    layer: jnp.ndarray          # global layer index (int32 scalar)
    site: jnp.ndarray           # SITES id (int32 scalar)
    spec: FaultSpec

    @staticmethod
    def none() -> "ModelFault":
        z = jnp.zeros((), jnp.int32)
        return ModelFault(layer=z, site=z, spec=FaultSpec.none())

    @staticmethod
    def at(layer: int, site: str, spec: FaultSpec) -> "ModelFault":
        return ModelFault(
            layer=jnp.asarray(layer, jnp.int32),
            site=jnp.asarray(SITES[site], jnp.int32),
            spec=spec,
        )


@dataclasses.dataclass(frozen=True)
class ShardingHints:
    """Static annotation hints for with_sharding_constraint inside layers
    (only where GSPMD propagation needs help, e.g. MoE dispatch buffers).
    ``dp``: data-parallel axes for token dims; ``dp_size``: their product
    (the MoE group count); ``ep``: expert axes; ``moe_mode``: 'ep'
    (experts sharded) or 'tp' (expert ffn dim sharded)."""

    dp: tuple = ("data",)
    dp_size: int = 1
    ep: tuple = ("model",)
    tp: str = "model"
    moe_mode: str = "ep"

    def constrain(self, x, *spec):
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain(ctx, x, *spec):
    """Apply a sharding constraint if hints are active (no-op on CPU/tests)."""
    if ctx.hints is None:
        return x
    return ctx.hints.constrain(x, *spec)


@dataclasses.dataclass(frozen=True)
class LayerCtx:
    """Per-forward context: the static ABFT config (a facade over the
    active ProtectionPolicy, core/policy.py) + traced fault target +
    traced current layer index (set inside scanned stacks)."""

    abft: ABFTConfig = ABFTConfig()
    fault: ModelFault | None = None
    layer_idx: jnp.ndarray | None = None   # traced global layer index
    hints: ShardingHints | None = None
    # static prefix for plan-facing site tags ("enc." inside the whisper
    # encoder stack) so the coverage auditor can tell encoder GEMMs from
    # identically-shaped decoder ones
    site_prefix: str = ""

    def with_layer(self, idx) -> "LayerCtx":
        return dataclasses.replace(self, layer_idx=idx)


def dense(x, w, ctx: LayerCtx, site: str, b=None, out_dtype=None,
          tag: str | None = None):
    """ABFT-protected ``x @ w (+ b)``.  Returns (y, flag: scalar bool).

    Scheme selection happens at trace time via the config's
    ProtectionPolicy (``ctx.abft.effective_policy()``).  Layers inside
    scanned stacks share one trace, so per-layer static distinctions —
    like the first protected layer's extra activation-checksum read —
    live in the analytic ``ProtectionPlan`` (explicit ``LayerSpec.first``
    descriptors), not here.

    ``site`` is the fault-injection site id (SITES); ``tag`` is the
    plan-facing layer name (counting.layer_gemms keys, e.g. ``attn.q``)
    stamped into the ``abft[...]`` trace marker for the coverage auditor
    — it defaults to the fault site so an untagged call is still marked
    (and shows up as a trace-only site in plan cross-validation, which
    is precisely the drift the auditor exists to catch)."""
    fault = None
    if ctx.fault is not None:
        here = ctx.fault.site == SITES[site]
        if ctx.layer_idx is not None:
            here = here & (ctx.fault.layer == ctx.layer_idx)
        spec = ctx.fault.spec
        fault = spec._replace(
            enabled=(spec.enabled.astype(bool) & here).astype(jnp.int32))
    y, chk = protected_matmul(
        x, w, ctx.abft, out_dtype=out_dtype or x.dtype, fault=fault,
        site=ctx.site_prefix + (tag or site))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y, chk.flag


# ---------------------------------------------------------------- norms

def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


def norm(x, p, kind: str, eps: float):
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"], eps)
    return rms_norm(x, p["w"], eps)


def gated_rms_norm(x, z, w, eps: float = 1e-6):
    """Mamba2 output norm: RMSNorm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(F32)).astype(x.dtype), w, eps)


# ---------------------------------------------------------------- rope

def rope_tables(positions, head_dim: int, theta: float, pct: float = 1.0):
    """positions: (..., L) int32 -> (cos, sin) of shape (..., L, rot/2)."""
    rot = int(head_dim * pct) // 2 * 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, rot, 2, dtype=F32) / rot))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, cos, sin, rot: int):
    """x: (B, L, H, D); rotate first ``rot`` dims (split-half convention)."""
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < x.shape[-1] else out


# ---------------------------------------------------------------- attention

NEG_INF = -1e30


def chunked_attention(
    q, k, v, *, causal: bool, q_offset=0, q_chunk: int = 512,
    k_chunk: int = 1024, scale: float | None = None, lengths=None,
):
    """Memory-bounded attention (pure-JAX flash style): nested scans over
    query and key chunks with online softmax.  Avoids materializing the
    (Lq, Lk) score matrix — required for the 32k prefill shapes.

    The whole body runs inside a ``flops[softmax]`` coverage scope: the
    score/PV einsums are outside the matmul-ABFT surface by design —
    they are the ops the fused flash-ABFT kernels replace when
    ``flash_attention=True`` — and the auditor allowlists them under
    that kind instead of flagging them unprotected.

    q: (B, Lq, H, Dk); k: (B, Lk, KV, Dk); v: (B, Lk, KV, Dv).
    GQA: H must be a multiple of KV; KV == 1 is MQA (used by absorbed MLA).
    ``lengths``: optional (B,) int32 per-row valid key count — keys at
    positions >= lengths[b] are masked for row b (ragged batched prefill).
    ``q_offset``: logical position of query 0 — a scalar, or a (B,) int32
    vector when every row starts at its own position (the prefix-sharing
    suffix prefill: row b's query t sits at logical position
    ``q_offset[b] + t`` for the causal mask; keys are addressed from
    logical 0).  The scalar path is untouched bit-for-bit.
    Returns (B, Lq, H, Dv).
    """
    with coverage_scope("softmax"):
        return _chunked_attention_impl(
            q, k, v, causal=causal, q_offset=q_offset, q_chunk=q_chunk,
            k_chunk=k_chunk, scale=scale, lengths=lengths)


def _chunked_attention_impl(
    q, k, v, *, causal, q_offset, q_chunk, k_chunk, scale, lengths,
):
    B, Lq, H, Dk = q.shape
    row_offset = getattr(q_offset, "ndim", 0) > 0          # (B,) vector?
    _, Lk, KV, Dv = v.shape[0], v.shape[1], v.shape[2], v.shape[3]
    groups = H // KV
    scale = scale if scale is not None else Dk ** -0.5

    q_chunk = min(q_chunk, Lq)
    k_chunk = min(k_chunk, Lk)
    # pad to chunk multiples
    pq = -Lq % q_chunk
    pk = -Lk % k_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // k_chunk

    qc = qp.reshape(B, nq, q_chunk, H, Dk).transpose(1, 0, 2, 3, 4)
    kc = kp.reshape(B, nk, k_chunk, KV, Dk).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nk, k_chunk, KV, Dv).transpose(1, 0, 2, 3, 4)

    kv_valid = Lk  # positions >= Lk are padding

    def q_step(_, qi_q):
        qi, qblk = qi_q
        q_base = qi * q_chunk + jnp.arange(q_chunk)
        if row_offset:
            q_pos = q_offset[:, None] + q_base[None, :]    # (B, qc)
        else:
            q_pos = q_offset + q_base                      # (qc,)

        def k_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            # scores: (B, qc, H, kc) = qblk @ kblk^T (kv-head broadcast).
            # NOTE: operands stay in their storage dtype — XLA computes
            # bf16 x bf16 -> f32 natively on the MXU; an explicit
            # .astype(F32) would materialize f32 copies of every k/v
            # chunk to HBM (measured: dominant memory-term contributor,
            # EXPERIMENTS.md §Perf iteration A2/C2).
            qg = qblk.reshape(B, q_chunk, KV, groups, Dk)
            s = jnp.einsum(
                "bqkgd,bskd->bqkgs", qg, kblk,
                preferred_element_type=F32) * scale
            if row_offset:
                mask = jnp.broadcast_to(
                    k_pos[None, None, :] < kv_valid, (B,) + (q_chunk,)
                    + (k_chunk,))
                if causal:
                    mask = mask & (q_pos[:, :, None] >= k_pos[None, None, :])
                s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
            else:
                mask = k_pos[None, :] < kv_valid
                if causal:
                    mask = mask & (q_pos[:, None] >= k_pos[None, :])
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            if lengths is not None:
                row_ok = k_pos[None, :] < lengths[:, None]     # (B, kc)
                s = jnp.where(row_ok[:, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # probs stay f32 into PV: bf16 probs regressed the backward
            # pass by 18% (extra convert round-trips in dp/dv), measured
            # in §Perf iteration B3 -> B4.
            pv = jnp.einsum(
                "bqkgs,bskv->bqkgv", p, vblk,
                preferred_element_type=F32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, KV, groups), NEG_INF, F32)
        l0 = jnp.zeros((B, q_chunk, KV, groups), F32)
        a0 = jnp.zeros((B, q_chunk, KV, groups, Dv), F32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.reshape(B, q_chunk, H, Dv).astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Lq]


def decode_attention(q, k_cache, v_cache, length, scale=None):
    """Single-token attention against a (B, S, KV, D) cache.

    q: (B, 1, H, Dk); ``length``: number of valid cache positions
    (scalar or (B,)).  Returns (B, 1, H, Dv).

    Runs inside a ``flops[softmax]`` coverage scope (see
    chunked_attention) — ``flash_decode`` is the fused-ABFT replacement.
    """
    with coverage_scope("softmax"):
        B, _, H, Dk = q.shape
        S, KV, Dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[3]
        groups = H // KV
        scale = scale if scale is not None else Dk ** -0.5
        qg = q.reshape(B, KV, groups, Dk)
        # storage-dtype operands: no materialized f32 cache copy (above)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=F32) * scale
        pos = jnp.arange(S)
        valid = pos[None, :] < jnp.reshape(length, (-1, 1))
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskv->bkgv", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=F32)
        return out.reshape(B, 1, H, Dv).astype(q.dtype)


def verify_attention(q, k_cache, v_cache, length, scale=None):
    """Speculative-verify attention: T consecutive queries per row
    against a (B, S, KV, D) cache.

    q: (B, T, H, Dk) — row b's queries sit at logical positions
    ``length[b] - 1 .. length[b] + T - 2`` (``length`` is the valid
    cache count for the FIRST query, i.e. its prefix plus its own
    freshly written key, exactly what ``decode_attention`` receives);
    query t may attend ``length[b] + t`` positions.  Returns
    (B, T, H, Dv).

    Deliberately replicates ``decode_attention``'s op sequence —
    storage-dtype score operands, one full softmax (no online
    accumulation), probs cast to the cache dtype before PV — instead of
    reusing ``chunked_attention`` (f32 probs + online softmax): each
    accepted row of a T>1 verify call must be bitwise identical to the
    decode path's output at the same position, the byte-identical-
    stream contract speculative decoding is gated on.  T=1 degenerates
    to ``decode_attention`` exactly.
    """
    with coverage_scope("softmax"):
        B, T, H, Dk = q.shape
        S, KV, Dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[3]
        groups = H // KV
        scale = scale if scale is not None else Dk ** -0.5
        qg = q.reshape(B, T, KV, groups, Dk)
        # storage-dtype operands: no materialized f32 cache copy (above)
        s = jnp.einsum("btkgd,bskd->btkgs", qg, k_cache,
                       preferred_element_type=F32) * scale
        pos = jnp.arange(S)
        lim = (jnp.reshape(length, (-1, 1))
               + jnp.arange(T, dtype=jnp.int32)[None, :])     # (B, T)
        valid = pos[None, None, :] < lim[:, :, None]
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("btkgs,bskv->btkgv", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=F32)
        return out.reshape(B, T, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------- mlp

def mlp(x, p, ctx: LayerCtx, act: str = "silu",
        tags: tuple = ("mlp.up", "mlp.down")):
    """SwiGLU (silu) or plain GELU MLP; GEMMs are ABFT-protected.
    ``tags``: plan-facing (up, down) site tags — MoE shared experts pass
    ("moe.shared_up", "moe.shared_down") so the auditor matches them to
    their own plan entries."""
    up_tag, down_tag = tags
    flags = []
    if act == "silu":
        up, f1 = dense(x, p["up"], ctx, "mlp_up", tag=up_tag)
        gate, f2 = dense(x, p["gate"], ctx, "mlp_up", tag=up_tag)
        h = jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up
        flags += [f1, f2]
    else:
        h, f1 = dense(x, p["up"], ctx, "mlp_up", b=p.get("up_b"),
                      tag=up_tag)
        h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
        flags.append(f1)
    out, f3 = dense(h, p["down"], ctx, "mlp_down", b=p.get("down_b"),
                    tag=down_tag)
    flags.append(f3)
    return out, _or(flags)


def _or(flags):
    out = flags[0]
    for f in flags[1:]:
        out = jnp.logical_or(out, f)
    return out


def or_flags(*flags):
    return _or(list(flags))
