"""Mamba2 (SSD — state-space duality) block, chunked for TPU.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) decomposes the selective
scan into intra-chunk GEMMs (MXU-friendly, quadratic within a chunk) plus a
sequential inter-chunk state recurrence (lax.scan).  The in/out projections
are ABFT-protected GEMMs; the intra-chunk einsums are the Mamba analogue of
attention score/PV matmuls.

Decode maintains (conv_state, ssm_state) — constant-size per request, which
is why the SSM archs own the long_500k shapes (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.markers import coverage_scope
from repro.configs.base import ModelConfig
from repro.models.layers import LayerCtx, dense, gated_rms_norm, or_flags

F32 = jnp.float32


def _init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (scale * jax.random.normal(key, shape, F32)).astype(dtype)


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba(cfg: ModelConfig, key, dtype) -> dict:
    """Projections are stored split (z / x / BC / dt and conv_x / conv_bc)
    rather than fused, so tensor-parallel sharding of the head dims never
    slices across semantic boundaries (see sharding.py)."""
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "in_z": _init(ks[0], (cfg.d_model, d_in), dtype=dtype),
        "in_x": _init(ks[1], (cfg.d_model, d_in), dtype=dtype),
        "in_bc": _init(ks[2], (cfg.d_model, 2 * n), dtype=dtype),
        "in_dt": _init(ks[3], (cfg.d_model, h), dtype=dtype),
        "conv_x_w": _init(
            ks[4], (cfg.ssm_conv_width, d_in), scale=0.5, dtype=dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_bc_w": _init(
            ks[5], (cfg.ssm_conv_width, 2 * n), scale=0.5, dtype=dtype),
        "conv_bc_b": jnp.zeros((2 * n,), dtype),
        "A_log": jnp.zeros((h,), F32),            # A = -exp(A_log) = -1
        "D": jnp.ones((h,), F32),
        "dt_bias": jnp.full((h,), -4.0, F32),     # softplus^-1(~0.018)
        "out_norm": jnp.ones((d_in,), dtype),
        "out_proj": _init(ks[6], (d_in, cfg.d_model), dtype=dtype),
    }


def _project_in(x, p, cfg: ModelConfig, ctx: LayerCtx):
    """Split input projections; returns (z, xs, Bm, Cm, dt, flag)."""
    n = cfg.ssm_state
    z, f1 = dense(x, p["in_z"], ctx, "ssm_in", tag="ssm.in_z")
    xs, f2 = dense(x, p["in_x"], ctx, "ssm_in", tag="ssm.in_x")
    bc, f3 = dense(x, p["in_bc"], ctx, "ssm_in", tag="ssm.in_bc")
    dt, f4 = dense(x, p["in_dt"], ctx, "ssm_in", tag="ssm.in_dt")
    return z, xs, bc[..., :n], bc[..., n:], dt, or_flags(f1, f2, f3, f4)


def _causal_conv(u, w, b):
    """Depthwise causal conv, width W.  u: (B, L, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=F32)
    for i in range(W):  # W is tiny (4): unrolled adds, fuses well
        out = out + pad[:, i: i + u.shape[1], :].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out + b.astype(F32)).astype(u.dtype)


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bm/Cm: (B, L, N) (single group).  Returns (B, L, H, P) and the final
    state (B, H, P, N).

    flops[ssm_scan]: the intra-chunk einsums are weight-free data-data
    contractions (the SSM analogue of attention score/PV matmuls) with no
    ABFT kernel — the coverage auditor reports them as a known gap rather
    than a regression.
    """
    with coverage_scope("ssm_scan"):
        return _ssd_chunked_impl(xh, dt, A, Bm, Cm, chunk)


def _ssd_chunked_impl(xh, dt, A, Bm, Cm, chunk):
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = -L % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // Q

    xc = xh.reshape(Bsz, nc, Q, H, P).astype(F32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(F32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(F32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(F32)

    dA = dtc * A[None, None, None, :]                 # (B, c, Q, H)
    cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    cs_end = cs[:, :, -1:, :]                         # (B, c, 1, H)

    # intra-chunk (quadratic, MXU): L_mat[q,s] = exp(cs_q - cs_s), q >= s
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,c,Q,S,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                        preferred_element_type=F32)
    xdt = xc * dtc[..., None]                         # (B,c,Q,H,P)
    y_diag = jnp.einsum("bcqs,bcqsh,bcshp->bcqhp", scores, L_mat, xdt,
                        preferred_element_type=F32)

    # per-chunk state contribution and decay
    decay_out = jnp.exp(cs_end - cs)                  # (B,c,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_out, xdt,
                        preferred_element_type=F32)
    chunk_decay = jnp.exp(cs_end[:, :, 0, :])         # (B,c,H)

    # inter-chunk recurrence (sequential scan over chunks)
    def step(S_prev, xs):
        st, dec = xs                                  # (B,H,P,N), (B,H)
        S_new = S_prev * dec[:, :, None, None] + st
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, H, P, N), F32)
    S_final, S_prevs = jax.lax.scan(
        step,
        S0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)        # (B,c,H,P,N)

    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, S_prevs, jnp.exp(cs),
                       preferred_element_type=F32)

    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)[:, :L]
    return y, S_final


def mamba_forward(x, p, cfg: ModelConfig, ctx: LayerCtx):
    """Full-sequence Mamba2 mixer.  x: (B, L, D) -> (B, L, D)."""
    Bsz, L, _ = x.shape
    H, P, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, Bm, Cm, dt, f1 = _project_in(x, p, cfg, ctx)
    xs = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(
        jnp.concatenate([Bm, Cm], axis=-1), p["conv_bc_w"], p["conv_bc_b"])
    Bm, Cm = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(Bsz, L, H, P)
    y, _ = _ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(Bsz, L, cfg.d_inner).astype(x.dtype)
    y = gated_rms_norm(y, z, p["out_norm"], cfg.norm_eps)
    out, f2 = dense(y, p["out_proj"], ctx, "ssm_out", tag="ssm.out")
    return out, or_flags(f1, f2)


def mamba_prefill(x, p, cfg: ModelConfig, ctx: LayerCtx, cache,
                  slots=None, lengths=None):
    """Prefill: full-sequence forward + final (conv, ssm) states.

    ``slots``/``lengths`` (continuous-batching path): x is the admission
    batch (A, L, D) padded to a common L.  Padded positions are masked out
    of the recurrence (dt := 0 there, so the state neither decays nor
    accumulates past lengths[b]); the conv window is taken per-row at the
    true prompt end; states scatter into engine cache rows ``slots``.

    Paged engines (serve/paged_cache.py) use this same path: mamba state
    is constant-size per request — one implicit permanently-resident
    block per slot — so there is nothing to page and no block table to
    consult."""
    Bsz, L, _ = x.shape
    H, P, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.ssm_conv_width
    z, xs, Bm, Cm, dt, f1 = _project_in(x, p, cfg, ctx)
    bc_in = jnp.concatenate([Bm, Cm], axis=-1)
    valid = None
    if lengths is not None:
        valid = (jnp.arange(L)[None, :] < lengths[:, None])   # (A, L)
        vz = valid[..., None].astype(xs.dtype)
        xs = xs * vz
        bc_in = bc_in * vz.astype(bc_in.dtype)
    # conv states: last W-1 raw inputs of each stream (per-row window
    # ending at the true prompt length when ragged)
    pad_xs = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
    pad_bc = jnp.pad(bc_in, ((0, 0), (W - 1, 0), (0, 0)))
    if lengths is None:
        conv_x_state = jax.lax.dynamic_slice_in_dim(pad_xs, L, W - 1, axis=1)
        conv_bc_state = jax.lax.dynamic_slice_in_dim(pad_bc, L, W - 1, axis=1)
    else:
        row_slice = jax.vmap(
            lambda r, s: jax.lax.dynamic_slice_in_dim(r, s, W - 1, axis=0))
        conv_x_state = row_slice(pad_xs, lengths)
        conv_bc_state = row_slice(pad_bc, lengths)
    xs2 = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(bc_in, p["conv_bc_w"], p["conv_bc_b"])
    Bm2, Cm2 = bc[..., :n], bc[..., n:]
    dt2 = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    if valid is not None:
        # dt == 0 past the prompt end: exp(0)=1 decay, zero input term —
        # the state is frozen at its lengths[b]-token value through padding
        dt2 = dt2 * valid.astype(F32)[..., None]
    A = -jnp.exp(p["A_log"])
    xh = xs2.reshape(Bsz, L, H, P)
    y, S_final = _ssd_chunked(xh, dt2, A, Bm2, Cm2, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(Bsz, L, cfg.d_inner).astype(x.dtype)
    y = gated_rms_norm(y, z, p["out_norm"], cfg.norm_eps)
    out, f2 = dense(y, p["out_proj"], ctx, "ssm_out", tag="ssm.out")
    conv_x_state = conv_x_state.astype(cache["conv_x"].dtype)
    conv_bc_state = conv_bc_state.astype(cache["conv_bc"].dtype)
    S_final = S_final.astype(cache["ssm"].dtype)
    if slots is None:
        new_cache = {
            "conv_x": conv_x_state, "conv_bc": conv_bc_state, "ssm": S_final}
    else:
        new_cache = {
            "conv_x": cache["conv_x"].at[slots].set(conv_x_state),
            "conv_bc": cache["conv_bc"].at[slots].set(conv_bc_state),
            "ssm": cache["ssm"].at[slots].set(S_final),
        }
    return out, new_cache, or_flags(f1, f2)


def _conv_step(state, new, w, b):
    """Rolling depthwise conv step.  state: (B, W-1, C); new: (B, C)."""
    with coverage_scope("ssm_scan"):
        window = jnp.concatenate(
            [state.astype(F32), new[:, None, :].astype(F32)], axis=1)
        out = jnp.einsum("bwc,wc->bc", window, w.astype(F32))
        out = jax.nn.silu(out + b.astype(F32))
        return out, window[:, 1:, :]


def mamba_decode(x, p, cfg: ModelConfig, ctx: LayerCtx, cache):
    """One-token recurrent step.  x: (B, 1, D).  Serves the dense AND
    paged engines alike (per-slot constant-size state; see
    mamba_prefill)."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, Bm, Cm, dt, f1 = _project_in(x, p, cfg, ctx)
    z, xs, dt = z[:, 0], xs[:, 0], dt[:, 0]
    bc_in = jnp.concatenate([Bm[:, 0], Cm[:, 0]], axis=-1)

    xs2, new_conv_x = _conv_step(
        cache["conv_x"], xs, p["conv_x_w"], p["conv_x_b"])
    bc2, new_conv_bc = _conv_step(
        cache["conv_bc"], bc_in, p["conv_bc_w"], p["conv_bc_b"])
    Bm2, Cm2 = bc2[..., :N], bc2[..., N:]

    dt2 = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt2 * A[None, :])                         # (B, H)
    xh = xs2.reshape(Bsz, H, P)
    S = cache["ssm"].astype(F32)                           # (B,H,P,N)
    with coverage_scope("ssm_scan"):
        S = S * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt2, Bm2, xh, preferred_element_type=F32)
        y = jnp.einsum("bn,bhpn->bhp", Cm2, S,
                       preferred_element_type=F32)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x.dtype)
    y = gated_rms_norm(y, z[:, None, :], p["out_norm"], cfg.norm_eps)
    out, f2 = dense(y, p["out_proj"], ctx, "ssm_out", tag="ssm.out")
    new_cache = {
        "conv_x": new_conv_x.astype(cache["conv_x"].dtype),
        "conv_bc": new_conv_bc.astype(cache["conv_bc"].dtype),
        "ssm": S.astype(cache["ssm"].dtype),
    }
    return out, new_cache, or_flags(f1, f2)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv_x": jnp.zeros(
            (batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros(
            (batch, cfg.ssm_conv_width - 1, 2 * cfg.ssm_state), dtype),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), F32),
    }
