"""Analytic accounting: parameter counts, per-layer GEMM dims, model FLOPs.

Used by (i) the roofline's MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE),
(ii) the paper-figure benchmarks (aggregate/per-layer arithmetic intensity),
and (iii) the intensity-guided selection report.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.intensity import GemmDims
from repro.models.model import layer_tags

# Which GEMM dim tensor parallelism shards at each plan site, mirroring
# the parameter PartitionSpecs in distributed/sharding.py._param_rule:
# "n" = column-parallel (output dim over 'model': wq/wk/wv, up/gate,
# lm_head, ...), "k" = row-parallel (contraction dim over 'model': wo,
# down, ssm out_proj, ...).  Sites absent here are replicated (mla.q_a /
# kv_a low-rank projections, ssm.in_bc, moe.router, vision.proj) and
# keep their full dims on every shard.
_TP_SHARD_DIM = {
    "attn.q": "n", "attn.k": "n", "attn.v": "n", "attn.o": "k",
    "mla.q_b": "n", "mla.out": "k",
    "ssm.in_z": "n", "ssm.in_x": "n", "ssm.in_dt": "n", "ssm.out": "k",
    "mlp.up": "n", "mlp.down": "k",
    "moe.shared_up": "n", "moe.shared_down": "k",
    "cross.q": "n", "cross.k": "n", "cross.v": "n", "cross.o": "k",
    "enc.attn.q": "n", "enc.attn.k": "n", "enc.attn.v": "n",
    "enc.attn.o": "k",
    "enc.mlp.up": "n", "enc.mlp.down": "k",
    "lm_head": "n",
}


def shard_gemms(sites: dict, cfg: ModelConfig, model_parallel: int) -> dict:
    """Per-DEVICE GEMM dims under ``model_parallel``-way tensor/expert
    parallelism — the post-sharding shapes a ProtectionPlan must be
    compiled from, because TP shrinks each device's (m,k,n) and with it
    the arithmetic intensity the scheme selection keys on (the paper's
    selection boundary moves with mesh width).

    Mirrors ``distributed/sharding.py`` exactly: a dim is divided only
    when the axis divides it (``sanitize_spec`` drops the sharding
    otherwise, so the per-device GEMM stays full); experts shard over
    the model axis when the expert count divides it (EP — per-device
    *count* shrinks, per-expert dims do not), falling back to TP on the
    expert FFN dim when it does not (qwen2-moe's 60 experts)."""
    tp = int(model_parallel)
    if tp <= 1:
        return sites
    ep_fits = cfg.n_experts % tp == 0 if cfg.n_experts else True
    out = {}
    for name, (d, count) in sites.items():
        dim = _TP_SHARD_DIM.get(name)
        if name in ("moe.expert_up", "moe.expert_down"):
            if ep_fits:
                count = max(1, count // tp)
            else:
                dim = "n" if name.endswith("up") else "k"
        if dim == "n" and d.n % tp == 0 and d.n >= tp:
            d = dataclasses.replace(d, n=d.n // tp)
        elif dim == "k" and d.k % tp == 0 and d.k >= tp:
            d = dataclasses.replace(d, k=d.k // tp)
        out[name] = (d, count)
    return out


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.attention == "mla":
        dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        return (
            cfg.d_model * cfg.q_lora_rank
            + cfg.q_lora_rank * cfg.n_heads * (dn + dr)
            + cfg.d_model * (cfg.kv_lora_rank + dr)
            + cfg.n_heads * dn * cfg.kv_lora_rank
            + cfg.n_heads * cfg.kv_lora_rank * dv
            + cfg.n_heads * dv * cfg.d_model
        )
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _mamba_params(cfg: ModelConfig) -> int:
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * d_in + 2 * n + h
    return (
        cfg.d_model * proj_out
        + cfg.ssm_conv_width * (d_in + 2 * n)
        + 3 * h            # A_log, D, dt_bias
        + d_in             # out_norm
        + d_in * cfg.d_model
    )


def _dense_ffn_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.act == "silu" else 2
    return mult * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ModelConfig) -> tuple:
    """(total, active) params of one MoE FFN."""
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    router = cfg.d_model * cfg.n_experts
    shared = 3 * cfg.d_model * cfg.moe_d_ff * cfg.n_shared_experts
    total = cfg.n_experts * per_expert + router + shared
    active = cfg.experts_per_token * per_expert + router + shared
    return total, active


def _cross_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    return (
        cfg.d_model * cfg.n_heads * hd
        + 2 * cfg.d_model * cfg.n_kv_heads * hd
        + cfg.n_heads * hd * cfg.d_model
    )


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model            # embedding
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size       # head
    for tag in layer_tags(cfg):
        mixer, ffn, cross = tag.split(":")
        if mixer in ("attn", "mla"):
            total += _attn_params(cfg)
        else:
            total += _mamba_params(cfg)
        if cross == "1":
            total += _cross_params(cfg)
        if ffn == "dense":
            total += _dense_ffn_params(cfg)
        elif ffn == "moe":
            t, a = _moe_params(cfg)
            total += a if active_only else t
    if cfg.is_encoder_decoder:
        total += cfg.n_enc_layers * (
            _attn_params(cfg) + _dense_ffn_params(cfg))
        if cfg.n_mels:
            # conv stem: two width-3 1-D convs + biases
            total += (3 * cfg.n_mels * cfg.d_model + cfg.d_model
                      + 3 * cfg.d_model * cfg.d_model + cfg.d_model)
    if cfg.vision_dim:
        total += cfg.vision_dim * cfg.d_model
    return total


def model_flops(cfg: ModelConfig, n_tokens: int, training: bool) -> float:
    """MODEL_FLOPS = 6*N*D (training) or 2*N*D (inference), with N the
    *active* parameter count (MoE counts only routed-in experts)."""
    n_active = count_params(cfg, active_only=True)
    mult = 6.0 if training else 2.0
    return mult * n_active * n_tokens


def layer_gemms(
    cfg: ModelConfig, n_tokens: int, phase: str = "prefill",
    dtype_bytes: int = 2, model_parallel: int = 1,
) -> dict:
    """Per-GEMM-site dims for one representative layer of each kind plus the
    head, scaled by site multiplicity.  ``n_tokens`` is the GEMM M dim
    (batch*seq for full passes; batch for decode).  ``model_parallel > 1``
    returns each DEVICE's post-sharding dims (``shard_gemms``)."""
    hd = cfg.resolved_head_dim
    sites: dict = {}
    m = n_tokens

    def g(k, n):
        return GemmDims(m=m, k=k, n=n, dtype_bytes=dtype_bytes)

    tags = layer_tags(cfg)
    n_attn = sum(1 for t in tags if t.split(":")[0] in ("attn", "mla"))
    n_mamba = sum(1 for t in tags if t.split(":")[0] == "mamba")
    n_dense_ffn = sum(1 for t in tags if t.split(":")[1] == "dense")
    n_moe = sum(1 for t in tags if t.split(":")[1] == "moe")
    n_cross = sum(1 for t in tags if t.split(":")[2] == "1")

    if n_attn:
        if cfg.attention == "mla":
            dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
            sites["mla.q_a"] = (g(cfg.d_model, cfg.q_lora_rank), n_attn)
            sites["mla.q_b"] = (
                g(cfg.q_lora_rank, cfg.n_heads * (dn + dr)), n_attn)
            sites["mla.kv_a"] = (
                g(cfg.d_model, cfg.kv_lora_rank + dr), n_attn)
            sites["mla.out"] = (
                g(cfg.n_heads * cfg.v_head_dim, cfg.d_model), n_attn)
        else:
            sites["attn.q"] = (g(cfg.d_model, cfg.n_heads * hd), n_attn)
            sites["attn.k"] = (g(cfg.d_model, cfg.n_kv_heads * hd), n_attn)
            sites["attn.v"] = (g(cfg.d_model, cfg.n_kv_heads * hd), n_attn)
            sites["attn.o"] = (g(cfg.n_heads * hd, cfg.d_model), n_attn)
    if n_mamba:
        d_in = cfg.d_inner
        # the in-projection is stored split (z / x / BC / dt; see
        # models/mamba.py) so each split GEMM is its own plan site with
        # its own arithmetic intensity
        sites["ssm.in_z"] = (g(cfg.d_model, d_in), n_mamba)
        sites["ssm.in_x"] = (g(cfg.d_model, d_in), n_mamba)
        sites["ssm.in_bc"] = (g(cfg.d_model, 2 * cfg.ssm_state), n_mamba)
        sites["ssm.in_dt"] = (g(cfg.d_model, cfg.ssm_heads), n_mamba)
        sites["ssm.out"] = (g(d_in, cfg.d_model), n_mamba)
    if n_dense_ffn:
        mult = 2 if cfg.act == "silu" else 1
        sites["mlp.up"] = (g(cfg.d_model, cfg.d_ff), n_dense_ffn * mult)
        sites["mlp.down"] = (g(cfg.d_ff, cfg.d_model), n_dense_ffn)
    if n_moe:
        sites["moe.router"] = (g(cfg.d_model, cfg.n_experts), n_moe)
        # per-expert GEMM: tokens-per-expert is the M dim
        m_e = max(1, m * cfg.experts_per_token // cfg.n_experts)
        ge = GemmDims(m=m_e, k=cfg.d_model, n=cfg.moe_d_ff,
                      dtype_bytes=dtype_bytes)
        gd = GemmDims(m=m_e, k=cfg.moe_d_ff, n=cfg.d_model,
                      dtype_bytes=dtype_bytes)
        sites["moe.expert_up"] = (ge, n_moe * 2 * cfg.n_experts)
        sites["moe.expert_down"] = (gd, n_moe * cfg.n_experts)
        if cfg.n_shared_experts:
            fs = cfg.moe_d_ff * cfg.n_shared_experts
            sites["moe.shared_up"] = (g(cfg.d_model, fs), n_moe * 2)
            sites["moe.shared_down"] = (
                GemmDims(m=m, k=fs, n=cfg.d_model, dtype_bytes=dtype_bytes),
                n_moe)
    if n_cross:
        sites["cross.q"] = (g(cfg.d_model, cfg.n_heads * hd), n_cross)
        sites["cross.k"] = (g(cfg.d_model, cfg.n_kv_heads * hd), n_cross)
        sites["cross.v"] = (g(cfg.d_model, cfg.n_kv_heads * hd), n_cross)
        sites["cross.o"] = (g(cfg.n_heads * hd, cfg.d_model), n_cross)
    if cfg.is_encoder_decoder and cfg.n_enc_layers:
        ne = cfg.n_enc_layers
        mult = 2 if cfg.act == "silu" else 1
        sites["enc.attn.q"] = (g(cfg.d_model, cfg.n_heads * hd), ne)
        sites["enc.attn.k"] = (g(cfg.d_model, cfg.n_kv_heads * hd), ne)
        sites["enc.attn.v"] = (g(cfg.d_model, cfg.n_kv_heads * hd), ne)
        sites["enc.attn.o"] = (g(cfg.n_heads * hd, cfg.d_model), ne)
        sites["enc.mlp.up"] = (g(cfg.d_model, cfg.d_ff), ne * mult)
        sites["enc.mlp.down"] = (g(cfg.d_ff, cfg.d_model), ne)
    if cfg.vision_dim:
        sites["vision.proj"] = (g(cfg.vision_dim, cfg.d_model), 1)
    sites["lm_head"] = (g(cfg.d_model, cfg.vocab_size), 1)
    return shard_gemms(sites, cfg, model_parallel)


def layer_specs(
    cfg: ModelConfig, n_tokens: int, phase: str = "prefill",
    dtype_bytes: int = 2, model_parallel: int = 1,
) -> list:
    """Plan-ready layer descriptors (``policy.LayerSpec``) for one
    representative layer of each kind plus the head.

    The ``first`` flag — global ABFT's unfused activation-checksum read
    (schemes.cost_global) — is placed EXPLICITLY on the mixer projection
    of the model's actual first layer (``layer_tags(cfg)[0]``), not on
    whichever site happens to enumerate first in the dict.  A jamba-style
    hybrid whose stack opens with a mamba block therefore flags
    ``ssm.in_z``, never ``attn.q``."""
    from repro.core.policy import LayerSpec

    sites = layer_gemms(cfg, n_tokens, phase, dtype_bytes,
                        model_parallel=model_parallel)
    first_mixer = layer_tags(cfg)[0].split(":")[0]
    first_site = {
        "attn": "attn.q", "mla": "mla.q_a", "mamba": "ssm.in_z",
    }.get(first_mixer)
    return [
        LayerSpec(name=name, dims=dims, count=count,
                  first=(name == first_site))
        for name, (dims, count) in sites.items()
    ]


def aggregate_ai(cfg: ModelConfig, n_tokens: int, phase: str = "prefill"):
    """Aggregate arithmetic intensity over all linear layers (paper §3.2)."""
    sites = layer_gemms(cfg, n_tokens, phase)
    flops = sum(d.flops * c for d, c in sites.values())
    bytes_ = sum(d.bytes_total * c for d, c in sites.values())
    return flops / max(bytes_, 1.0)
