"""Model zoo: every assigned architecture built from ABFT-protected layers."""

from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model, build_model, layer_tags, seg_plan

__all__ = [
    "LayerCtx",
    "Model",
    "ModelFault",
    "build_model",
    "layer_tags",
    "seg_plan",
]
