"""Model assembly: builds every assigned architecture from the layer zoo.

Heterogeneous stacks (jamba's 1:7 mamba:attention interleave, deepseek's
3-dense + 58-MoE split, the VLM's every-5th cross-attention layer) are
expressed as a *segment plan*: the per-layer tag sequence is factored into
segments of repeating units, each segment scanned with stacked params so
HLO size stays bounded at 512-way SPMD (DESIGN.md §5).

Tags are "mixer:ffn:cross" with mixer in {attn, mla, mamba},
ffn in {dense, moe, none}, cross in {0, 1}.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.markers import coverage_scope
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.layers import LayerCtx, dense, mlp, norm, or_flags

F32 = jnp.float32


def _init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (scale * jax.random.normal(key, shape, F32)).astype(dtype)


# ---------------------------------------------------------------- plan

@dataclasses.dataclass(frozen=True)
class Segment:
    unit: tuple    # tags of one repeating unit
    repeats: int


def layer_tags(cfg: ModelConfig) -> list:
    tags = []
    for i in range(cfg.n_layers):
        mixer = cfg.layer_kind(i)            # attn | mamba
        if mixer == "attn" and cfg.attention == "mla":
            mixer = "mla"
        if cfg.d_ff or cfg.n_experts:
            ffn = cfg.ffn_kind(i)
        else:
            ffn = "none"
        cross = (
            "1"
            if cfg.cross_attn_every
            and i % cfg.cross_attn_every == cfg.cross_attn_every - 2
            else "0"
        )
        tags.append(f"{mixer}:{ffn}:{cross}")
    return tags


def seg_plan(cfg: ModelConfig) -> list:
    tags = layer_tags(cfg)
    n = len(tags)
    if n == 0:
        return []
    # (a) smallest period p such that the whole stack is p-periodic
    for p in range(1, min(12, n) + 1):
        if n % p == 0 and all(tags[i] == tags[i % p] for i in range(n)):
            return [Segment(unit=tuple(tags[:p]), repeats=n // p)]
    # (b) contiguous uniform runs (deepseek: 3 dense + 58 moe)
    segs = []
    start = 0
    for i in range(1, n + 1):
        if i == n or tags[i] != tags[start]:
            segs.append(Segment(unit=(tags[start],), repeats=i - start))
            start = i
    if len(segs) <= 4:
        return segs
    # (c) fallback: one unrolled segment
    return [Segment(unit=tuple(tags), repeats=1)]


# ---------------------------------------------------------------- layer init

def init_layer(cfg: ModelConfig, tag: str, key, dtype) -> dict:
    mixer, ffn, cross = tag.split(":")
    ks = jax.random.split(key, 4)
    norm_p = (
        lambda: {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros(
            (cfg.d_model,), dtype)}
        if cfg.norm == "layernorm"
        else {"w": jnp.ones((cfg.d_model,), dtype)}
    )
    p: dict = {"mixer_norm": norm_p()}
    if mixer == "attn":
        p["mixer"] = attn.init_gqa(cfg, ks[0], dtype)
    elif mixer == "mla":
        p["mixer"] = attn.init_mla(cfg, ks[0], dtype)
    elif mixer == "mamba":
        p["mixer"] = mb.init_mamba(cfg, ks[0], dtype)
    if cross == "1":
        p["cross"] = attn.init_cross(cfg, ks[1], dtype)
        p["cross_norm"] = norm_p()
        p["cross_gate"] = jnp.zeros((), F32)
    if ffn == "dense":
        fk = jax.random.split(ks[2], 3)
        p["ffn"] = {
            "up": _init(fk[0], (cfg.d_model, cfg.d_ff), dtype=dtype),
            "gate": _init(fk[1], (cfg.d_model, cfg.d_ff), dtype=dtype),
            "down": _init(fk[2], (cfg.d_ff, cfg.d_model), dtype=dtype),
        }
        if cfg.act == "gelu":
            del p["ffn"]["gate"]
            p["ffn"]["up_b"] = jnp.zeros((cfg.d_ff,), dtype)
            p["ffn"]["down_b"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn_norm"] = norm_p()
    elif ffn == "moe":
        p["ffn"] = moe_mod.init_moe(cfg, ks[2], dtype)
        p["ffn_norm"] = norm_p()
    return p


def init_cross_cache(cfg: ModelConfig, batch: int, mem_len: int, dtype):
    """Cross-attention K/V cache: per-slot, fixed mem_len (encoder/vision
    memory never grows, so it is identical under dense and paged KV)."""
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, mem_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, mem_len, cfg.n_kv_heads, hd), dtype),
    }


def init_layer_cache(cfg: ModelConfig, tag: str, batch: int, max_len: int,
                     mem_len: int, dtype) -> dict:
    mixer, _, cross = tag.split(":")
    c: dict = {}
    if mixer == "attn":
        c["attn"] = attn.init_gqa_cache(cfg, batch, max_len, dtype)
    elif mixer == "mla":
        c["attn"] = attn.init_mla_cache(cfg, batch, max_len, dtype)
    elif mixer == "mamba":
        c["attn"] = mb.init_mamba_cache(cfg, batch, dtype)
    if cross == "1":
        c["cross"] = init_cross_cache(cfg, batch, mem_len, dtype)
    return c


# ---------------------------------------------------------------- layer apply

def apply_layer(
    x, lp, tag: str, cfg: ModelConfig, ctx: LayerCtx, positions,
    mode: str, cache, pos, mem, causal: bool = True,
    slots=None, lengths=None, tables=None, prefix_lens=None,
):
    """One transformer/mamba layer.  mode: full | prefill | decode |
    verify.
    ``pos`` (decode/verify): scalar or (B,) per-slot cursor vector.
    ``verify`` (speculative decoding): x is (B, T, D) — each row's last
    committed token plus its draft window — and ``lengths`` carries the
    per-row VALID window size (rows are padded to a uniform T);
    attention-only stacks, like chunked prefill, for the same reason
    (rollback resets a cursor, not an SSM recurrence).
    ``slots``/``lengths`` (prefill): scatter targets + ragged valid lengths
    for continuous-batching admission into an engine-deep cache.
    ``tables``: (B, W) block tables — selects the PAGED cache paths, where
    attention KV lives in a (num_blocks, block_size, ...) pool shared
    across slots (serve/paged_cache.py) while mamba state stays per-slot.
    ``prefix_lens``: (B,) logical start of each row's tokens — the
    prefix-sharing suffix prefill (paged) and the chunked-prefill resume
    path (paged or dense).  Attention layers only: SSM recurrence state
    is not a pure function of resident KV, so both are gated off for
    hybrid stacks at the engine.
    Returns (x, new_cache, flag, aux)."""
    mixer, ffn, cross = tag.split(":")
    flags = []
    aux = jnp.zeros((), F32)
    new_cache: dict = {}

    h = norm(x, lp["mixer_norm"], cfg.norm, cfg.norm_eps)
    if mixer in ("attn", "mla"):
        fwd = attn.gqa_forward if mixer == "attn" else attn.mla_forward
        if tables is not None:
            pre = (attn.gqa_paged_prefill if mixer == "attn"
                   else attn.mla_paged_prefill)
            dec = (attn.gqa_paged_decode if mixer == "attn"
                   else attn.mla_paged_decode)
        else:
            pre = attn.gqa_prefill if mixer == "attn" else attn.mla_prefill
            dec = attn.gqa_decode if mixer == "attn" else attn.mla_decode
        if mode == "full":
            if mixer == "attn":
                a, f = fwd(h, lp["mixer"], cfg, ctx, positions, causal=causal)
            else:
                a, f = fwd(h, lp["mixer"], cfg, ctx, positions)
        elif mode == "prefill":
            if tables is not None:
                a, nc, f = pre(h, lp["mixer"], cfg, ctx, positions,
                               cache["attn"], tables, lengths,
                               starts=prefix_lens)
            else:
                a, nc, f = pre(h, lp["mixer"], cfg, ctx, positions,
                               cache["attn"], slots=slots, lengths=lengths,
                               starts=prefix_lens)
            new_cache["attn"] = nc
        elif mode == "verify":
            ver = (attn.gqa_verify if mixer == "attn" else attn.mla_verify)
            if tables is not None:
                ver = (attn.gqa_paged_verify if mixer == "attn"
                       else attn.mla_paged_verify)
                a, nc, f = ver(h, lp["mixer"], cfg, ctx, pos,
                               cache["attn"], lengths, tables)
            else:
                a, nc, f = ver(h, lp["mixer"], cfg, ctx, pos,
                               cache["attn"], lengths)
            new_cache["attn"] = nc
        else:
            if tables is not None:
                a, nc, f = dec(h, lp["mixer"], cfg, ctx, pos, cache["attn"],
                               tables)
            else:
                a, nc, f = dec(h, lp["mixer"], cfg, ctx, pos, cache["attn"])
            new_cache["attn"] = nc
    else:
        # mamba state is constant-size per request (conv window + SSD
        # state) — one implicit permanently-resident block per slot, so
        # the paged engine uses the same per-slot paths and the block
        # tables are simply not forwarded
        assert prefix_lens is None, (
            "prefix sharing / chunked prefill cannot resume the SSM "
            "recurrence state mid-prompt")
        assert mode != "verify", (
            "speculative verify cannot roll the SSM recurrence state "
            "back to the last accepted position")
        if mode == "full":
            a, f = mb.mamba_forward(h, lp["mixer"], cfg, ctx)
        elif mode == "prefill":
            a, nc, f = mb.mamba_prefill(h, lp["mixer"], cfg, ctx,
                                        cache["attn"],
                                        slots=slots, lengths=lengths)
            new_cache["attn"] = nc
        else:
            a, nc, f = mb.mamba_decode(h, lp["mixer"], cfg, ctx,
                                       cache["attn"])
            new_cache["attn"] = nc
    x = x + a
    flags.append(f)

    if cross == "1":
        h = norm(x, lp["cross_norm"], cfg.norm, cfg.norm_eps)
        if mode == "decode":
            ck, cv = cache["cross"]["k"], cache["cross"]["v"]
            fkv = jnp.zeros((), bool)
            new_cache["cross"] = cache["cross"]
        else:
            ck, cv, fkv = attn.cross_kv(mem, lp["cross"], cfg, ctx)
            if mode == "prefill":
                ckd = ck.astype(cache["cross"]["k"].dtype)
                cvd = cv.astype(cache["cross"]["v"].dtype)
                if slots is None:
                    new_cache["cross"] = {"k": ckd, "v": cvd}
                else:
                    new_cache["cross"] = {
                        "k": cache["cross"]["k"].at[slots].set(ckd),
                        "v": cache["cross"]["v"].at[slots].set(cvd),
                    }
        a, f = attn.cross_forward(h, ck, cv, lp["cross"], cfg, ctx)
        gate = jnp.tanh(lp["cross_gate"]).astype(x.dtype)
        x = x + gate * a
        flags += [fkv, f]

    if ffn != "none":
        h = norm(x, lp["ffn_norm"], cfg.norm, cfg.norm_eps)
        if ffn == "moe":
            o, f, a_loss = moe_mod.moe_forward(h, lp["ffn"], cfg, ctx)
            aux = aux + a_loss
        else:
            o, f = mlp(h, lp["ffn"], ctx, act=cfg.act)
        x = x + o
        flags.append(f)

    return x, new_cache, or_flags(*flags), aux


# ---------------------------------------------------------------- stacks

def run_stack(
    x, segments_params, plan, cfg: ModelConfig, ctx: LayerCtx, positions,
    mode: str, caches, pos, mem, causal: bool = True, remat: bool = False,
    layer_offset: int = 0, slots=None, lengths=None, tables=None,
    prefix_lens=None,
):
    """Apply all segments.  caches: list aligned with plan (or None).
    ``pos``: decode cursor — scalar or (B,) vector; ``slots``/``lengths``
    thread the continuous-batching prefill path, ``tables`` the paged
    block-table path, and ``prefix_lens`` the prefix-sharing suffix
    prefill (see apply_layer).
    Returns (x, new_caches, flag, aux)."""
    flag = jnp.zeros((), bool)
    aux = jnp.zeros((), F32)
    new_caches = []
    offset = layer_offset
    for si, seg in enumerate(plan):
        sp = segments_params[si]
        sc = caches[si] if caches is not None else None
        p = len(seg.unit)
        seg_off = offset

        def unit_body(carry, xs, _unit=seg.unit, _off=seg_off, _p=p):
            xx, fl, au = carry
            if sc is not None:
                up, uc, rep = xs
            else:
                up, rep = xs
                uc = None
            new_uc = {}
            for q, tag in enumerate(_unit):
                idx = _off + rep * _p + q
                lctx = ctx.with_layer(jnp.asarray(idx, jnp.int32))
                xx, ncq, f, a = apply_layer(
                    xx, up[f"pos{q}"], tag, cfg, lctx, positions, mode,
                    uc[f"pos{q}"] if uc is not None else None, pos, mem,
                    causal=causal, slots=slots, lengths=lengths,
                    tables=tables, prefix_lens=prefix_lens,
                )
                new_uc[f"pos{q}"] = ncq
                fl = jnp.logical_or(fl, f)
                au = au + a
            return (xx, fl, au), new_uc if sc is not None else None

        body = jax.checkpoint(unit_body) if remat else unit_body

        if seg.repeats == 1:
            # single unit: apply directly (no scan) with unstacked params
            sp1 = jax.tree_util.tree_map(lambda a: a[0], sp)
            sc1 = (
                jax.tree_util.tree_map(lambda a: a[0], sc)
                if sc is not None else None
            )
            xs = (sp1, sc1, jnp.zeros((), jnp.int32)) if sc is not None \
                else (sp1, jnp.zeros((), jnp.int32))
            (x, flag, aux), nc = body((x, flag, aux), xs)
            new_caches.append(
                jax.tree_util.tree_map(lambda a: a[None], nc)
                if nc is not None else None)
        else:
            reps = jnp.arange(seg.repeats, dtype=jnp.int32)
            xs = (sp, sc, reps) if sc is not None else (sp, reps)
            (x, flag, aux), nc = jax.lax.scan(body, (x, flag, aux), xs)
            new_caches.append(nc)
        offset += p * seg.repeats
    return x, new_caches, flag, aux


# ---------------------------------------------------------------- model

class ForwardOut(NamedTuple):
    logits: jnp.ndarray
    flag: jnp.ndarray
    aux_loss: jnp.ndarray
    mtp_logits: Any = None


def sinusoid_pos(positions, d_model: int):
    """Whisper-style sinusoidal position encoding.  positions: (B, L)."""
    half = d_model // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=F32) / max(half - 1, 1))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class Model:
    """Functional model wrapper for one architecture."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = seg_plan(cfg)
        self.enc_plan = (
            [Segment(unit=("attn:dense:0",), repeats=cfg.n_enc_layers)]
            if cfg.is_encoder_decoder else []
        )

    # -------------------------------------------------- init
    def init_params(self, key, dtype=jnp.bfloat16):
        cfg = self.cfg
        k_emb, k_seg, k_enc, k_head, k_misc = jax.random.split(key, 5)
        params: dict = {
            "embed": _init(k_emb, (cfg.vocab_size, cfg.d_model), dtype=dtype),
            "final_norm": (
                {"w": jnp.ones((cfg.d_model,), dtype),
                 "b": jnp.zeros((cfg.d_model,), dtype)}
                if cfg.norm == "layernorm"
                else {"w": jnp.ones((cfg.d_model,), dtype)}
            ),
            "segments": self._init_segments(self.plan, k_seg, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = _init(
                k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
        if cfg.is_encoder_decoder:
            params["encoder"] = {
                "segments": self._init_segments(self.enc_plan, k_enc, dtype),
                "final_norm": {
                    "w": jnp.ones((cfg.d_model,), dtype),
                    "b": jnp.zeros((cfg.d_model,), dtype)},
            }
        if cfg.is_encoder_decoder and cfg.n_mels:
            ck = jax.random.split(k_misc, 2)
            params["conv_stem"] = {
                "w1": _init(ck[0], (3, cfg.n_mels, cfg.d_model),
                            dtype=dtype),
                "b1": jnp.zeros((cfg.d_model,), dtype),
                "w2": _init(ck[1], (3, cfg.d_model, cfg.d_model),
                            dtype=dtype),
                "b2": jnp.zeros((cfg.d_model,), dtype),
            }
        if cfg.vision_dim:
            params["vision_proj"] = _init(
                k_misc, (cfg.vision_dim, cfg.d_model), dtype=dtype)
        if cfg.mtp_depth:
            mk = jax.random.split(k_misc, 3)
            params["mtp"] = {
                "proj": _init(mk[0], (2 * cfg.d_model, cfg.d_model),
                              dtype=dtype),
                "layer": init_layer(
                    cfg, layer_tags(cfg)[-1], mk[1], dtype),
                "norm": {"w": jnp.ones((cfg.d_model,), dtype)},
            }
        return params

    def _init_segments(self, plan, key, dtype):
        cfg = self.cfg
        segs = []
        keys = jax.random.split(key, max(len(plan), 1))
        for seg, k in zip(plan, keys):
            rkeys = jax.random.split(k, seg.repeats)

            def one(kk, _unit=seg.unit):
                uks = jax.random.split(kk, len(_unit))
                return {
                    f"pos{q}": init_layer(cfg, tag, uks[q], dtype)
                    for q, tag in enumerate(_unit)
                }

            segs.append(jax.vmap(one)(rkeys))
        return segs

    # -------------------------------------------------- cache
    def _resolved_mem_len(self, mem_len: int | None) -> int:
        cfg = self.cfg
        return mem_len or (
            cfg.enc_seq_len if cfg.is_encoder_decoder else cfg.n_image_tokens)

    def _stack_caches(self, layer_cache_fn):
        """Build the per-segment cache list: one layer-cache per unit
        position, stacked over segment repeats."""
        caches = []
        for seg in self.plan:
            one = {f"pos{q}": layer_cache_fn(tag)
                   for q, tag in enumerate(seg.unit)}
            caches.append(
                jax.tree_util.tree_map(
                    lambda a, _r=seg.repeats: jnp.broadcast_to(
                        a[None], (_r,) + a.shape), one))
        return caches

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   mem_len: int | None = None):
        cfg = self.cfg
        mem_len = self._resolved_mem_len(mem_len)
        return self._stack_caches(
            lambda tag: init_layer_cache(
                cfg, tag, batch, max_len, mem_len, dtype))

    def init_paged_cache(self, slots: int, num_blocks: int,
                         block_size: int, dtype=jnp.bfloat16,
                         mem_len: int | None = None):
        """Paged-engine cache: attention KV lives in per-layer
        (num_blocks, block_size, ...) pools indexed by the engine's
        shared block tables (serve/paged_cache.py); mamba and cross-attn
        state stay per-slot (constant-size / fixed mem_len)."""
        from repro.serve import paged_cache as pc

        cfg = self.cfg
        mem_len = self._resolved_mem_len(mem_len)

        def one_layer(tag):
            mixer, _, cross = tag.split(":")
            c: dict = {}
            if mixer == "attn":
                c["attn"] = pc.init_paged_gqa_cache(
                    cfg, num_blocks, block_size, dtype)
            elif mixer == "mla":
                c["attn"] = pc.init_paged_mla_cache(
                    cfg, num_blocks, block_size, dtype)
            elif mixer == "mamba":
                c["attn"] = pc.init_paged_mamba_cache(cfg, slots, dtype)
            if cross == "1":
                c["cross"] = init_cross_cache(cfg, slots, mem_len, dtype)
            return c

        return self._stack_caches(one_layer)

    # -------------------------------------------------- memory (enc / vision)
    def _conv_stem(self, params, audio):
        """Whisper audio frontend: two width-3 1-D convs (stride 1 then 2)
        with GELU, mapping (B, T, n_mels) log-mel frames to
        (B, ceil(T/2), d_model).

        flops[conv_stem]: conv FLOPs have no registered ABFT scheme —
        the coverage auditor reports them as the known_unprotected conv
        frontend (ROADMAP item 5a tracks closing the gap with a
        checksummed im2col GEMM)."""
        cs = params["conv_stem"]
        with coverage_scope("conv_stem"):
            h = jax.lax.conv_general_dilated(
                audio.astype(cs["w1"].dtype), cs["w1"],
                window_strides=(1,), padding="SAME",
                dimension_numbers=("NWC", "WIO", "NWC"))
            h = jax.nn.gelu(h + cs["b1"])
            h = jax.lax.conv_general_dilated(
                h, cs["w2"], window_strides=(2,), padding="SAME",
                dimension_numbers=("NWC", "WIO", "NWC"))
            h = jax.nn.gelu(h + cs["b2"])
        return h

    def _memory(self, params, batch, ctx):
        """Encoder output (whisper) or projected vision tokens (vlm)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            if "audio" in batch and "conv_stem" in params:
                # (B, T, n_mels) raw log-mel frames through the conv stem
                frames = self._conv_stem(params, batch["audio"])
            else:
                frames = batch["enc_input"]      # (B, S_enc, d_model) stub
            B, S, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            h = frames + sinusoid_pos(pos, cfg.d_model).astype(frames.dtype)
            # encoder sites get their own plan/audit namespace ("enc.")
            enc_ctx = dataclasses.replace(ctx, site_prefix="enc.")
            h, _, flag, _ = run_stack(
                h, params["encoder"]["segments"], self.enc_plan, cfg,
                enc_ctx, pos, "full", None, None, None, causal=False)
            h = norm(h, params["encoder"]["final_norm"], "layernorm",
                     cfg.norm_eps)
            return h, flag
        if cfg.vision_dim:
            img = batch["images"]                # (B, n_img, vision_dim)
            mem, f = dense(img, params["vision_proj"], ctx, "cross_qkv",
                           tag="vision.proj")
            return mem, f
        return None, jnp.zeros((), bool)

    # -------------------------------------------------- forward (train)
    def forward(self, params, batch, ctx: LayerCtx) -> ForwardOut:
        cfg = self.cfg
        tokens = batch["tokens"]                 # (B, L)
        B, L = tokens.shape
        mem, mem_flag = self._memory(params, batch, ctx)
        x = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
        if cfg.is_encoder_decoder:
            x = x + sinusoid_pos(positions, cfg.d_model).astype(x.dtype)
        x, _, flag, aux = run_stack(
            x, params["segments"], self.plan, cfg, ctx, positions,
            "full", None, None, mem, remat=True)
        x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits, f_head = self._head(params, x, ctx)
        flag = or_flags(flag, f_head, mem_flag)

        mtp_logits = None
        if cfg.mtp_depth and "mtp" in params:
            mtp_logits, f_mtp = self._mtp(params, x, tokens, ctx, positions)
            flag = or_flags(flag, f_mtp)
        return ForwardOut(
            logits=logits, flag=flag, aux_loss=aux, mtp_logits=mtp_logits)

    def _head(self, params, x, ctx):
        cfg = self.cfg
        w = (
            params["embed"].T.astype(x.dtype)
            if cfg.tie_embeddings else params["lm_head"]
        )
        return dense(x, w, ctx, "lm_head", out_dtype=jnp.float32)

    def _mtp(self, params, h, tokens, ctx, positions):
        """DeepSeek-V3 multi-token prediction head (depth 1)."""
        cfg = self.cfg
        emb_next = params["embed"][jnp.roll(tokens, -1, axis=1)]
        comb = jnp.concatenate(
            [norm(h, params["mtp"]["norm"], "rmsnorm", cfg.norm_eps),
             emb_next], axis=-1)
        hm, f1 = dense(comb, params["mtp"]["proj"], ctx, "mlp_up",
                       tag="mtp.proj")
        hm, _, f2, _ = apply_layer(
            hm, params["mtp"]["layer"], layer_tags(cfg)[-1], cfg, ctx,
            positions, "full", None, None, None)
        logits, f3 = self._head(params, hm, ctx)
        return logits, or_flags(f1, f2, f3)

    # -------------------------------------------------- prefix sharing
    @property
    def supports_prefix_sharing(self) -> bool:
        """Prefix KV sharing is sound only when a token's cached state is
        a pure function of the token prefix: SSM layers carry recurrent
        state outside the block pool, and encoder-decoder / vision stacks
        condition every position on per-request memory, so identical
        prompt tokens do NOT imply identical cache content there."""
        cfg = self.cfg
        if cfg.is_encoder_decoder or cfg.vision_dim or cfg.cross_attn_every:
            return False
        return not any(t.startswith("mamba") for t in layer_tags(cfg))

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill resumes a prompt mid-sequence from resident
        cache state.  Attention can: KV at positions < start is exactly
        what a later chunk needs.  SSM layers cannot — the recurrence
        state after ``start`` tokens is not re-enterable through the
        prefill path — and encoder-decoder / vision stacks would redo
        their per-request memory every chunk, so both are gated off.
        (Same condition as prefix sharing, for the same underlying
        reason: resident state must be a pure, resumable function of the
        token prefix.)"""
        return self.supports_prefix_sharing

    def protection_plan(self, hw=None, policy=None, *,
                        phase: str = "serve", n_tokens: int = 1,
                        dtype_bytes: int = 2, model_parallel: int = 1):
        """Compile this model's ProtectionPlan (core/policy.py): per-site
        intensity-guided selections with the explicit first-layer flag,
        plus the serving fast paths (``for_step``, ``tune_chunk_budget``)
        the engine consults.  ``n_tokens`` sets the representative GEMM M
        dim (batch*seq for full passes; batch/slots for decode);
        ``model_parallel=k`` compiles one shard's post-sharding shapes
        (the per-device plan on a k-wide model axis)."""
        from repro.core.hardware import DEFAULT
        from repro.core.policy import ProtectionPlan

        return ProtectionPlan.for_model(
            self.cfg, hw=hw or DEFAULT, policy=policy, phase=phase,
            n_tokens=n_tokens, dtype_bytes=dtype_bytes,
            model_parallel=model_parallel)

    def audit_coverage(self, phase: str = "mixed", **kw):
        """Static protection-coverage audit (repro.analysis): trace this
        model's prefill/decode to jaxprs, walk every FLOP-carrying
        primitive, and classify each as protected / allowlisted /
        known-unprotected / UNPROTECTED.  Returns an ``AuditReport``."""
        from repro.analysis.audit import audit_model

        return audit_model(self, phase=phase, **kw)

    def copy_paged_blocks(self, cache, src, dst):
        """Functional device copy ``pool[dst[i]] <- pool[src[i]]`` on
        every paged attention leaf — the COW payload move.  Walks the
        segment plan so per-slot leaves (mamba state, cross KV) are never
        touched even if their leading dims collide with the pool's."""
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        out = []
        for seg, segc in zip(self.plan, cache):
            nc = {}
            for q, tag in enumerate(seg.unit):
                mixer = tag.split(":")[0]
                lc = dict(segc[f"pos{q}"])
                if mixer in ("attn", "mla"):
                    lc["attn"] = {
                        k: leaf.at[:, dst].set(leaf[:, src])
                        for k, leaf in lc["attn"].items()
                    }
                nc[f"pos{q}"] = lc
            out.append(nc)
        return out

    # -------------------------------------------------- prefill / decode
    def prefill(self, params, batch, cache, ctx: LayerCtx,
                slots=None, lengths=None, block_tables=None,
                prefix_lens=None):
        """Prefill the cache from ``batch["tokens"]`` (B, L).

        Default path: cache is B-deep, rows map 1:1 to the batch, logits
        come from the last token of each row.

        Continuous-batching path (``slots``/``lengths`` given): cache is
        engine-deep, tokens are an admission batch padded to a common L,
        ``slots`` (A,) names the cache rows to fill and ``lengths`` (A,)
        the true prompt lengths.  Attention/SSM recurrences are masked at
        the per-row length and logits are gathered at the last *valid*
        token of each row.

        Paged path (``block_tables`` (A, W) additionally given): the
        cache is a block pool (init_paged_cache) and attention KV
        scatters via the tables instead of dense rows.

        Mid-sequence path (``prefix_lens`` (A,) additionally given, paged
        OR dense): tokens hold only each row's tail — the unshared suffix
        under prefix sharing, or one resumable chunk under the chunked-
        prefill scheduler — and ``lengths`` its valid token count; row a's
        first token sits at logical position ``prefix_lens[a]`` (0 for
        rows starting from scratch).  Rotary offsets, causal masks, and
        cache scatter targets are all computed from the true logical
        position — the prefix KV already resident in the cache is what
        the tail attends to."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, L = tokens.shape
        mem, mem_flag = self._memory(params, batch, ctx)
        x = params["embed"][tokens]
        positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
        if prefix_lens is not None:
            positions = prefix_lens[:, None].astype(jnp.int32) + positions
        if cfg.is_encoder_decoder:
            x = x + sinusoid_pos(positions, cfg.d_model).astype(x.dtype)
        x, new_cache, flag, _ = run_stack(
            x, params["segments"], self.plan, cfg, ctx, positions,
            "prefill", cache, None, mem, slots=slots, lengths=lengths,
            tables=block_tables, prefix_lens=prefix_lens)
        x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        if lengths is not None:
            last = x[jnp.arange(B), jnp.maximum(lengths - 1, 0)][:, None]
        else:
            last = x[:, -1:, :]
        logits, f_head = self._head(params, last, ctx)
        return logits, new_cache, or_flags(flag, f_head, mem_flag)

    def decode(self, params, token, cache, pos, ctx: LayerCtx,
               block_tables=None):
        """token: (B, 1) int32; pos: scalar int32 OR (B,) int32 per-slot
        position vector.  With a vector, each batch row writes its new KV
        at its own cursor and attends its own prefix — the contract the
        continuous-batching engine relies on for mixed-length traffic.
        ``block_tables`` (B, W): paged cache — each row's KV entry lands
        at ``tables[b, pos[b] // block_size]`` in the block pool."""
        cfg = self.cfg
        B = token.shape[0]
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        x = params["embed"][token]
        if cfg.is_encoder_decoder:
            positions = pos[:, None]
            x = x + sinusoid_pos(positions, cfg.d_model).astype(x.dtype)
        x, new_cache, flag, _ = run_stack(
            x, params["segments"], self.plan, cfg, ctx, None,
            "decode", cache, pos, None, tables=block_tables)
        x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits, f_head = self._head(params, x, ctx)
        return logits, new_cache, or_flags(flag, f_head)

    def verify(self, params, tokens, cache, pos, ctx: LayerCtx, valid,
               block_tables=None):
        """Speculative-decoding batched verify: score K+1 positions per
        slot in ONE call.  tokens: (B, T) int32 — row b holds its last
        committed token followed by its (padded) draft window; pos: (B,)
        per-slot cursors; ``valid`` (B,) the per-row usable window size
        (``K_slot + 1``; padded rows beyond it neither write cache nor
        emit — their logits are discarded host-side).  Row b's token t
        sits at logical position ``pos[b] + t``; its k/v land at that
        cache position and logits[b, t] predicts position
        ``pos[b] + t + 1``.  Returns ALL T logits (B, T, V) — the host
        acceptance loop compares them against the drafts.  Attention-
        only stacks (supports_chunked_prefill); rejected-draft KV above
        the accepted cursor is dead weight — masked by per-query lengths
        and overwritten before any later query can attend it."""
        cfg = self.cfg
        B, T = tokens.shape
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        x = params["embed"][tokens]
        x, new_cache, flag, _ = run_stack(
            x, params["segments"], self.plan, cfg, ctx, None,
            "verify", cache, pos, None, lengths=valid,
            tables=block_tables)
        x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        logits, f_head = self._head(params, x, ctx)
        return logits, new_cache, or_flags(flag, f_head)


@functools.lru_cache(maxsize=64)
def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
