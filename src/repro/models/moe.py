"""Mixture-of-Experts FFN: top-k routing, sort-based grouped-GEMM dispatch
(capacity-bounded, EP-shardable), optional shared experts.

Dispatch is the production pattern: tokens are argsorted by expert id,
packed into an (E, C, D) buffer (C = capacity), the expert GEMMs run as one
batched einsum (expert dim shardable over the mesh => expert parallelism;
the scatter/gather become all-to-alls under GSPMD), and outputs are
combined back with routing weights.  Tokens over capacity are dropped
(standard switch-style), contributing only their residual path.

Expert GEMMs are ABFT-protected per expert via vmap — each expert's GEMM is
its own "linear layer" in the paper's sense, with its own arithmetic
intensity (thin per-expert GEMMs at low batch are exactly the
bandwidth-bound case where block-level ABFT wins; DESIGN.md §4).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import LayerCtx, constrain, dense, mlp, or_flags

F32 = jnp.float32


def _init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (scale * jax.random.normal(key, shape, F32)).astype(dtype)


def init_moe(cfg: ModelConfig, key, dtype) -> dict:
    E, D, Fd = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (D, E), dtype=dtype),
        "w_up": _init(ks[1], (E, D, Fd), dtype=dtype),
        "w_gate": _init(ks[2], (E, D, Fd), dtype=dtype),
        "w_down": _init(ks[3], (E, Fd, D), dtype=dtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "up": _init(sk[0], (D, Fs), dtype=dtype),
            "gate": _init(sk[1], (D, Fs), dtype=dtype),
            "down": _init(sk[2], (Fs, D), dtype=dtype),
        }
    return p


def _batched_dense(x_e, w_e, ctx: LayerCtx, site: str, tag=None):
    """Per-expert protected GEMM: x_e (E, C, D) @ w_e (E, D, F)."""
    y, flags = jax.vmap(
        lambda xb, wb: dense(xb, wb, ctx, site, tag=tag))(x_e, w_e)
    return y, jnp.any(flags)


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(
        math.ceil(
            n_tokens * cfg.experts_per_token / cfg.n_experts
            * cfg.capacity_factor))
    # round to a lane-friendly multiple, bounded by the token count
    c = max(8, -(-c // 8) * 8)
    return min(c, n_tokens)


def moe_forward(x, p, cfg: ModelConfig, ctx: LayerCtx):
    """x: (B, L, D) -> (B, L, D).  Returns (y, flag, aux_loss).

    Group-local dispatch: tokens are split into G = dp_size groups aligned
    with the data-parallel shards; each group sorts/scatters its own tokens
    locally (small argsort, local scatter), the (G, E, C, D) buffer is
    sharded [G->data, E->model], and the group->expert resharding is the
    all-to-all GSPMD emits.  Keeps every dispatch intermediate sharded —
    a global sort/scatter would be replicated per device (DESIGN.md §5).
    """
    Bsz, L, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = Bsz * L
    G = ctx.hints.dp_size if ctx.hints else 1
    if T % G or G <= 0:
        G = 1
    Tl = T // G
    C = capacity(cfg, Tl)
    xf = x.reshape(G, Tl, D)
    xf = constrain(ctx, xf, ctx.hints.dp, None, None) if ctx.hints else xf

    # --- routing (router GEMM is protected; softmax in f32)
    logits, f_router = dense(xf, p["router"], ctx, "router",
                             out_dtype=jnp.float32, tag="moe.router")
    probs = jax.nn.softmax(logits.astype(F32), axis=-1)       # (G, Tl, E)
    topk_w, topk_i = jax.lax.top_k(probs, K)                  # (G, Tl, K)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)

    # --- load-balancing aux loss (switch-style, global means)
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_i, E, dtype=F32), axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce) / K

    # --- group-local sort-based dispatch into (E, C, D) buffers
    def dispatch(xg, ig):
        flat_e = ig.reshape(-1)                               # (Tl*K,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        pos_in_e = (
            jnp.arange(Tl * K, dtype=jnp.int32)
            - jnp.searchsorted(
                sorted_e, sorted_e, side="left").astype(jnp.int32))
        keep = pos_in_e < C
        slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
        tok = order // K
        buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xg[tok])
        return buf[:-1].reshape(E, C, D), slot, tok, keep, order

    buf, slot, tok, keep, order = jax.vmap(dispatch)(xf, topk_i)
    e_ax = "model" if (ctx.hints and ctx.hints.moe_mode == "ep") else None
    if ctx.hints is not None:
        buf = constrain(ctx, buf, ctx.hints.dp, e_ax, None, None)

    # --- expert GEMMs (SwiGLU) per (group, expert); E shardable over model
    def expert_gemm(b, w, site, tag):
        return jax.vmap(
            lambda bg: _batched_dense(bg, w, ctx, site, tag=tag))(b)

    up, f1 = expert_gemm(buf, p["w_up"], "expert_up", "moe.expert_up")
    gate, f2 = expert_gemm(buf, p["w_gate"], "expert_up", "moe.expert_up")
    h = jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up
    out_buf, f3 = expert_gemm(h, p["w_down"], "expert_down",
                              "moe.expert_down")
    if ctx.hints is not None:
        out_buf = constrain(
            ctx, out_buf, ctx.hints.dp, e_ax, None, None)

    # --- group-local combine
    def combine(ob, sl, tk, kp, od, wk):
        flat_out = ob.reshape(E * C, D)
        gathered = flat_out[jnp.minimum(sl, E * C - 1)]       # (Tl*K, D)
        w_sorted = wk.reshape(-1)[od]
        contrib = gathered.astype(F32) * (
            w_sorted * kp.astype(F32))[:, None]
        return jnp.zeros((Tl, D), F32).at[tk].add(contrib)

    y = jax.vmap(combine)(out_buf, slot, tok, keep, order, topk_w)
    y = constrain(ctx, y, ctx.hints.dp, None, None) if ctx.hints else y
    y = y.astype(x.dtype)

    flag = or_flags(f_router, jnp.any(f1), jnp.any(f2), jnp.any(f3))

    # --- shared experts (dense path, always on)
    if cfg.n_shared_experts:
        ys, fs = mlp(xf, p["shared"], ctx, act="silu",
                     tags=("moe.shared_up", "moe.shared_down"))
        y = y + ys
        flag = or_flags(flag, fs)

    return y.reshape(Bsz, L, D), flag, aux
