"""Paged KV-cache subsystem: block-table memory manager for the
continuous-batching engine.

Why paging (ISSUE 2 / ROADMAP "Paged KV cache"): the paper's deployment
scenario (§6) is latency-sensitive serving where decode is memory-bandwidth
bound, so KV-cache footprint directly gates batch size — and batch size is
what the intensity-guided selector's decode-side arithmetic-intensity
predictions key on.  With dense per-slot rows every request pays
``max_len`` memory; with a vLLM-style block pool, long and short requests
share a fixed set of fixed-size blocks and the sustainable slot count rises
to what the *actual* traffic needs.

Block-table layout
------------------
The device-side cache is a **pool**: per layer, the KV tensor's leading
``(slots, max_len)`` dims are replaced by ``(num_blocks, block_size)``:

    GQA:    k/v    (num_blocks, block_size, KV_heads, head_dim)
    MLA:    latent (num_blocks, block_size, kv_lora + rope)
    mamba:  conv/SSD state stays per-slot — it is O(1) per request (that
            is the whole point of SSMs), i.e. every slot owns exactly one
            implicit, permanently-resident block; no table indirection is
            needed or useful.

The host-side ``BlockPool`` owns the free list and one **block table per
slot** — a row of physical block ids, padded with an out-of-range
``SENTINEL`` (== num_blocks).  All layers share the SAME logical table;
each layer indexes its own physical pool with it (the vLLM layout).  A
token at logical position ``t`` of slot ``s`` lives at

    pool[ table[s, t // block_size], t % block_size ]

Device-side access is sentinel-safe by construction:

  * scatters use ``.at[...].set(mode='drop')`` — writes routed to the
    sentinel (padding tokens, inactive slots, freed rows) vanish;
  * gathers use ``take(mode='fill', fill_value=0)`` — sentinel blocks read
    as zeros and are masked by per-row lengths before the softmax, exactly
    like dense padding.

Prefix sharing, refcounts, and copy-on-write
--------------------------------------------
Templated traffic (system prompts, few-shot headers) makes many requests
open with the *same* tokens, and identical tokens at identical positions
produce identical KV — so their leading table entries can point at the
SAME physical blocks.  Three pieces make that safe:

  * **Refcounts.**  Every physical block carries a reference count: 1 when
    drawn from the free list, +1 per additional table entry that aliases
    it (``try_admit_prefix``), -1 when a referencing slot releases it.
    ``free_slot`` returns a block to the free list only when its LAST
    reference drops — evicting one sharer (hard fault, ``oom:kv_blocks``
    growth failure) can never free or scribble on blocks a live request
    still references.

  * **Content-hash index.**  ``PrefixIndex`` maps hash *chains* over fully
    cached blocks (key_i = H(key_{i-1}, tokens of block i)) to physical
    block ids, plus the partial tail block of each registered prompt.
    Lookups re-verify the stored tokens, so a hash collision degrades to
    "no match", never to silent cross-request corruption.  Entries are
    registered only after a prompt's prefill has been accepted (clean ABFT
    flag) and are purged the moment their block is physically freed.

  * **Copy-on-write.**  Blocks are immutable once shared *except* through
    COW: when a slot must write into a block another slot references —
    the last, partial block of a shared prefix, which the new request's
    suffix continues into — ``try_cow`` redirects the slot's table entry
    to a fresh block and the engine device-copies the payload before any
    jitted step runs.  Full shared blocks are never written again (decode
    cursors sit past the prompt), so sharing full blocks needs no copy.

Invariants (enforced by the property tests):

  * ``blocks_free + blocks_used == num_blocks`` at every point;
  * ``refcount[b] ==`` number of table entries naming ``b``; a block is
    on the free list iff its refcount is 0;
  * alloc -> share -> evict round trips in any order never double-free or
    leak a block.

Interaction with ABFT recovery snapshots
----------------------------------------
The engine's detect->recompute loop snapshots the *device* cache by simply
keeping the pre-step pytree alive (functional update).  That remains
sufficient under paging because the pool update is functional too — a
retry re-scatters into the held ``prev_cache`` pool.  The one new
invariant: the **host** block tables AND refcounts must not change between
a faulty attempt and its clean retry, so the engine performs all
allocation / sharing / COW (including the COW device copies, which are
plain data movement, not ABFT-protected GEMMs) strictly *before* the
jitted step and all frees / index registrations strictly *after* the flag
has been read back.  Hard-fault eviction then drops the victim slots'
references; blocks whose refcount reaches zero return to the free list
and their index entries are purged, while blocks a surviving sharer still
references stay resident (covered by the refcount lifecycle tests).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class PoolExhausted(Exception):
    """Raised by the strict alloc API when the free list cannot cover a
    request.  The engine uses the non-throwing ``try_*`` variants and
    records an ``error`` on the request instead."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` cache entries."""
    return max(0, -(-int(n_tokens) // block_size))


@dataclasses.dataclass
class BlockPool:
    """Host-side free-list allocator + per-slot block tables.

    ``num_blocks`` physical blocks of ``block_size`` tokens are shared by
    ``slots`` logical sequences.  ``table_width`` bounds the per-slot
    logical length at ``table_width * block_size`` tokens (the engine sets
    it to cover ``max_len``).  Freed blocks go to the head of the free
    list (LIFO) so reuse after eviction is immediate and testable.
    """

    num_blocks: int
    block_size: int
    slots: int
    table_width: int

    def __post_init__(self):
        assert self.num_blocks >= 1 and self.block_size >= 1
        self.sentinel = self.num_blocks
        self.reset()

    # ------------------------------------------------------------ queries
    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_used(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def blocks_shared(self) -> int:
        """Physical blocks currently referenced by more than one slot."""
        return int((self.refcount > 1).sum())

    def ref_of(self, block: int) -> int:
        return int(self.refcount[block])

    def slot_blocks(self, slot: int) -> int:
        return int(self._used[slot])

    def capacity_tokens(self, slot: int) -> int:
        """Tokens the slot's current allocation can hold."""
        return self.slot_blocks(slot) * self.block_size

    def can_admit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.block_size) <= self.blocks_free

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Drop every allocation (fresh engine / full eviction)."""
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._used = np.zeros((self.slots,), np.int32)
        self.refcount = np.zeros((self.num_blocks,), np.int32)
        self.tables = np.full(
            (self.slots, self.table_width), self.num_blocks, np.int32)
        self.sentinel = self.num_blocks

    def try_alloc(self, slot: int, n_tokens: int) -> bool:
        """Allocate blocks so ``slot`` can hold ``n_tokens`` tokens
        (fresh sequence: the slot must currently own no blocks).  All-or-
        nothing: on exhaustion nothing is allocated and False returns."""
        assert self._used[slot] == 0, f"slot {slot} already allocated"
        return self.try_grow(slot, n_tokens)

    def alloc(self, slot: int, n_tokens: int) -> None:
        if not self.try_alloc(slot, n_tokens):
            raise PoolExhausted(
                f"need {blocks_for(n_tokens, self.block_size)} blocks, "
                f"{self.blocks_free} free")

    def try_grow(self, slot: int, n_tokens: int) -> bool:
        """Ensure ``slot`` can hold ``n_tokens`` tokens, allocating the
        delta (decode crossing a block boundary).  All-or-nothing."""
        need = blocks_for(n_tokens, self.block_size)
        have = int(self._used[slot])
        if need <= have:
            return True
        if need > self.table_width or need - have > len(self._free):
            return False
        for b in range(have, need):
            blk = self._free.pop()
            self.tables[slot, b] = blk
            self.refcount[blk] = 1
        self._used[slot] = need
        return True

    def grow(self, slot: int, n_tokens: int) -> None:
        if not self.try_grow(slot, n_tokens):
            raise PoolExhausted(
                f"slot {slot}: grow to {n_tokens} tokens failed "
                f"({self.blocks_free} blocks free)")

    def try_admit_prefix(self, slot: int, n_tokens: int,
                         shared_ids) -> bool:
        """Admission with a shared prefix: the slot's leading table
        entries alias the given physical blocks (refcount +1 each, NO
        free-list draw), the remaining ``blocks_for(n_tokens)`` blocks
        come fresh from the free list.  All-or-nothing: on exhaustion
        nothing is allocated or referenced and False returns."""
        assert self._used[slot] == 0, f"slot {slot} already allocated"
        need = blocks_for(n_tokens, self.block_size)
        k = len(shared_ids)
        assert k <= need, "shared prefix longer than the prompt"
        if need > self.table_width or need - k > len(self._free):
            return False
        for i, blk in enumerate(shared_ids):
            assert self.refcount[blk] >= 1, f"sharing a free block {blk}"
            self.tables[slot, i] = int(blk)
            self.refcount[blk] += 1
        for i in range(k, need):
            blk = self._free.pop()
            self.tables[slot, i] = blk
            self.refcount[blk] = 1
        self._used[slot] = need
        return True

    def try_cow(self, slot: int, idx: int):
        """Copy-on-write: if the slot's table entry ``idx`` aliases a
        block another slot also references, redirect it to a fresh block.
        Returns ``(src, dst)`` for the caller's device copy, ``None`` when
        the block is exclusively owned (no copy needed).  Raises
        ``PoolExhausted`` when a copy is needed but the free list is empty
        — callers budget the COW block into their all-or-nothing check."""
        assert 0 <= idx < int(self._used[slot])
        src = int(self.tables[slot, idx])
        if self.refcount[src] <= 1:
            return None
        if not self._free:
            raise PoolExhausted(f"COW for slot {slot} needs a free block")
        dst = self._free.pop()
        self.refcount[src] -= 1
        self.refcount[dst] = 1
        self.tables[slot, idx] = dst
        return src, dst

    def free_slot(self, slot: int) -> list:
        """Drop the slot's references; blocks whose refcount reaches zero
        return to the free list.  Returns the list of *physically freed*
        block ids (so callers can purge content-index entries).
        Idempotent (freeing an empty slot is a no-op)."""
        n = int(self._used[slot])
        freed = []
        for b in range(n - 1, -1, -1):
            blk = int(self.tables[slot, b])
            self.refcount[blk] -= 1
            assert self.refcount[blk] >= 0, f"double free of block {blk}"
            if self.refcount[blk] == 0:
                self._free.append(blk)
                freed.append(blk)
        self.tables[slot, :] = self.num_blocks
        self._used[slot] = 0
        return freed

    # ------------------------------------------------------------ invariants
    def check_invariants(self) -> None:
        """Assert the refcount/free-list bookkeeping is exactly consistent
        with the tables (used by the lifecycle property tests)."""
        assert len(self._free) == len(set(self._free)), "free-list dup"
        refs = np.zeros((self.num_blocks,), np.int32)
        for s in range(self.slots):
            for b in range(int(self._used[s])):
                refs[int(self.tables[s, b])] += 1
        assert (refs == self.refcount).all(), "refcount != table references"
        on_free = np.zeros((self.num_blocks,), bool)
        on_free[self._free] = True
        assert ((self.refcount == 0) == on_free).all(), (
            "a block is on the free list iff its refcount is 0")
        assert self.blocks_free + self.blocks_used == self.num_blocks

    # ------------------------------------------------------------ device view
    def device_tables(self, rows=None) -> jnp.ndarray:
        """Block tables as an int32 device array — all slots, or the given
        row indices (admission batches pass their slot ids)."""
        t = self.tables if rows is None else self.tables[np.asarray(rows)]
        return jnp.asarray(t, jnp.int32)


# ================================================================ prefix index

_ROOT = "prefix-index-root"


@dataclasses.dataclass
class PrefixMatch:
    """Result of a prefix lookup: the physical blocks the new slot should
    alias (full blocks, plus at most one partial tail that the caller must
    COW before writing its suffix into it) and the matched token count."""

    shared_ids: list
    match_len: int
    partial: bool          # last entry of shared_ids is a partial block

    @property
    def full_blocks(self) -> int:
        return len(self.shared_ids) - (1 if self.partial else 0)


class PrefixIndex:
    """Content-hash index over cached prompt blocks.

    Full blocks are keyed by a hash *chain*: ``key_i = hash((key_{i-1},
    tokens_i))`` where ``tokens_i`` is the i-th block's token tuple — so a
    block only matches behind the exact prefix that produced its KV.  Each
    chain node also carries the partial tail blocks registered under it
    (a prompt whose length is not a block multiple).  Every entry stores
    its token tuple and lookups re-verify it: a Python-hash collision
    degrades to a miss, never to silent sharing of wrong content.

    Entries are added only for prompts whose prefill passed the ABFT check
    and are purged when their physical block is freed (refcount zero), so
    the index never names a block whose payload is stale or recycled.
    """

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self._full: dict = {}       # chain key -> (block_id, tokens)
        self._partial: dict = {}    # chain key -> [(block_id, tokens), ...]
        self._by_block: dict = {}   # block_id -> set of (kind, key)

    def _note(self, block: int, kind: str, key) -> None:
        self._by_block.setdefault(int(block), set()).add((kind, key))

    @staticmethod
    def _chain(parent, tokens: tuple):
        return hash((parent, tokens))

    # ------------------------------------------------------------ register
    def add(self, prompt, table_row) -> None:
        """Register a fully prefilled prompt: one chain entry per full
        block, plus the partial tail (if any) under its prefix's key.
        Re-registering existing content is a no-op (first writer wins —
        later identical prompts were sharers and alias the same ids)."""
        toks = tuple(int(t) for t in prompt)
        bs = self.block_size
        key = _ROOT
        for i in range(len(toks) // bs):
            blk_toks = toks[i * bs:(i + 1) * bs]
            key = self._chain(key, blk_toks)
            if key not in self._full:
                blk = int(table_row[i])
                self._full[key] = (blk, blk_toks)
                self._note(blk, "full", key)
        rem = len(toks) % bs
        if rem:
            tail = toks[len(toks) - rem:]
            cand = self._partial.setdefault(key, [])
            if not any(t == tail for _, t in cand):
                blk = int(table_row[len(toks) // bs])
                cand.append((blk, tail))
                self._note(blk, "partial", key)

    # ------------------------------------------------------------ lookup
    def match(self, prompt) -> PrefixMatch:
        """Longest cached prefix of ``prompt``, capped at ``len(prompt) -
        1`` tokens so the suffix prefill always has at least one token to
        produce the first sampled logits from."""
        toks = tuple(int(t) for t in prompt)
        bs = self.block_size
        cap = len(toks) - 1
        ids, key, matched = [], _ROOT, 0
        while matched + bs <= cap:
            blk_toks = toks[matched:matched + bs]
            nxt = self._chain(key, blk_toks)
            ent = self._full.get(nxt)
            if ent is None or ent[1] != blk_toks:     # miss or hash clash
                break
            ids.append(ent[0])
            key = nxt
            matched += bs
        # partial tail: reuse the longest common lead of a cached block
        # under this chain — the caller COWs it before writing its suffix.
        best_blk, best_m = None, 0
        candidates = list(self._partial.get(key, []))
        if matched + bs <= len(toks):
            # a cached FULL block can seed a partial share too: the cap
            # above may have stopped the chain one token short of it
            # (prompt identical to a block-aligned cached prompt)
            ent = self._full.get(self._chain(key, toks[matched:matched + bs]))
            if ent is not None:
                candidates.append((ent[0], ent[1]))
        for blk, cand_toks in candidates:
            m = 0
            lim = min(len(cand_toks), cap - matched)
            while m < lim and cand_toks[m] == toks[matched + m]:
                m += 1
            if m > best_m:
                best_blk, best_m = blk, m
        if best_m > 0:
            ids.append(best_blk)
            return PrefixMatch(ids, matched + best_m, partial=True)
        return PrefixMatch(ids, matched, partial=False)

    # ------------------------------------------------------------ purge
    def purge(self, freed_blocks) -> None:
        """Remove every entry naming a physically freed block."""
        for blk in freed_blocks:
            for kind, key in self._by_block.pop(int(blk), ()):
                if kind == "full":
                    ent = self._full.get(key)
                    if ent is not None and ent[0] == int(blk):
                        del self._full[key]
                else:
                    cand = self._partial.get(key)
                    if cand is not None:
                        cand[:] = [c for c in cand if c[0] != int(blk)]
                        if not cand:
                            del self._partial[key]


# ================================================================ pytrees
# Paged cache initializers, mirroring attention.init_*_cache / mamba's
# init_mamba_cache but with the (slots, max_len) dims replaced by the
# (num_blocks, block_size) pool.  Kept here so the subsystem owns its
# memory layout end to end; models/model.py routes by cache kind.

def init_paged_gqa_cache(cfg: ModelConfig, num_blocks: int,
                         block_size: int, dtype) -> dict:
    from repro.models.attention import eff_counts

    hd = cfg.resolved_head_dim
    _, KVp = eff_counts(cfg)
    return {
        "k": jnp.zeros((num_blocks, block_size, KVp, hd), dtype),
        "v": jnp.zeros((num_blocks, block_size, KVp, hd), dtype),
    }


def init_paged_mla_cache(cfg: ModelConfig, num_blocks: int,
                         block_size: int, dtype) -> dict:
    return {
        "latent": jnp.zeros(
            (num_blocks, block_size,
             cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype),
    }


def init_paged_mamba_cache(cfg: ModelConfig, slots: int, dtype) -> dict:
    """Mamba state under paging == dense: constant-size per slot (one
    implicit resident block per slot; see module docstring)."""
    from repro.models.mamba import init_mamba_cache

    return init_mamba_cache(cfg, slots, dtype)


# ================================================================ device ops
# Sentinel-safe scatter/gather between logical (row, position) coordinates
# and the physical pool.  Shared by the GQA and MLA paged paths.

def paged_scatter_prefill(pool, new, tables, lengths, starts=None):
    """Write an admission batch into the pool.

    pool: (NB, BS, ...); new: (A, L, ...) padded to a common L;
    tables: (A, W) int32 rows (sentinel-padded); lengths: (A,) valid
    token counts of ``new``.  Positions >= lengths[a] are routed to the
    sentinel and dropped.

    ``starts`` (A,) int32: logical position of each row's FIRST token —
    the prefix-sharing suffix prefill writes ``new[a, t]`` at logical
    position ``starts[a] + t`` (the shared prefix already lives in the
    pool).  ``None`` keeps the from-zero fast path bit-for-bit."""
    nb, bs = pool.shape[0], pool.shape[1]
    A, L = new.shape[0], new.shape[1]
    t = jnp.arange(L, dtype=jnp.int32)
    valid = t[None, :] < lengths[:, None]
    if starts is None:
        blk = jnp.take(tables, t // bs, axis=1)        # (A, L)
        off = jnp.broadcast_to(t % bs, (A, L))
    else:
        logical = starts[:, None].astype(jnp.int32) + t[None, :]
        blk = jnp.take_along_axis(tables, logical // bs, axis=1,
                                  mode="clip")
        off = logical % bs
    blk = jnp.where(valid, blk, nb)                    # force-drop padding
    return pool.at[blk, off].set(new.astype(pool.dtype), mode="drop")


def paged_scatter_decode(pool, new, tables, pos):
    """Write one new entry per slot at its own cursor.

    pool: (NB, BS, ...); new: (B, ...); tables: (B, W); pos: (B,) int32.
    Inactive/freed slots carry sentinel tables, so their writes drop —
    no activity mask is needed (the table IS the guard)."""
    bs = pool.shape[1]
    blk = jnp.take_along_axis(
        tables, (pos[:, None] // bs).astype(jnp.int32), axis=1)[:, 0]
    off = pos % bs
    return pool.at[blk, off].set(new.astype(pool.dtype), mode="drop")


def paged_gather(pool, tables):
    """Materialize per-slot contiguous KV from the pool.

    pool: (NB, BS, ...); tables: (B, W) -> (B, W*BS, ...).  Sentinel
    blocks read as zeros; callers mask by per-row length before softmax.
    (The Pallas paged flash_decode skips this materialization and indexes
    the pool directly via the block table — this is the XLA reference
    path.)"""
    bs = pool.shape[1]
    B, W = tables.shape
    g = jnp.take(pool, tables, axis=0, mode="fill", fill_value=0)
    return g.reshape((B, W * bs) + pool.shape[2:])


def pytree_bytes(tree) -> int:
    """Total bytes of every array leaf (cache_stats accounting)."""
    import jax

    return sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "dtype")
    )
