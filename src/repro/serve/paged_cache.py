"""Paged KV-cache subsystem: block-table memory manager for the
continuous-batching engine.

Why paging (ISSUE 2 / ROADMAP "Paged KV cache"): the paper's deployment
scenario (§6) is latency-sensitive serving where decode is memory-bandwidth
bound, so KV-cache footprint directly gates batch size — and batch size is
what the intensity-guided selector's decode-side arithmetic-intensity
predictions key on.  With dense per-slot rows every request pays
``max_len`` memory; with a vLLM-style block pool, long and short requests
share a fixed set of fixed-size blocks and the sustainable slot count rises
to what the *actual* traffic needs.

Block-table layout
------------------
The device-side cache is a **pool**: per layer, the KV tensor's leading
``(slots, max_len)`` dims are replaced by ``(num_blocks, block_size)``:

    GQA:    k/v    (num_blocks, block_size, KV_heads, head_dim)
    MLA:    latent (num_blocks, block_size, kv_lora + rope)
    mamba:  conv/SSD state stays per-slot — it is O(1) per request (that
            is the whole point of SSMs), i.e. every slot owns exactly one
            implicit, permanently-resident block; no table indirection is
            needed or useful.

The host-side ``BlockPool`` owns the free list and one **block table per
slot** — a row of physical block ids, padded with an out-of-range
``SENTINEL`` (== num_blocks).  All layers share the SAME logical table;
each layer indexes its own physical pool with it (the vLLM layout).  A
token at logical position ``t`` of slot ``s`` lives at

    pool[ table[s, t // block_size], t % block_size ]

Device-side access is sentinel-safe by construction:

  * scatters use ``.at[...].set(mode='drop')`` — writes routed to the
    sentinel (padding tokens, inactive slots, freed rows) vanish;
  * gathers use ``take(mode='fill', fill_value=0)`` — sentinel blocks read
    as zeros and are masked by per-row lengths before the softmax, exactly
    like dense padding.

Interaction with ABFT recovery snapshots
----------------------------------------
The engine's detect->recompute loop snapshots the *device* cache by simply
keeping the pre-step pytree alive (functional update).  That remains
sufficient under paging because the pool update is functional too — a
retry re-scatters into the held ``prev_cache`` pool.  The one new
invariant: the **host** block tables must not change between a faulty
attempt and its clean retry, so the engine performs all allocation /
growth strictly *before* the jitted step and all frees strictly *after*
the flag has been read back.  Hard-fault eviction then returns the victim
slots' blocks to the free list; the next admission reuses them (covered by
the free-list reuse tests).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class PoolExhausted(Exception):
    """Raised by the strict alloc API when the free list cannot cover a
    request.  The engine uses the non-throwing ``try_*`` variants and
    records an ``error`` on the request instead."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` cache entries."""
    return max(0, -(-int(n_tokens) // block_size))


@dataclasses.dataclass
class BlockPool:
    """Host-side free-list allocator + per-slot block tables.

    ``num_blocks`` physical blocks of ``block_size`` tokens are shared by
    ``slots`` logical sequences.  ``table_width`` bounds the per-slot
    logical length at ``table_width * block_size`` tokens (the engine sets
    it to cover ``max_len``).  Freed blocks go to the head of the free
    list (LIFO) so reuse after eviction is immediate and testable.
    """

    num_blocks: int
    block_size: int
    slots: int
    table_width: int

    def __post_init__(self):
        assert self.num_blocks >= 1 and self.block_size >= 1
        self.sentinel = self.num_blocks
        self.reset()

    # ------------------------------------------------------------ queries
    @property
    def blocks_free(self) -> int:
        return len(self._free)

    @property
    def blocks_used(self) -> int:
        return self.num_blocks - len(self._free)

    def slot_blocks(self, slot: int) -> int:
        return int(self._used[slot])

    def capacity_tokens(self, slot: int) -> int:
        """Tokens the slot's current allocation can hold."""
        return self.slot_blocks(slot) * self.block_size

    def can_admit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.block_size) <= self.blocks_free

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Drop every allocation (fresh engine / full eviction)."""
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._used = np.zeros((self.slots,), np.int32)
        self.tables = np.full(
            (self.slots, self.table_width), self.num_blocks, np.int32)
        self.sentinel = self.num_blocks

    def try_alloc(self, slot: int, n_tokens: int) -> bool:
        """Allocate blocks so ``slot`` can hold ``n_tokens`` tokens
        (fresh sequence: the slot must currently own no blocks).  All-or-
        nothing: on exhaustion nothing is allocated and False returns."""
        assert self._used[slot] == 0, f"slot {slot} already allocated"
        return self.try_grow(slot, n_tokens)

    def alloc(self, slot: int, n_tokens: int) -> None:
        if not self.try_alloc(slot, n_tokens):
            raise PoolExhausted(
                f"need {blocks_for(n_tokens, self.block_size)} blocks, "
                f"{self.blocks_free} free")

    def try_grow(self, slot: int, n_tokens: int) -> bool:
        """Ensure ``slot`` can hold ``n_tokens`` tokens, allocating the
        delta (decode crossing a block boundary).  All-or-nothing."""
        need = blocks_for(n_tokens, self.block_size)
        have = int(self._used[slot])
        if need <= have:
            return True
        if need > self.table_width or need - have > len(self._free):
            return False
        for b in range(have, need):
            self.tables[slot, b] = self._free.pop()
        self._used[slot] = need
        return True

    def grow(self, slot: int, n_tokens: int) -> None:
        if not self.try_grow(slot, n_tokens):
            raise PoolExhausted(
                f"slot {slot}: grow to {n_tokens} tokens failed "
                f"({self.blocks_free} blocks free)")

    def free_slot(self, slot: int) -> int:
        """Return the slot's blocks to the free list; returns the count.
        Idempotent (freeing an empty slot is a no-op)."""
        n = int(self._used[slot])
        for b in range(n - 1, -1, -1):
            self._free.append(int(self.tables[slot, b]))
        self.tables[slot, :] = self.num_blocks
        self._used[slot] = 0
        return n

    # ------------------------------------------------------------ device view
    def device_tables(self, rows=None) -> jnp.ndarray:
        """Block tables as an int32 device array — all slots, or the given
        row indices (admission batches pass their slot ids)."""
        t = self.tables if rows is None else self.tables[np.asarray(rows)]
        return jnp.asarray(t, jnp.int32)


# ================================================================ pytrees
# Paged cache initializers, mirroring attention.init_*_cache / mamba's
# init_mamba_cache but with the (slots, max_len) dims replaced by the
# (num_blocks, block_size) pool.  Kept here so the subsystem owns its
# memory layout end to end; models/model.py routes by cache kind.

def init_paged_gqa_cache(cfg: ModelConfig, num_blocks: int,
                         block_size: int, dtype) -> dict:
    from repro.models.attention import eff_counts

    hd = cfg.resolved_head_dim
    _, KVp = eff_counts(cfg)
    return {
        "k": jnp.zeros((num_blocks, block_size, KVp, hd), dtype),
        "v": jnp.zeros((num_blocks, block_size, KVp, hd), dtype),
    }


def init_paged_mla_cache(cfg: ModelConfig, num_blocks: int,
                         block_size: int, dtype) -> dict:
    return {
        "latent": jnp.zeros(
            (num_blocks, block_size,
             cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype),
    }


def init_paged_mamba_cache(cfg: ModelConfig, slots: int, dtype) -> dict:
    """Mamba state under paging == dense: constant-size per slot (one
    implicit resident block per slot; see module docstring)."""
    from repro.models.mamba import init_mamba_cache

    return init_mamba_cache(cfg, slots, dtype)


# ================================================================ device ops
# Sentinel-safe scatter/gather between logical (row, position) coordinates
# and the physical pool.  Shared by the GQA and MLA paged paths.

def paged_scatter_prefill(pool, new, tables, lengths):
    """Write an admission batch into the pool.

    pool: (NB, BS, ...); new: (A, L, ...) padded to a common L;
    tables: (A, W) int32 rows (sentinel-padded); lengths: (A,) valid
    prompt lengths.  Positions >= lengths[a] are routed to the sentinel
    and dropped."""
    nb, bs = pool.shape[0], pool.shape[1]
    A, L = new.shape[0], new.shape[1]
    t = jnp.arange(L, dtype=jnp.int32)
    blk = jnp.take(tables, t // bs, axis=1)            # (A, L)
    valid = t[None, :] < lengths[:, None]
    blk = jnp.where(valid, blk, nb)                    # force-drop padding
    off = jnp.broadcast_to(t % bs, (A, L))
    return pool.at[blk, off].set(new.astype(pool.dtype), mode="drop")


def paged_scatter_decode(pool, new, tables, pos):
    """Write one new entry per slot at its own cursor.

    pool: (NB, BS, ...); new: (B, ...); tables: (B, W); pos: (B,) int32.
    Inactive/freed slots carry sentinel tables, so their writes drop —
    no activity mask is needed (the table IS the guard)."""
    bs = pool.shape[1]
    B = new.shape[0]
    blk = jnp.take_along_axis(
        tables, (pos[:, None] // bs).astype(jnp.int32), axis=1)[:, 0]
    off = pos % bs
    return pool.at[blk, off].set(new.astype(pool.dtype), mode="drop")


def paged_gather(pool, tables):
    """Materialize per-slot contiguous KV from the pool.

    pool: (NB, BS, ...); tables: (B, W) -> (B, W*BS, ...).  Sentinel
    blocks read as zeros; callers mask by per-row length before softmax.
    (The Pallas paged flash_decode skips this materialization and indexes
    the pool directly via the block table — this is the XLA reference
    path.)"""
    bs = pool.shape[1]
    B, W = tables.shape
    g = jnp.take(pool, tables, axis=0, mode="fill", fill_value=0)
    return g.reshape((B, W * bs) + pool.shape[2:])


def pytree_bytes(tree) -> int:
    """Total bytes of every array leaf (cache_stats accounting)."""
    import jax

    return sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "dtype")
    )
