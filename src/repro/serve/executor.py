"""Executor layer of the serving engine (executor-hierarchy refactor).

The executor owns the DEVICE residency of a serving engine — params,
cache, and per-slot PRNG keys — and compiles the engine's
``ProtectionPlan`` for the hardware it actually runs on:

``LocalExecutor``
    Single-device (the old monolith's implicit behavior): params/cache
    live wherever jax puts them, ``model_parallel == 1``, and the plan
    sees the model's full GEMM shapes.

``MeshExecutor``
    Tensor-parallel serving over a ``(data=1, model=k)`` device mesh.
    Params are committed with the production sharding rules
    (``distributed/sharding.py::param_specs`` — heads/ffn/vocab over
    the ``model`` axis), the KV cache with ``cache_specs`` (paged block
    pools shard their kv-head dim over ``model`` while the host block
    table stays ONE logical table — per-device KV shards behind one
    logical index), and the jitted runner entry points then run SPMD by
    GSPMD propagation from those committed inputs: no per-call
    ``in_shardings``, no runner changes, no scheduler changes.

    The executor is also where protection becomes HARDWARE-AWARE PER
    SHARD: ``protection_plan`` passes ``model_parallel=k`` down to
    ``ProtectionPlan.for_model``, which divides each GEMM's sharded dim
    (n for column-parallel, k for row-parallel) before computing
    arithmetic intensity — so TP=4 can legitimately select a DIFFERENT
    ABFT scheme than TP=1 for the same layer (smaller per-device GEMMs
    sit lower on the roofline).  That per-shard re-selection is the
    paper's intensity-guided decision re-made for the post-sharding
    shapes, and it is what the sharded equivalence tests pin down.

Stream equality: greedy token streams are byte-identical between
``LocalExecutor`` and ``MeshExecutor`` at any width for bf16 models —
per-device partial GEMMs accumulate in f32 and round to bf16 after the
reduction, so the psum reordering TP introduces is below the output
precision.  (Full-f32 models can differ in the last ulp across widths;
the equivalence suite therefore runs bf16, like production serving.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.mesh import build_mesh, make_hints
from repro.distributed.sharding import (
    cache_specs,
    make_sharding,
    param_specs,
)
from repro.models.model import Model


class LocalExecutor:
    """Single-device executor: owns params/cache/keys, no mesh."""

    mesh = None
    model_parallel = 1

    def __init__(self, model: Model, params, *, dtype, hints=None):
        self.model = model
        self.params = params
        self.dtype = dtype
        self.dtype_bytes = jnp.dtype(dtype).itemsize
        self.hints = hints
        self.cache = None
        self.keys = None

    # ------------------------------------------------------------- state
    def init_dense_cache(self, slots: int, max_len: int) -> None:
        self.cache = self.model.init_cache(slots, max_len, dtype=self.dtype)

    def init_paged_cache(self, slots: int, num_blocks: int,
                         block_size: int) -> None:
        self.cache = self.model.init_paged_cache(
            slots, num_blocks, block_size, dtype=self.dtype)

    def init_keys(self, seed: int, slots: int) -> None:
        # per-slot PRNG key vector: each slot samples from its own stream
        self.keys = jax.random.split(jax.random.PRNGKey(seed), slots)

    # -------------------------------------------------------------- plan
    def protection_plan(self, abft, *, slots: int):
        """Compile the ProtectionPlan for THIS executor's hardware view:
        per-shard GEMM shapes under ``model_parallel``-way TP."""
        return self.model.protection_plan(
            hw=abft.hardware, policy=abft.effective_policy(),
            phase="serve", n_tokens=slots, dtype_bytes=self.dtype_bytes,
            model_parallel=self.model_parallel)


class MeshExecutor(LocalExecutor):
    """Mesh-sharded executor (see module docstring).

    ``mesh``: an int tensor-parallel width (builds a ``(data=1,
    model=k)`` mesh over the first k local devices via the canonical
    ``distributed/mesh.py::build_mesh``) or a prebuilt ``jax.sharding
    .Mesh`` carrying a ``model`` axis."""

    def __init__(self, model: Model, params, *, mesh, dtype, hints=None):
        if isinstance(mesh, int):
            mesh = build_mesh(model=mesh, data=1)
        if "model" not in mesh.axis_names:
            raise ValueError(
                f"MeshExecutor needs a 'model' axis, mesh has "
                f"{mesh.axis_names}")
        self.mesh = mesh
        self.model_parallel = int(mesh.shape["model"])
        if hints is None:
            hints = make_hints(model.cfg, mesh)
        # commit the params with the production sharding rules; the
        # jitted runner entry points pick the layout up by propagation
        specs = param_specs(model.cfg, params, mesh)
        params = jax.device_put(params, make_sharding(mesh, specs))
        super().__init__(model, params, dtype=dtype, hints=hints)

    def _put_cache(self, cache, *, paged: bool, slots: int):
        specs = cache_specs(self.model.cfg, cache, self.mesh, slots,
                            paged=paged)
        return jax.device_put(cache, make_sharding(self.mesh, specs))

    def init_dense_cache(self, slots: int, max_len: int) -> None:
        super().init_dense_cache(slots, max_len)
        self.cache = self._put_cache(self.cache, paged=False, slots=slots)

    def init_paged_cache(self, slots: int, num_blocks: int,
                         block_size: int) -> None:
        super().init_paged_cache(slots, num_blocks, block_size)
        self.cache = self._put_cache(self.cache, paged=True, slots=slots)

    def init_keys(self, seed: int, slots: int) -> None:
        super().init_keys(seed, slots)
        # keys are host-logical state: replicate them so every device
        # samples identically (the sampler's argmax/categorical runs on
        # model-replicated logits rows)
        self.keys = jax.device_put(
            self.keys, NamedSharding(self.mesh, P()))
