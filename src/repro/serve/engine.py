"""Serving engine: continuous-batched decode with ABFT detect->recompute
recovery, built around a **vectorized per-slot position cursor** and an
optional **paged KV cache** (block-table memory manager).

The engine owns a fixed-capacity slot table (the batch dimension of the KV
cache).  Every slot carries its own write cursor ``pos[s]``; the decode
step passes the full ``(slots,)`` cursor vector to ``model.decode`` so each
slot writes its new KV entry at its *own* offset and attends only its own
valid prefix.  This is what makes mixed-length traffic correct: two
requests with different prompt lengths share a batch without ever touching
each other's cache rows (the seed engine collapsed cursors to a scalar
``max(pos)`` and corrupted exactly this case).

Cache kinds
-----------
``cache_kind="dense"`` (default): every slot owns a dense ``(max_len,)``
cache row — one long request makes the whole batch pay max-length memory.

``cache_kind="paged"``: attention KV lives in fixed-size blocks drawn from
a shared pool (serve/paged_cache.py).  Blocks are allocated at admission
(prompt length only), grown one block at a time as decode crosses block
boundaries, and returned to the free list when a request finishes or is
evicted — including hard-fault eviction under ``RecoveryPolicy``.  Pool
exhaustion never crashes: a request that could NEVER fit is rejected with
``error="oom:block_pool"``; one that merely hit transient pressure
(blocks held by in-flight requests) is deferred until decode frees
blocks; a slot whose mid-decode growth cannot be covered is evicted with
``error="oom:kv_blocks"``.
Token streams are identical to the dense engine under greedy decoding
(block-size divides max_len => identical attention shapes); the allocation
is what changes: ``cache_stats()`` reports pool bytes ≪ slots × max_len
when prompt lengths are skewed.

``prefix_sharing=True`` (paged only) adds refcounted prefix sharing with
copy-on-write: admission matches each prompt against a content-hash index
of resident blocks (``PrefixIndex``), aliases the new slot's leading
table entries onto the longest cached prefix (full blocks refcounted; a
partial tail block is COW-copied because the suffix will write into it),
and prefills ONLY the unshared suffix at its true logical positions.
Matches are capped at ``len(prompt) - 1`` tokens so the suffix always
yields the first sampled token's logits.  The index registers prompts
only after their prefill passed the ABFT check, and entries are purged
when blocks are physically freed — so fault-driven eviction of one
sharer never frees or corrupts blocks a live request still references
(refcounts drop; the free list only sees count-zero blocks).  Greedy
streams are byte-identical to the unshared paged engine: identical
tokens at identical logical positions produce bit-identical KV, and the
suffix path's gathered-KV attention masks padding to exact zeros.
Requires ``model.supports_prefix_sharing`` (attention-only stacks —
SSM/cross-attention state is not a pure function of the token prefix).

Chunked-prefill scheduler (``chunk_tokens``)
--------------------------------------------
Unchunked, ``admit()`` runs the WHOLE prompt's prefill synchronously on
the decode path — a 32k prompt stalls every resident decode stream for
one monolithic model call (the ROADMAP's "async admission" item).  With
``chunk_tokens=N`` set, admission only *allocates* (slot, blocks, prefix
plan, COW) and parks the prompt behind a resumable **chunk cursor**;
``step()`` then builds every iteration from the fixed token budget:

  * all resident decode tokens are packed FIRST — every active stream
    advances every step, so a flood of long prompts can never starve a
    resident decode (the scheduler's latency contract);
  * the remaining ``N - n_decode`` tokens are filled with prefill chunks
    drawn FIFO from the cursor queue, each chunk resuming at its prompt's
    logical position (per-chunk rotary offsets, per-row causal
    ``q_offset``, cache scatter at arbitrary starts — the PR-3
    ``prefix_lens`` machinery generalized to both paged AND dense
    caches).

This subsumes async admission without threads: chunking bounds the
prefill work co-scheduled with every decode step, so TTFT/ITL tails
collapse on long-prompt mixes while greedy streams stay byte-identical
to the unchunked engine (same logical positions => bit-identical KV and
logits; the equivalence tests demand it, faults included).  A fault
detected during a chunk retries ONLY that chunk from the pre-chunk
cache; the step's decode call and earlier chunks are never re-executed.
Requires ``model.supports_chunked_prefill`` (attention-only stacks —
SSM recurrence state cannot resume mid-prompt through the prefill path).

Per-step intensity-guided re-selection: the engine compiles a
``ProtectionPlan`` (core/policy.py) for its (model, hardware, serving)
triple at construction; each executed step's ACTUAL token composition
(decode + chunk tokens) goes through the plan's cached
``for_step(decode, prefill)`` fast path — decode-only steps sit deep in
the memory-bound regime (fused block ABFT), mixed steps carrying a
chunk can cross into the compute-bound regime (global ABFT).  The
per-step ``(composition, intensity, scheme)`` decisions are recorded in
``EngineStats.selection_trace``; the jitted calls resolve the scheme
per GEMM shape at trace time, so distinct compositions genuinely execute
distinct schemes (the paper's §5.3 selection re-made at serving time,
per step instead of per static phase).

``chunk_tokens="auto"`` delegates the budget itself to the plan's
roofline autotuner (``plan.tune_chunk_budget``): the smallest per-step
token budget whose mixed-step arithmetic intensity clears the device
CMR (or, when the step geometry cannot reach the CMR, the
maximum-intensity budget under ``max_len``).  The budget re-tunes as
slot occupancy drifts — its floor tracks resident decode tokens so
prefill always progresses — with re-tunes counted in
``EngineStats.chunk_budget_retunes``.

Engine API
----------
``admit(pending)``
    Batched admission: up to ``len(free_slots())`` requests are drawn
    from ``pending`` (IN PLACE — consumed requests are removed), padded
    to a common length, and prefilled in ONE model call **directly into
    their engine cache rows** (per-slot scatter + per-row length masking
    — no 1-deep temp cache or splice).  Each consumed request is
    admitted, finished (``max_new_tokens`` already satisfied by the
    prefill-sampled token), rejected with ``error`` set before prefill
    (over-long prompt, pool exhaustion), or evicted on a persistent
    prefill fault.  Returns the list of consumed requests so the caller
    can always make progress (no livelock on a hard-faulting head).

    Head-of-line blocking: a transiently-deferred large prompt no longer
    stalls every request behind it.  A bounded lookahead admits later
    requests that fit RIGHT NOW, but each such admission spends one unit
    of the head's bypass budget (``admit_lookahead``); once the budget is
    exhausted, admission reverts to strict FIFO — every freed block is
    implicitly reserved for the deferred head, which therefore cannot
    starve (bounded bypass, then exclusive claim on frees).

``step(fault=None)``
    One decode step for all active slots.  Tokens are chosen by a
    slot-masked sampler inside the jitted step — greedy argmax by default,
    or temperature/top-k sampling driven by a ``(slots,)`` per-slot PRNG
    key vector (each slot owns an independent key stream, advanced only
    on *accepted* steps so a fault retry resamples the same token).

``run(requests, fault_at=None, admit_fault_at=None)``
    Drives admission + decode to completion.  ``fault_at=(step, fault)``
    injects a campaign fault into one decode step; ``admit_fault_at=
    (uid, fault)`` injects into the admission batch containing that uid.

``cache_stats()``
    Cache geometry/occupancy introspection (kind, bytes, block pool
    usage) so benchmarks and tests never poke at private pytrees.

Recovery policy
---------------
``RecoveryPolicy`` makes the paper's detect->recompute loop explicit:

  * a detected fault re-executes the step from the pre-step cache state
    (``prev_cache`` is held until the flag is read back) up to
    ``max_retries`` times — prefill retries likewise restart from the
    pre-admission cache, never from the possibly-corrupted attempt.
    Under paging this stays sound because pool updates are functional
    and the host block tables are mutated only *outside* the
    attempt/retry window (alloc/growth before the step, frees after);
  * if the flag persists, the fault is *hard*: with
    ``evict_on_hard_fault`` (default) the affected requests are evicted
    with ``error`` recorded (their blocks returned to the free list) and
    the engine keeps serving, otherwise a ``RuntimeError`` is raised
    (the seed behavior).

Token budget: ``max_new_tokens`` counts every generated token *including*
the one sampled at prefill, so ``max_new_tokens=N`` yields exactly N new
tokens (``N-1`` decode steps) — a request satisfied at admission never
occupies a slot.

Accounting: ``EngineStats`` distinguishes **rejections** (pre-prefill
screening: ``prompt_too_long``, ``oom:block_pool`` — the request never
held cache state) from **evictions** (a resident request lost its slot:
hard fault, ``oom:kv_blocks`` growth failure).  ``cache_stats()`` reports
paged ``utilization`` against *allocated* tokens (``blocks_used *
block_size``), so internal fragmentation is visible as its complement
rather than hidden by the total-pool denominator, plus ``fragmentation``,
``blocks_shared``, and ``prefix_hit_rate``.

Telemetry (``telemetry=EngineTelemetry(...)``, repro/obs/)
----------------------------------------------------------
An attached ``EngineTelemetry`` exports the engine's internals without
changing them: every ``EngineStats`` counter is mirrored into the
metrics registry after each ``admit()``/``step()`` (monotonic
``inc_to`` — the exported counters equal the stats fields exactly, by
construction), per-step deltas feed the rolling ``FaultRateMonitor``
(the observed detection/retry-rate surface ROADMAP 5b's adaptive
protection consumes), and — when tracing is enabled — the scheduler's
phases are recorded as Chrome-trace spans (``admit``, ``prefill``,
``prefill_chunk``, ``decode_step``, ``abft_check``, ``abft_retry``,
``cow_copy``) fenced with ``jax.block_until_ready`` so asynchronous
device work is attributed to the right span, plus instant events for
fault detections, evictions/rejections, and intensity-guided
``scheme_flip``s carrying {intensity, scheme, decode, prefill}.
Telemetry is passive: greedy token streams are byte-identical with it
enabled or disabled (fencing orders host timestamps, never values),
and with no telemetry attached the instrumented paths reduce to no-op
spans.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model
from repro.obs.trace import Tracer
from repro.serve.paged_cache import (
    BlockPool,
    PrefixIndex,
    blocks_for,
    pytree_bytes,
)

# shared no-op tracer for engines without telemetry: instrumented paths
# cost one disabled-flag check, and hand out a singleton null span
_NULL_TRACER = Tracer(enabled=False)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int           # budget of generated tokens (incl. the
                                  # prefill-sampled first token)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None      # set when evicted (hard fault, too long,
                                  # block-pool exhaustion)
    # wall-clock perf_counter() stamp per generated token (benchmarks
    # derive TTFT / inter-token-latency percentiles from these)
    times: list = dataclasses.field(default_factory=list, repr=False)


@dataclasses.dataclass
class _ChunkCursor:
    """Resumable prefill state of one admitted-but-not-yet-decoding
    request under the chunked-prefill scheduler: ``prompt[:filled]`` is
    resident in the cache (including any shared prefix), the rest still
    has to be prefilled in token-budgeted chunks.  Host-only state —
    mutated strictly outside the jitted attempt/retry window, like the
    block tables."""

    req: Request
    total: int                    # len(prompt)
    filled: int                   # logical tokens already resident
    prefix: int                   # shared-prefix tokens (stats accounting)


# errors set before a request ever reaches prefill (admission screening)
PRE_PREFILL_ERRORS = ("prompt_too_long", "oom:block_pool")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """ABFT detect->recompute policy (see module docstring)."""

    max_retries: int = 1           # clean re-executions after a detection
    evict_on_hard_fault: bool = True   # evict + record error vs raise


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    faults_detected: int = 0
    retries: int = 0
    hard_faults: int = 0
    evictions: int = 0         # resident requests that lost their slot
    rejections: int = 0        # screened out before prefill (never resident)
    # prefix sharing
    prompt_tokens_total: int = 0
    prefix_tokens_shared: int = 0
    cow_copies: int = 0
    # chunked prefill
    prefill_chunks: int = 0    # prompt-chunks executed (one per row per step)
    chunk_retries: int = 0     # clean re-executions of a faulted chunk only
    chunk_budget_retunes: int = 0  # auto-budget changes as occupancy drifts
    mixed_steps: int = 0       # steps carrying decode AND prefill tokens
    decode_only_steps: int = 0
    prefill_only_steps: int = 0
    # per-step intensity-guided selection trace: one entry per executed
    # step, {"step", "decode", "prefill", "intensity", "scheme"} — the
    # serving-time record of the paper's §5.3 decision re-made from each
    # step's ACTUAL token composition.  Bounded by the same deterministic
    # stride decimation as the occupancy samples.
    selection_trace: list = dataclasses.field(default_factory=list)
    selection_count: int = 0
    selection_stride: int = 1
    # steps whose intensity-guided selection differs from the previous
    # step's (the regime crossings telemetry emits as instant events)
    scheme_flips: int = 0
    # per-step pool occupancy aggregates (one observation per executed
    # decode step on a paged engine).  The mean is exact (sum/count); the
    # median comes from a BOUNDED sample list kept small by deterministic
    # stride decimation, so a long-lived serving engine never accumulates
    # unbounded per-step state
    blocks_used_sum: int = 0
    blocks_used_count: int = 0
    blocks_used_samples: list = dataclasses.field(default_factory=list)
    blocks_used_stride: int = 1
    blocks_used_peak: int = 0
    blocks_shared_peak: int = 0

    MAX_OCCUPANCY_SAMPLES = 4096

    def observe_blocks_used(self, used: int) -> None:
        self.blocks_used_sum += used
        self.blocks_used_count += 1
        self.blocks_used_peak = max(self.blocks_used_peak, used)
        if self.blocks_used_count % self.blocks_used_stride == 0:
            self.blocks_used_samples.append(used)
            if len(self.blocks_used_samples) > self.MAX_OCCUPANCY_SAMPLES:
                # halve the sampling rate.  Keep the ODD indices: entry k
                # was recorded at observation (k+1)*stride, so [1::2]
                # retains exactly the even multiples of the old stride —
                # the multiples of the DOUBLED stride — and the
                # "entry k <=> observation (k+1)*stride" alignment
                # survives every decimation round ([::2] kept the odd
                # multiples, which the new stride can never produce)
                self.blocks_used_samples = self.blocks_used_samples[1::2]
                self.blocks_used_stride *= 2

    def observe_selection(self, decode: int, prefill: int,
                          intensity: float, scheme: str) -> None:
        """Record one step's (composition, intensity, scheme) decision."""
        if decode and prefill:
            self.mixed_steps += 1
        elif prefill:
            self.prefill_only_steps += 1
        else:
            self.decode_only_steps += 1
        self.selection_count += 1
        if self.selection_count % self.selection_stride == 0:
            self.selection_trace.append({
                "step": self.steps, "decode": decode, "prefill": prefill,
                "intensity": intensity, "scheme": scheme,
            })
            if len(self.selection_trace) > self.MAX_OCCUPANCY_SAMPLES:
                # decimation keeps the ODD indices (see
                # observe_blocks_used): trace[k] stays the observation
                # numbered (k+1)*selection_stride after ANY number of
                # rounds, so downstream consumers can reconstruct true
                # observation indices from (k, stride) alone
                self.selection_trace = self.selection_trace[1::2]
                self.selection_stride *= 2

    @property
    def blocks_used_mean(self) -> float:
        return self.blocks_used_sum / max(self.blocks_used_count, 1)

    @property
    def blocks_used_median(self) -> float:
        """Steady-state resident blocks: the median is robust to the
        cold-start wave, whose requests cannot share (nothing is cached
        yet) and briefly hold unshared copies of a common template."""
        s = sorted(self.blocks_used_samples)
        n = len(s)
        if not n:
            return 0.0
        return (s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_tokens_shared / max(self.prompt_tokens_total, 1)


def _pad_len(n: int) -> int:
    """Bucket prefill lengths to multiples of 8 to bound jit recompiles."""
    return max(8, -(-n // 8) * 8)


def _pad_rows(n: int, cap: int) -> int:
    """Bucket a prefill batch's ROW count to the next power of two (capped
    at the engine's slot count).  Chunk batches vary in both row count and
    chunk length step to step; bucketing both dims bounds the number of
    jitted ``_prefill_chunk`` variants at O(log2(slots) x chunk/8) for an
    entire run instead of one compile per composition."""
    r = 1
    while r < n:
        r *= 2
    return min(r, cap)


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 abft: ABFTConfig = ABFTConfig(), dtype=jnp.bfloat16,
                 hints=None,
                 policy: RecoveryPolicy = RecoveryPolicy(),
                 cache_kind: str = "dense", block_size: int = 16,
                 num_blocks: int | None = None,
                 prefix_sharing: bool = False, admit_lookahead: int = 8,
                 chunk_tokens: int | str | None = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 telemetry=None):
        assert slots >= 1
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.abft = abft
        self.ctx = LayerCtx(abft=abft, hints=hints)
        self.policy = policy
        self.stats = EngineStats()
        self.pos = np.zeros((slots,), np.int32)      # per-slot write cursor
        self.active: dict = {}                        # slot -> Request
        self.cache_kind = cache_kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.admit_lookahead = int(admit_lookahead)
        self._dtype_bytes = jnp.dtype(dtype).itemsize
        # observability (repro/obs): optional EngineTelemetry — metrics
        # mirroring + fault-rate monitor + span tracer.  _tr is always a
        # Tracer so instrumented paths need no None checks; _last_scheme
        # tracks the per-step selection for scheme_flip instant events
        self.telemetry = telemetry
        self._tr = telemetry.tracer if telemetry is not None \
            else _NULL_TRACER
        self._last_scheme: str | None = None
        # compiled protection plan for this (model, hardware, serving)
        # triple: the per-step intensity-guided fast path step() consults
        # plus the roofline chunk-budget autotuner (core/policy.py)
        self.plan = model.protection_plan(
            hw=abft.hardware, policy=abft.effective_policy(),
            phase="serve", n_tokens=slots, dtype_bytes=self._dtype_bytes)
        # chunked-prefill scheduler: per-step token budget + chunk cursors.
        # chunk_tokens="auto" asks the plan for the smallest budget whose
        # mixed-step arithmetic intensity clears the device CMR (ROADMAP
        # autotuning item); the budget re-tunes as slot occupancy drifts
        # (_retune_chunk_budget).
        self.chunk_auto = chunk_tokens == "auto"
        if self.chunk_auto:
            chunk_tokens = self.plan.tune_chunk_budget(lo=8, hi=max_len)
        if chunk_tokens is not None:
            if not isinstance(chunk_tokens, int):
                raise ValueError(
                    f"chunk_tokens must be an int or 'auto', got "
                    f"{chunk_tokens!r}")
            if chunk_tokens < 1:
                raise ValueError("chunk_tokens must be >= 1")
            if not model.supports_chunked_prefill:
                raise ValueError(
                    "chunk_tokens requires an attention-only decoder "
                    "(SSM / cross-attention state cannot resume a prompt "
                    "mid-sequence)")
        self.chunk_tokens = chunk_tokens
        self._prefill_cursors: dict = {}      # slot -> _ChunkCursor (FIFO)
        # admission-campaign fault awaiting the target's first chunk
        self._pending_prefill_fault: tuple | None = None
        # requests that turned done inside admit()/step(), awaiting run()'s
        # result collection (replaces the O(requests x steps) done-scan)
        self._done_events: list = []
        # head-of-line state: (uid of the deferred head, bypasses spent)
        self._hol_uid: int | None = None
        self._hol_bypassed = 0
        # per-slot PRNG key vector: each slot samples from its own stream
        self.keys = jax.random.split(jax.random.PRNGKey(seed), slots)

        if cache_kind == "paged":
            width = -(-max_len // block_size)         # blocks covering max_len
            if num_blocks is None:
                num_blocks = slots * width            # dense-equivalent pool
            self.pool: BlockPool | None = BlockPool(
                num_blocks, block_size, slots, width)
            self.cache = model.init_paged_cache(
                slots, num_blocks, block_size, dtype=dtype)
        elif cache_kind == "dense":
            self.pool = None
            self.cache = model.init_cache(slots, max_len, dtype=dtype)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        if prefix_sharing:
            if self.pool is None:
                raise ValueError("prefix_sharing requires cache_kind='paged'")
            if not model.supports_prefix_sharing:
                raise ValueError(
                    "prefix_sharing requires an attention-only decoder "
                    "(no SSM / cross-attention state outside the block "
                    "pool)")
            self.index: PrefixIndex | None = PrefixIndex(block_size)
        else:
            self.index = None

        def _advance(keys):
            """Split each slot key into (sample, next) — a no-op pair in
            greedy mode so the jitted graph stays key-free."""
            if self.temperature <= 0.0:
                return keys, keys
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            return ks[:, 0], ks[:, 1]

        def _sample(logits, keys):
            """logits: (n, V) -> (n,) int32 token ids."""
            if self.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            lg = logits.astype(jnp.float32) / self.temperature
            if self.top_k > 0:
                # clamp to the vocab: an oversized --top-k is "no cutoff",
                # not a crash inside the jitted step
                k = min(self.top_k, lg.shape[-1])
                kth = jax.lax.top_k(lg, k)[0][..., -1:]
                lg = jnp.where(lg < kth, jnp.float32(-1e30), lg)
            return jax.vmap(jax.random.categorical)(keys, lg).astype(
                jnp.int32)

        def _decode_step(p, tok, cache, pos, mask, keys, tables, fault):
            logits, new_cache, flag = model.decode(
                p, tok, cache, pos,
                dataclasses.replace(self.ctx, fault=fault),
                block_tables=tables)
            sub, nkeys = _advance(keys)
            nxt = _sample(logits[:, 0, :], sub)
            # slot-masked sampling: inactive slots never emit a token,
            # and their key streams stay untouched — a slot's sampling
            # sequence depends only on its own accepted steps, never on
            # unrelated engine activity
            nxt = jnp.where(mask, nxt, jnp.int32(-1))
            nkeys = jnp.where(mask[:, None], nkeys, keys)
            return nxt, new_cache, flag, nkeys

        def _prefill_step(p, toks, cache, slot_ids, lengths, keys, tables,
                          fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            return first, new_cache, flag, nkeys

        def _prefill_prefix_step(p, toks, cache, slot_ids, lengths, keys,
                                 tables, prefix_lens, fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables,
                prefix_lens=prefix_lens)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            return first, new_cache, flag, nkeys

        def _prefill_chunk_step(p, toks, cache, slot_ids, lengths, keys,
                                tables, starts, final_mask, fault):
            """One co-scheduled prefill chunk: rows are mid-prompt chunks
            whose logical positions begin at ``starts``.  Only rows whose
            chunk COMPLETES the prompt (``final_mask``) emit their first
            sampled token and advance their key stream — so a prompt's
            sampling sequence is identical however it was chunked."""
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables,
                prefix_lens=starts)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            first = jnp.where(final_mask, first, jnp.int32(-1))
            nkeys = jnp.where(final_mask[:, None], nkeys, keys)
            return first, new_cache, flag, nkeys

        self._decode = jax.jit(_decode_step)
        self._prefill = jax.jit(_prefill_step)
        self._prefill_prefix = jax.jit(_prefill_prefix_step)
        self._prefill_chunk = jax.jit(_prefill_chunk_step)

    # ----------------------------------------------------------- telemetry
    def attach_telemetry(self, telemetry) -> None:
        """Attach (or replace) an ``EngineTelemetry`` mid-lifecycle —
        e.g. after a warm-up run whose stats were reset, so the mirrored
        counters start from the fresh ``EngineStats``.  The telemetry
        object must be fresh too (counter mirroring is monotonic)."""
        self.telemetry = telemetry
        self._tr = telemetry.tracer if telemetry is not None \
            else _NULL_TRACER

    def _sync_telemetry(self) -> None:
        """Mirror EngineStats into the registry + feed the fault-rate
        monitor (one observation per admit/step)."""
        if self.telemetry is None:
            return
        self.telemetry.sync(
            self.stats,
            active_slots=len(self.active),
            prefill_cursors=len(self._prefill_cursors),
            blocks_used=(self.pool.blocks_used
                         if self.pool is not None else None),
            blocks_free=(self.pool.blocks_free
                         if self.pool is not None else None),
            chunk_budget=(self.chunk_tokens
                          if isinstance(self.chunk_tokens, int)
                          else None))

    # ------------------------------------------------------------ admission
    def free_slots(self) -> list:
        return [s for s in range(self.slots)
                if s not in self.active and s not in self._prefill_cursors]

    def _release(self, slot: int) -> None:
        """Drop a slot's cache references (paged: refcount decrements;
        blocks whose last reference dropped return to the free list and
        their prefix-index entries are purged)."""
        if self.pool is not None:
            freed = self.pool.free_slot(slot)
            if self.index is not None and freed:
                self.index.purge(freed)
        self.pos[slot] = 0

    def _finish(self, req: Request, error: str | None = None, *,
                reject: bool = False, evict: bool = False) -> None:
        """Mark a request done and queue it for run()'s result collection.
        ``reject``: screened out before prefill (never held cache state);
        ``evict``: a resident request lost its slot."""
        if error is not None:
            req.error = error
        req.done = True
        if reject:
            self.stats.rejections += 1
            self._tr.instant("reject", {"uid": req.uid, "error": error})
        if evict:
            self.stats.evictions += 1
            self._tr.instant("evict", {"uid": req.uid, "error": error})
        self._done_events.append(req)

    def _drain_finished(self) -> list:
        done, self._done_events = self._done_events, []
        return done

    def admit(self, pending: list, fault: ModelFault | None = None,
              fault_uid: int | None = None) -> list:
        """Batched admission (see module docstring).  Consumes up to
        ``len(free_slots())`` requests from ``pending`` — IN PLACE — and
        returns the consumed requests: every one ends up active, done, or
        rejected/evicted with ``error`` set, so the caller always
        progresses.  Consumption is FIFO except for the bounded lookahead
        past a transiently-deferred head (see module docstring).
        ``fault``/``fault_uid``: campaign injection applied only when the
        targeted request actually reaches prefill."""
        with self._tr.span("admit") as sp:
            consumed = self._admit_impl(pending, fault, fault_uid)
            sp.set_args(consumed=len(consumed),
                        admitted=len([r for r in consumed
                                      if r.error is None]))
        self._sync_telemetry()
        return consumed

    def _admit_impl(self, pending: list, fault: ModelFault | None = None,
                    fault_uid: int | None = None) -> list:
        free = self.free_slots()
        if not pending or not free:
            return []

        admitted, slot_list, prefix_plans, cow_pairs = [], [], [], []
        consumed, consumed_idx = [], []
        head_deferred = False
        scanned_past_head = 0
        for i, req in enumerate(pending):
            if len(slot_list) >= len(free):
                break
            if head_deferred:
                # bounded lookahead: examine at most admit_lookahead
                # requests past the deferred head
                if scanned_past_head >= self.admit_lookahead:
                    break
                scanned_past_head += 1
            if req.max_new_tokens <= 0:
                self._finish(req)            # zero budget: nothing to do
                consumed.append(req)
                consumed_idx.append(i)
                continue
            # the prompt plus the decode budget must fit in the cache rows
            if len(req.prompt) + max(req.max_new_tokens - 1, 0) > \
                    self.max_len:
                self._finish(req, "prompt_too_long", reject=True)
                consumed.append(req)
                consumed_idx.append(i)
                continue
            slot = free[len(slot_list)]
            plan = None
            if self.pool is not None:
                # paged admission: blocks for the prompt are claimed up
                # front (decode growth is on-demand).  A request that can
                # NEVER fit is rejected with a recorded error; a request
                # that merely hit transient pressure (blocks held by
                # in-flight requests) is DEFERRED until decode frees
                # blocks.  No livelock: deferral with an empty engine is
                # impossible (a full free list that still cannot cover
                # the prompt means never-fits), so something is always
                # decoding and eventually freeing.
                need = blocks_for(len(req.prompt), self.pool.block_size)
                if need > self.pool.num_blocks or \
                        need > self.pool.table_width:
                    self._finish(req, "oom:block_pool", reject=True)
                    consumed.append(req)
                    consumed_idx.append(i)
                    continue
                if self.index is not None:
                    plan = self.index.match(req.prompt)
                    if not plan.shared_ids:
                        plan = None
                # a shared full block costs no free-list draw; the COW
                # copy of a partial tail does (need counts its index)
                fresh = need - (plan.full_blocks if plan else 0)
                if fresh > self.pool.blocks_free:
                    if not head_deferred:
                        head_deferred = True
                        if self._hol_uid != req.uid:
                            self._hol_uid = req.uid
                            self._hol_bypassed = 0
                    continue                 # deferred, keep scanning
                if head_deferred:
                    # admitting past the deferred head spends its bypass
                    # budget; once exhausted admission is strict FIFO and
                    # every freed block is reserved for the head
                    if self._hol_bypassed >= self.admit_lookahead:
                        break
                    self._hol_bypassed += 1
                if plan is not None:
                    ok = self.pool.try_admit_prefix(
                        slot, len(req.prompt), plan.shared_ids)
                else:
                    ok = self.pool.try_alloc(slot, len(req.prompt))
                assert ok, "alloc failed after fresh <= blocks_free check"
                if plan is not None and plan.partial:
                    # the suffix will write into the shared partial tail:
                    # copy-on-write it now, before any jitted step
                    pair = self.pool.try_cow(
                        slot, len(plan.shared_ids) - 1)
                    assert pair is not None, "partial tail was unshared"
                    cow_pairs.append(pair)
            admitted.append(req)
            slot_list.append(slot)
            prefix_plans.append(plan)
            consumed.append(req)
            consumed_idx.append(i)
        for i in reversed(consumed_idx):
            pending.pop(i)
        if self._hol_uid is not None and any(
                r.uid == self._hol_uid for r in consumed):
            self._hol_uid, self._hol_bypassed = None, 0    # head unblocked
        if not admitted:
            return consumed
        if fault is not None and fault_uid is not None and not any(
                r.uid == fault_uid for r in admitted):
            fault = None    # campaign target never reached prefill

        if self.chunk_tokens is not None:
            # chunked-prefill admission: allocation only — NO model call,
            # so a 32k prompt costs the decode path nothing here.  The
            # prompt becomes a chunk cursor; step() co-schedules its
            # chunks against resident decodes under the token budget.
            if cow_pairs:
                with self._tr.span("cow_copy",
                                   {"pairs": len(cow_pairs)}) as sp:
                    self.cache = self.model.copy_paged_blocks(
                        self.cache, [s for s, _ in cow_pairs],
                        [d for _, d in cow_pairs])
                    sp.fence(self.cache)
                self.stats.cow_copies += len(cow_pairs)
            for slot, req, plan in zip(slot_list, admitted, prefix_plans):
                start = plan.match_len if plan is not None else 0
                self._prefill_cursors[slot] = _ChunkCursor(
                    req=req, total=len(req.prompt), filled=start,
                    prefix=start)
                self.pos[slot] = start
            if fault is not None and fault_uid is not None:
                # campaign injection fires at the target's first chunk
                self._pending_prefill_fault = (fault_uid, fault)
            return consumed

        slot_ids = np.asarray(slot_list, np.int32)
        full_lens = np.asarray([len(r.prompt) for r in admitted], np.int32)
        prefix = np.asarray(
            [p.match_len if p is not None else 0 for p in prefix_plans],
            np.int32)
        lengths = full_lens - prefix         # valid SUFFIX tokens per row
        # admissible prompts always fit (budget check above), so clamping
        # the bucketed pad to max_len keeps the scatter in bounds
        Lpad = min(_pad_len(int(lengths.max())), self.max_len)
        toks = np.zeros((len(admitted), Lpad), np.int32)
        for i, r in enumerate(admitted):
            toks[i, : lengths[i]] = r.prompt[prefix[i]:]

        if cow_pairs:
            # COW payload moves are committed BEFORE the attempt so the
            # detect->retry window sees stable tables and block contents
            # (plain data movement, not an ABFT-protected GEMM)
            with self._tr.span("cow_copy",
                               {"pairs": len(cow_pairs)}) as sp:
                self.cache = self.model.copy_paged_blocks(
                    self.cache, [s for s, _ in cow_pairs],
                    [d for _, d in cow_pairs])
                sp.fence(self.cache)
            self.stats.cow_copies += len(cow_pairs)

        tables = (self.pool.device_tables(slot_ids)
                  if self.pool is not None else None)
        keys = self.keys[jnp.asarray(slot_ids)]
        use_prefix = bool(prefix.any())
        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths))
        prefix_dev = jnp.asarray(prefix)
        prev_cache = self.cache        # pre-admission state, kept for retry

        def attempt(fa):
            if use_prefix:
                return self._prefill_prefix(
                    args[0], args[1], prev_cache, args[2], args[3], keys,
                    tables, prefix_dev, fa)
            return self._prefill(
                args[0], args[1], prev_cache, args[2], args[3], keys,
                tables, fa)

        f = fault if fault is not None else ModelFault.none()
        with self._tr.span("prefill", {"rows": len(admitted),
                                       "tokens": int(lengths.sum())}) as sp:
            first, new_cache, flag, nkeys = attempt(f)
            sp.fence(first, flag)
        with self._tr.span("abft_check", {"phase": "prefill"}):
            faulted = bool(flag)
        if faulted:
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "prefill"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                # clean retry from the PRE-admission cache — never from the
                # possibly-corrupted attempt (mirrors decode's prev_cache);
                # same keys, so the retry resamples the same token
                with self._tr.span("abft_retry",
                                   {"phase": "prefill"}) as sp:
                    first, new_cache, flag, nkeys = attempt(
                        ModelFault.none())
                    sp.fence(first, flag)
                if not bool(flag):
                    break
            if bool(flag):
                # persistent fault: evict the admission batch with recorded
                # errors instead of retrying it forever (livelock fix).
                # _release drops refcounts only — a shared prefix block a
                # LIVE request still references stays resident
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault", {"phase": "prefill"})
                for slot, r in zip(slot_ids, admitted):
                    self._finish(r, "hard_fault:prefill", evict=True)
                    self._release(int(slot))
                return consumed

        self.cache = new_cache
        self.keys = self.keys.at[jnp.asarray(slot_ids)].set(nkeys)
        # admit-time monolithic prefill is a prefill-only "step" in the
        # selection trace: the whole-prompt token mass lands in one call
        # (exactly the composition the chunked scheduler bounds)
        self._observe_step_mix(0, int(lengths.sum()))
        first = np.asarray(first)
        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slot_ids, admitted)):
            req.generated.append(int(first[i]))
            req.times.append(now)
            self.stats.tokens += 1
            self.stats.prompt_tokens_total += int(full_lens[i])
            self.stats.prefix_tokens_shared += int(prefix[i])
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)           # budget met at prefill: the
                self._release(int(slot))    # request never occupies a slot
                continue
            self.active[int(slot)] = req
            self.pos[int(slot)] = int(full_lens[i])
            if self.index is not None:
                # register only AFTER the flag read back clean: the index
                # must never name blocks holding a faulty attempt's data
                self.index.add(req.prompt, self.pool.tables[int(slot)])
        return consumed

    # ------------------------------------------------------------ decoding
    def step(self, fault: ModelFault | None = None) -> dict:
        """One engine step.  Returns {uid: token} for decoded slots.

        Unchunked: one decode step for all active slots (admission
        already prefilled them whole).  Chunked (``chunk_tokens`` set):
        one *budgeted* step — all resident decode tokens first, then the
        leftover budget is filled with prefill chunks from the cursor
        queue (see module docstring)."""
        before = self.stats.steps
        t0 = time.perf_counter()
        if self.chunk_tokens is not None:
            out = self._step_chunked(fault)
        else:
            out = self._decode_core(fault)
            if self.stats.steps > before:
                self._observe_step_mix(len(out), 0)
        if self.telemetry is not None:
            if self.stats.steps > before:
                self.telemetry.observe_step_latency(
                    time.perf_counter() - t0)
            self._sync_telemetry()
        return out

    def _observe_step_mix(self, decode_tokens: int,
                          prefill_tokens: int) -> None:
        """Record THIS step's intensity-guided (composition, intensity,
        scheme) decision via the plan's cached per-step fast path
        (``plan.for_step``).  The representative dims are the widest
        per-token projection (d_model x d_ff); the jitted calls
        re-resolve the scheme per GEMM shape at trace time anyway — this
        records the step-level decision those shapes imply."""
        if decode_tokens + prefill_tokens == 0:
            return
        sel = self.plan.for_step(decode_tokens, prefill_tokens)
        self.stats.observe_selection(decode_tokens, prefill_tokens,
                                     sel.arithmetic_intensity,
                                     sel.scheme_name)
        if self._last_scheme is not None and \
                sel.scheme_name != self._last_scheme:
            # the paper's §5.3 decision changed regime between steps —
            # exported as an instant event so a Perfetto timeline shows
            # WHERE the serving mix crossed the CMR boundary
            self.stats.scheme_flips += 1
            self._tr.instant("scheme_flip", {
                "intensity": sel.arithmetic_intensity,
                "scheme": sel.scheme_name,
                "decode": decode_tokens, "prefill": prefill_tokens,
            })
        self._last_scheme = sel.scheme_name

    def _retune_chunk_budget(self) -> None:
        """Auto-budget re-tuning as slot occupancy drifts: the budget
        floor tracks resident decode tokens (decode packs first — the
        floor guarantees prefill a quantum of progress every step),
        while the CMR target keeps full mixed steps compute-bound
        whenever the step geometry can reach it."""
        budget = self.plan.tune_chunk_budget(
            decode_tokens=len(self.active), lo=8, hi=self.max_len)
        if budget != self.chunk_tokens:
            self.chunk_tokens = budget
            self.stats.chunk_budget_retunes += 1

    def _plan_chunks(self, budget: int) -> list:
        """Pick this step's prefill chunks: cursors in admission (FIFO)
        order, each taking ``min(budget left, tokens left)``.  Returns
        [(slot, cursor, take, final)]."""
        rows = []
        for slot, cur in self._prefill_cursors.items():
            if budget <= 0:
                break
            take = min(budget, cur.total - cur.filled)
            rows.append((slot, cur, take, cur.filled + take == cur.total))
            budget -= take
        return rows

    def _step_chunked(self, fault: ModelFault | None = None) -> dict:
        """One budgeted mixed step: decode tokens are packed first (every
        resident stream advances every step — the starvation guarantee),
        then prefill chunks fill ``chunk_tokens - n_decode``.  An injected
        step fault lands on the prefill chunk when one is scheduled, else
        on the decode call — each call retries independently, so a chunk
        fault re-executes ONLY that chunk."""
        if self.chunk_auto:
            self._retune_chunk_budget()
        n_decode = len(self.active)
        rows = self._plan_chunks(max(0, self.chunk_tokens - n_decode))
        prefill_tokens = sum(take for _, _, take, _ in rows)
        chunk_fault = fault if rows else None
        decode_fault = fault if not rows else None

        out = {}
        steps_before = self.stats.steps
        if self.active:
            out = self._decode_core(decode_fault)
        if rows:
            committed = self._run_prefill_chunk(rows, chunk_fault)
            if not committed:
                prefill_tokens = 0     # discarded: never actually served
            if self.stats.steps == steps_before:
                # the chunk ran even if decode didn't (no actives, or the
                # growth guard evicted them all before executing) — count
                # the step so run()'s fault_at disarm check sees it and
                # never re-injects a fault this chunk already consumed
                self.stats.steps += 1
        if self.stats.steps > steps_before:
            self._observe_step_mix(len(out), prefill_tokens)
        return out

    def _run_prefill_chunk(self, rows: list,
                           fault: ModelFault | None) -> bool:
        """Execute one co-scheduled prefill-chunk batch (host side of the
        chunk state machine).  Cursor/table state mutates only outside
        the attempt/retry window; a detected fault re-executes the chunk
        from the pre-chunk cache — earlier chunks and this step's decode
        are never re-run.  Returns True when the chunk committed, False
        when a persistent fault discarded it (the batch was evicted and
        its tokens were never served)."""
        A = len(rows)
        slot_list = [s for s, _, _, _ in rows]
        # pending admission-campaign fault: consumed by the first chunk
        # batch containing the target (one fault per jitted call — if a
        # step fault is already routed here, the campaign entry is
        # retired rather than left to linger past the target's prefill)
        if self._pending_prefill_fault is not None:
            uid, pf = self._pending_prefill_fault
            if any(cur.req.uid == uid for _, cur, _, _ in rows):
                if fault is None:
                    fault = pf
                self._pending_prefill_fault = None

        Apad = _pad_rows(A, self.slots)
        Lpad = min(_pad_len(max(take for _, _, take, _ in rows)),
                   self.max_len)
        toks = np.zeros((Apad, Lpad), np.int32)
        slot_ids = np.full((Apad,), slot_list[0], np.int32)
        lengths = np.zeros((Apad,), np.int32)
        starts = np.zeros((Apad,), np.int32)
        final = np.zeros((Apad,), bool)
        for i, (slot, cur, take, fin) in enumerate(rows):
            toks[i, :take] = cur.req.prompt[cur.filled:cur.filled + take]
            slot_ids[i] = slot
            lengths[i] = take
            starts[i] = cur.filled
            final[i] = fin
        # padding rows alias row 0's slot with lengths == 0: their cache
        # writes route to the drop sentinel and their sampled token / key
        # advance are masked by ``final`` — pure shape ballast so the jit
        # cache is keyed by (row bucket, length bucket) only

        tables = (self.pool.device_tables(slot_ids)
                  if self.pool is not None else None)
        keys = self.keys[jnp.asarray(slot_ids)]
        prev_cache = self.cache        # pre-chunk state, kept for retry
        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths), jnp.asarray(starts),
                jnp.asarray(final))

        def attempt(fa):
            return self._prefill_chunk(
                args[0], args[1], prev_cache, args[2], args[3], keys,
                tables, args[4], args[5], fa)

        f = fault if fault is not None else ModelFault.none()
        with self._tr.span(
                "prefill_chunk",
                {"rows": A,
                 "tokens": int(sum(t for _, _, t, _ in rows))}) as sp:
            first, new_cache, flag, nkeys = attempt(f)
            sp.fence(first, flag)
        with self._tr.span("abft_check", {"phase": "prefill_chunk"}):
            faulted = bool(flag)
        if faulted:
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "prefill_chunk"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                self.stats.chunk_retries += 1
                with self._tr.span("abft_retry",
                                   {"phase": "prefill_chunk"}) as sp:
                    first, new_cache, flag, nkeys = attempt(
                        ModelFault.none())
                    sp.fence(first, flag)
                if not bool(flag):
                    break
            if bool(flag):
                # persistent chunk fault: evict ONLY this chunk batch's
                # requests (their earlier chunks die with their blocks —
                # refcounts protect any shared prefix a live sharer
                # holds); the committed cache stays pre-chunk
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault",
                                 {"phase": "prefill_chunk"})
                for slot, cur, _, _ in rows:
                    self._finish(cur.req, "hard_fault:prefill", evict=True)
                    del self._prefill_cursors[slot]
                    self._release(slot)
                    if self._pending_prefill_fault is not None and \
                            self._pending_prefill_fault[0] == cur.req.uid:
                        self._pending_prefill_fault = None  # target gone
                return False

        self.cache = new_cache
        self.keys = self.keys.at[jnp.asarray(slot_list)].set(
            jnp.asarray(nkeys)[:A])
        self.stats.prefill_chunks += A
        first = np.asarray(first)
        now = time.perf_counter()
        for i, (slot, cur, take, fin) in enumerate(rows):
            cur.filled += take
            self.pos[slot] = cur.filled
            if not fin:
                continue
            req = cur.req
            req.generated.append(int(first[i]))
            req.times.append(now)
            self.stats.tokens += 1
            self.stats.prompt_tokens_total += cur.total
            self.stats.prefix_tokens_shared += cur.prefix
            del self._prefill_cursors[slot]
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)          # budget met at prefill
                self._release(slot)
                continue
            self.active[slot] = req
            if self.index is not None:
                self.index.add(req.prompt, self.pool.tables[slot])
        return True

    def _decode_core(self, fault: ModelFault | None = None) -> dict:
        """One decode step for all active slots.  Returns {uid: token}."""
        if self.pool is not None:
            # on-demand growth: claim the block the cursor is about to
            # enter BEFORE the jitted step (tables must be stable across
            # the attempt/retry window); a slot that cannot grow is
            # evicted with a recorded error, freeing blocks for the rest
            cow_pairs = []
            for s in sorted(self.active):
                # copy-on-write guard: if this step's write lands in a
                # block another slot still references, redirect to a
                # fresh copy first.  Admission COWs the shared partial
                # tail eagerly, so this only fires on exotic lifecycles —
                # but scribbling on a sharer's block is silent corruption,
                # so the guard is unconditional.
                idx = int(self.pos[s]) // self.pool.block_size
                if idx < self.pool.slot_blocks(s) and \
                        self.pool.refcount[self.pool.tables[s, idx]] > 1:
                    if self.pool.blocks_free == 0:
                        req = self.active.pop(s)
                        self._finish(req, "oom:kv_blocks", evict=True)
                        self._release(s)
                        continue
                    cow_pairs.append(self.pool.try_cow(s, idx))
                if not self.pool.try_grow(s, int(self.pos[s]) + 1):
                    req = self.active.pop(s)
                    self._finish(req, "oom:kv_blocks", evict=True)
                    self._release(s)
            if cow_pairs:
                with self._tr.span("cow_copy",
                                   {"pairs": len(cow_pairs)}) as sp:
                    self.cache = self.model.copy_paged_blocks(
                        self.cache, [a for a, _ in cow_pairs],
                        [b for _, b in cow_pairs])
                    sp.fence(self.cache)
                self.stats.cow_copies += len(cow_pairs)
        if not self.active:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for s, req in self.active.items():
            toks[s, 0] = req.generated[-1]
            mask[s] = True
        pos = jnp.asarray(self.pos)            # (slots,) vectorized cursor
        tables = (self.pool.device_tables()
                  if self.pool is not None else None)
        f = fault if fault is not None else ModelFault.none()

        prev_cache = self.cache
        prev_keys = self.keys
        with self._tr.span("decode_step",
                           {"tokens": len(self.active)}) as sp:
            nxt, new_cache, flag, nkeys = self._decode(
                self.params, jnp.asarray(toks), prev_cache, pos,
                jnp.asarray(mask), prev_keys, tables, f)
            sp.fence(nxt, flag)
        self.stats.steps += 1
        if self.pool is not None:
            # per-step occupancy samples: benchmarks report mean/median/
            # peak blocks_used (the paged capacity win) without poking
            # mid-run
            self.stats.observe_blocks_used(self.pool.blocks_used)
            self.stats.blocks_shared_peak = max(
                self.stats.blocks_shared_peak, self.pool.blocks_shared)
        with self._tr.span("abft_check", {"phase": "decode"}):
            faulted = bool(flag)
        if faulted:
            # ABFT detection -> recompute from pre-step state (clean run,
            # same per-slot keys: the retry resamples the same token)
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "decode"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                with self._tr.span("abft_retry",
                                   {"phase": "decode"}) as sp:
                    nxt, new_cache, flag, nkeys = self._decode(
                        self.params, jnp.asarray(toks), prev_cache, pos,
                        jnp.asarray(mask), prev_keys, tables,
                        ModelFault.none())
                    sp.fence(nxt, flag)
                if not bool(flag):
                    break
            if bool(flag):
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault", {"phase": "decode"})
                if not self.policy.evict_on_hard_fault:
                    raise RuntimeError("persistent fault after retry")
                # the flag is step-global: every in-flight request may be
                # corrupted, so evict them all with recorded errors and
                # keep the engine alive for subsequent admissions (shared
                # blocks survive as long as ANY sharer was admitted later
                # with live references — refcounts gate the free list)
                for s, req in list(self.active.items()):
                    self._finish(req, "hard_fault:decode", evict=True)
                    del self.active[s]
                    self._release(s)
                return {}
        self.cache = new_cache
        self.keys = nkeys

        out = {}
        nxt = np.asarray(nxt)
        finished = []
        now = time.perf_counter()
        for s, req in list(self.active.items()):
            t = int(nxt[s])
            req.generated.append(t)
            req.times.append(now)
            self.pos[s] += 1
            out[req.uid] = t
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)
                finished.append(s)
        for s in finished:
            del self.active[s]
            self._release(s)
        return out

    def run(self, requests: list, fault_at: tuple | None = None,
            admit_fault_at: tuple | None = None) -> dict:
        """Drive admission + decode to completion (continuous batching).

        ``fault_at``: (step_idx, ModelFault) decode-step injection —
        armed from that step index on, it fires at the first step that
        actually decodes (a step with no active slots re-arms the
        injection for the next real step instead of silently dropping
        it); ``admit_fault_at``: (uid, ModelFault) injected into the
        admission batch that contains that request uid (campaign hooks).

        Results are collected from the engine's finished-event queue —
        O(1) amortized per request — instead of rescanning every request
        each step (the seed's O(requests x steps) done-scan)."""
        pending = list(requests)
        results = {
            r.uid: r.generated for r in requests if r.done}  # pre-done edge
        self._drain_finished()
        step_i = 0
        step_fault_armed = fault_at is not None
        while pending or self.active or self._prefill_cursors:
            if pending and self.free_slots():
                if admit_fault_at is not None:
                    uid, afault = admit_fault_at
                    consumed = self.admit(pending, fault=afault,
                                          fault_uid=uid)
                    # consumed exactly once: only when the target actually
                    # went through prefill (not filtered out beforehand)
                    if any(r.uid == uid
                           and r.error not in PRE_PREFILL_ERRORS
                           and r.max_new_tokens > 0
                           for r in consumed):
                        admit_fault_at = None
                else:
                    self.admit(pending)
            fault = None
            if step_fault_armed and step_i >= fault_at[0]:
                fault = fault_at[1]
            steps_before = self.stats.steps
            self.step(fault)
            if fault is not None and self.stats.steps > steps_before:
                step_fault_armed = False     # injection hit a real step
            step_i += 1
            for req in self._drain_finished():
                if req.uid not in results:
                    results[req.uid] = req.generated
        return results

    # ------------------------------------------------------------ stats
    def cache_stats(self) -> dict:
        """Cache geometry + occupancy, without poking at private pytrees.

        Common keys: ``kind``, ``slots``, ``max_len``, ``bytes_total``
        (allocated cache bytes across all layers), ``tokens_capacity``
        (cache entries the allocation can hold), ``active_tokens`` (sum
        of live cursors), ``utilization``, ``fragmentation``,
        ``blocks_shared``, and ``prefix_hit_rate``.

        Paged ``utilization`` divides live logical tokens by *allocated*
        tokens (``blocks_used * block_size``) — NOT total pool capacity,
        which hid internal fragmentation behind an always-small ratio.
        ``fragmentation`` is its complement: the allocated-but-unfilled
        share (partial last blocks).  Under prefix sharing, logical
        tokens can exceed allocated tokens (several slots count the same
        shared block), so utilization may exceed 1.0 — that excess IS the
        sharing win.  Paged engines also report ``block_size`` /
        ``blocks_total`` / ``blocks_used`` / ``blocks_free`` /
        ``tokens_allocated``."""
        stats = {
            "kind": self.cache_kind,
            "slots": self.slots,
            "max_len": self.max_len,
            "bytes_total": pytree_bytes(self.cache),
            "active_tokens": int(
                sum(int(self.pos[s]) for s in self.active)
                + sum(int(self.pos[s]) for s in self._prefill_cursors)),
        }
        if self.pool is not None:
            allocated = self.pool.blocks_used * self.pool.block_size
            stats.update(
                block_size=self.pool.block_size,
                blocks_total=self.pool.num_blocks,
                blocks_used=self.pool.blocks_used,
                blocks_free=self.pool.blocks_free,
                blocks_shared=self.pool.blocks_shared,
                tokens_capacity=self.pool.num_blocks
                * self.pool.block_size,
                tokens_allocated=allocated,
            )
        else:
            stats["tokens_capacity"] = self.slots * self.max_len
            stats["tokens_allocated"] = stats["tokens_capacity"]
            stats["blocks_shared"] = 0
        alloc = stats["tokens_allocated"]
        stats["utilization"] = stats["active_tokens"] / alloc if alloc else 0.0
        stats["fragmentation"] = (
            max(0.0, 1.0 - stats["utilization"]) if alloc else 0.0)
        stats["prefix_hit_rate"] = self.stats.prefix_hit_rate
        return stats
