"""Serving engine: continuous-batched decode with ABFT detect->recompute
recovery.

The engine owns a fixed-capacity slot table (the batch dimension of the KV
cache).  Requests are admitted into free slots (continuous batching), each
step decodes one token for every active slot, and the per-step ABFT flag
drives the recovery policy:

  detect (paper's contribution) -> re-execute the step from the pre-step
  cache state (kept until the flag is read back) -> if the flag persists,
  surface a hard fault to the caller.

A fault-injection campaign hook lets tests corrupt a chosen layer GEMM and
verify detection + recovery end to end.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    faults_detected: int = 0
    retries: int = 0
    hard_faults: int = 0


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 abft: ABFTConfig = ABFTConfig(), dtype=jnp.bfloat16,
                 greedy: bool = True, hints=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.abft = abft
        self.ctx = LayerCtx(abft=abft, hints=hints)
        self.stats = EngineStats()
        self.cache = model.init_cache(slots, max_len, dtype=dtype)
        self.pos = np.zeros((slots,), np.int32)      # per-slot write cursor
        self.active: dict = {}                        # slot -> Request
        self.greedy = greedy

        self._decode = jax.jit(
            lambda p, tok, cache, pos, fault: model.decode(
                p, tok, cache, pos,
                dataclasses.replace(self.ctx, fault=fault)))

    # ------------------------------------------------------------ admission
    def free_slots(self) -> list:
        return [s for s in range(self.slots) if s not in self.active]

    def admit(self, req: Request) -> bool:
        """Prefill is executed per request (single-slot batch) and written
        into the slot's cache rows.  Returns False when full."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        L = len(req.prompt)
        # per-request prefill on a 1-deep batch, then splice into the slot
        tmp_cache = self.model.init_cache(1, self.max_len,
                                          dtype=jnp.bfloat16)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, tmp_cache, flag = self.model.prefill(
            self.params, batch, tmp_cache, self.ctx)
        if bool(flag):
            self.stats.faults_detected += 1
            # retry once
            logits, tmp_cache, flag = self.model.prefill(
                self.params, batch, tmp_cache, self.ctx)
            self.stats.retries += 1
            if bool(flag):
                self.stats.hard_faults += 1
                return False
        self.cache = _splice_cache(self.cache, tmp_cache, slot)
        self.pos[slot] = L
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        self.active[slot] = req
        return True

    # ------------------------------------------------------------ decoding
    def step(self, fault: ModelFault | None = None) -> dict:
        """One decode step for all active slots.  Returns {uid: token}."""
        if not self.active:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in self.active.items():
            toks[s, 0] = req.generated[-1]
        pos = int(max(self.pos[s] for s in self.active))
        f = fault if fault is not None else ModelFault.none()

        prev_cache = self.cache
        logits, new_cache, flag = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos, jnp.int32), f)
        self.stats.steps += 1
        if bool(flag):
            # ABFT detection -> recompute from pre-step state (clean run)
            self.stats.faults_detected += 1
            self.stats.retries += 1
            logits, new_cache, flag = self._decode(
                self.params, jnp.asarray(toks), prev_cache,
                jnp.asarray(pos, jnp.int32), ModelFault.none())
            if bool(flag):
                self.stats.hard_faults += 1
                raise RuntimeError("persistent fault after retry")
        self.cache = new_cache

        out = {}
        next_tok = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for s, req in list(self.active.items()):
            t = int(next_tok[s])
            req.generated.append(t)
            self.pos[s] = pos + 1
            out[req.uid] = t
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished.append(s)
        for s in finished:
            del self.active[s]
        return out

    def run(self, requests: list, fault_at: tuple | None = None) -> dict:
        """Drive admission + decode to completion (continuous batching).
        ``fault_at``: (step_idx, ModelFault) for campaign injection."""
        pending = list(requests)
        results = {}
        step_i = 0
        while pending or self.active:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            fault = None
            if fault_at is not None and step_i == fault_at[0]:
                fault = fault_at[1]
            self.step(fault)
            step_i += 1
            for req in requests:
                if req.done and req.uid not in results:
                    results[req.uid] = req.generated
        return results


def _splice_cache(dst, src, slot: int):
    """Write a 1-deep cache into row ``slot`` of the engine cache.  Handles
    both (reps, B, ...) stacked leaves and mamba f32 states."""
    def one(d, s):
        # batch dim is axis 1 for stacked leaves (reps, B, ...)
        return jax.lax.dynamic_update_slice_in_dim(
            d, s.astype(d.dtype), slot, axis=1)

    return jax.tree_util.tree_map(one, dst, src)
