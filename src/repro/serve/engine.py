"""Serving engine: continuous-batched decode with ABFT detect->recompute
recovery, built around a **vectorized per-slot position cursor** and an
optional **paged KV cache** (block-table memory manager).

Executor hierarchy (this module is the FACADE)
----------------------------------------------
The engine is three layers behind one public class:

  * ``serve/scheduler.py`` — host-side request/slot/block bookkeeping:
    ``Request``/``ChunkCursor`` lifecycle, ``EngineStats``, admission
    screening (budget checks, paged allocation, prefix matching + COW
    planning, bounded head-of-line lookahead), chunk-cursor queue, and
    the paged decode growth guard.  Pure host state, mutated strictly
    outside the jitted attempt/retry window.
  * ``serve/runner.py`` — the jitted device entry points (``decode``,
    ``prefill``, ``prefill_prefix``, ``prefill_chunk``) plus the
    slot-masked sampler.  No request state, no mesh awareness.
  * ``serve/executor.py`` — device residency: params, cache, PRNG keys,
    and the hardware-aware ``ProtectionPlan``.  ``LocalExecutor`` is
    the single-device default; ``MeshExecutor`` (``mesh=`` kwarg) runs
    tensor-parallel SPMD over a ``(data=1, model=k)`` device mesh with
    the production sharding rules (``distributed/sharding.py``): params
    sharded by ``param_specs``, the paged block pool's kv-head dim
    sharded by ``cache_specs`` behind ONE logical host block table, and
    the SAME jitted runner functions parallelized by GSPMD propagation
    from the committed inputs.

``ServeEngine`` orchestrates the three: the detect->retry windows, the
per-step intensity-guided selection, telemetry sync, and the public
``admit``/``step``/``run``/``cache_stats`` API are unchanged from the
monolith — as are greedy token streams, byte-for-byte, at every mesh
width (bf16: per-device partials accumulate in f32 and round below the
output precision).

Sharded protection plans
------------------------
With ``mesh=k``, the executor compiles the ``ProtectionPlan`` from the
POST-SHARDING per-device GEMM shapes (``model_parallel=k`` divides the
column-parallel n dims and row-parallel k dims).  Smaller per-device
GEMMs sit lower on the roofline, so the same layer can be compute-bound
(global ABFT) at TP=1 and memory-bound (fused block ABFT) at TP=4 on
the same hardware — the paper's intensity-guided selection re-made per
shard.  The per-step ``for_step`` fast path, the chunk-budget
autotuner, and the telemetry ``scheme_flip``/plan-row events all see
the sharded shapes.

Cache kinds
-----------
``cache_kind="dense"`` (default): every slot owns a dense ``(max_len,)``
cache row — one long request makes the whole batch pay max-length memory.

``cache_kind="paged"``: attention KV lives in fixed-size blocks drawn from
a shared pool (serve/paged_cache.py).  Blocks are allocated at admission
(prompt length only), grown one block at a time as decode crosses block
boundaries, and returned to the free list when a request finishes or is
evicted — including hard-fault eviction under ``RecoveryPolicy``.  Pool
exhaustion never crashes: a request that could NEVER fit is rejected with
``error="oom:block_pool"``; one that merely hit transient pressure
(blocks held by in-flight requests) is deferred until decode frees
blocks; a slot whose mid-decode growth cannot be covered is evicted with
``error="oom:kv_blocks"``.
Token streams are identical to the dense engine under greedy decoding
(block-size divides max_len => identical attention shapes); the allocation
is what changes: ``cache_stats()`` reports pool bytes ≪ slots × max_len
when prompt lengths are skewed.

``prefix_sharing=True`` (paged only) adds refcounted prefix sharing with
copy-on-write: admission matches each prompt against a content-hash index
of resident blocks (``PrefixIndex``), aliases the new slot's leading
table entries onto the longest cached prefix (full blocks refcounted; a
partial tail block is COW-copied because the suffix will write into it),
and prefills ONLY the unshared suffix at its true logical positions.
Matches are capped at ``len(prompt) - 1`` tokens so the suffix always
yields the first sampled token's logits.  The index registers prompts
only after their prefill passed the ABFT check, and entries are purged
when blocks are physically freed — so fault-driven eviction of one
sharer never frees or corrupts blocks a live request still references
(refcounts drop; the free list only sees count-zero blocks).  Greedy
streams are byte-identical to the unshared paged engine: identical
tokens at identical logical positions produce bit-identical KV, and the
suffix path's gathered-KV attention masks padding to exact zeros.
Requires ``model.supports_prefix_sharing`` (attention-only stacks —
SSM/cross-attention state is not a pure function of the token prefix).

Chunked-prefill scheduler (``chunk_tokens``)
--------------------------------------------
Unchunked, ``admit()`` runs the WHOLE prompt's prefill synchronously on
the decode path — a 32k prompt stalls every resident decode stream for
one monolithic model call (the ROADMAP's "async admission" item).  With
``chunk_tokens=N`` set, admission only *allocates* (slot, blocks, prefix
plan, COW) and parks the prompt behind a resumable **chunk cursor**;
``step()`` then builds every iteration from the fixed token budget:

  * all resident decode tokens are packed FIRST — every active stream
    advances every step, so a flood of long prompts can never starve a
    resident decode (the scheduler's latency contract);
  * the remaining ``N - n_decode`` tokens are filled with prefill chunks
    drawn FIFO from the cursor queue, each chunk resuming at its prompt's
    logical position (per-chunk rotary offsets, per-row causal
    ``q_offset``, cache scatter at arbitrary starts — the PR-3
    ``prefix_lens`` machinery generalized to both paged AND dense
    caches).

This subsumes async admission without threads: chunking bounds the
prefill work co-scheduled with every decode step, so TTFT/ITL tails
collapse on long-prompt mixes while greedy streams stay byte-identical
to the unchunked engine (same logical positions => bit-identical KV and
logits; the equivalence tests demand it, faults included).  A fault
detected during a chunk retries ONLY that chunk from the pre-chunk
cache; the step's decode call and earlier chunks are never re-executed.
Requires ``model.supports_chunked_prefill`` (attention-only stacks —
SSM recurrence state cannot resume mid-prompt through the prefill path).

Per-step intensity-guided re-selection: the engine compiles a
``ProtectionPlan`` (core/policy.py) for its (model, hardware, serving)
triple at construction; each executed step's ACTUAL token composition
(decode + chunk tokens) goes through the plan's cached
``for_step(decode, prefill)`` fast path — decode-only steps sit deep in
the memory-bound regime (fused block ABFT), mixed steps carrying a
chunk can cross into the compute-bound regime (global ABFT).  The
per-step ``(composition, intensity, scheme)`` decisions are recorded in
``EngineStats.selection_trace``; the jitted calls resolve the scheme
per GEMM shape at trace time, so distinct compositions genuinely execute
distinct schemes (the paper's §5.3 selection re-made at serving time,
per step instead of per static phase).

``chunk_tokens="auto"`` delegates the budget itself to the plan's
roofline autotuner (``plan.tune_chunk_budget``): the smallest per-step
token budget whose mixed-step arithmetic intensity clears the device
CMR (or, when the step geometry cannot reach the CMR, the
maximum-intensity budget under ``max_len``).  The budget re-tunes as
slot occupancy drifts — its floor tracks resident decode tokens so
prefill always progresses — with re-tunes counted in
``EngineStats.chunk_budget_retunes``.

Engine API
----------
``admit(pending)``
    Batched admission: up to ``len(free_slots())`` requests are drawn
    from ``pending`` (IN PLACE — consumed requests are removed), padded
    to a common length, and prefilled in ONE model call **directly into
    their engine cache rows** (per-slot scatter + per-row length masking
    — no 1-deep temp cache or splice).  Each consumed request is
    admitted, finished (``max_new_tokens`` already satisfied by the
    prefill-sampled token), rejected with ``error`` set before prefill
    (over-long prompt, pool exhaustion), or evicted on a persistent
    prefill fault.  Returns the list of consumed requests so the caller
    can always make progress (no livelock on a hard-faulting head).

    Head-of-line blocking: a transiently-deferred large prompt no longer
    stalls every request behind it.  A bounded lookahead admits later
    requests that fit RIGHT NOW, but each such admission spends one unit
    of the head's bypass budget (``admit_lookahead``); once the budget is
    exhausted, admission reverts to strict FIFO — every freed block is
    implicitly reserved for the deferred head, which therefore cannot
    starve (bounded bypass, then exclusive claim on frees).

``step(fault=None)``
    One decode step for all active slots.  Tokens are chosen by a
    slot-masked sampler inside the jitted step — greedy argmax by default,
    or temperature/top-k sampling driven by a ``(slots,)`` per-slot PRNG
    key vector (each slot owns an independent key stream, advanced only
    on *accepted* steps so a fault retry resamples the same token).

``run(requests, fault_at=None, admit_fault_at=None)``
    Drives admission + decode to completion.  ``fault_at=(step, fault)``
    injects a campaign fault into one decode step; ``admit_fault_at=
    (uid, fault)`` injects into the admission batch containing that uid.

``cache_stats()``
    Cache geometry/occupancy introspection (kind, bytes, block pool
    usage) so benchmarks and tests never poke at private pytrees.

Recovery policy
---------------
``RecoveryPolicy`` makes the paper's detect->recompute loop explicit:

  * a detected fault re-executes the step from the pre-step cache state
    (``prev_cache`` is held until the flag is read back) up to
    ``max_retries`` times — prefill retries likewise restart from the
    pre-admission cache, never from the possibly-corrupted attempt.
    Under paging this stays sound because pool updates are functional
    and the host block tables are mutated only *outside* the
    attempt/retry window (alloc/growth before the step, frees after);
  * if the flag persists, the fault is *hard*: with
    ``evict_on_hard_fault`` (default) the affected requests are evicted
    with ``error`` recorded (their blocks returned to the free list) and
    the engine keeps serving, otherwise a ``RuntimeError`` is raised
    (the seed behavior).

Token budget: ``max_new_tokens`` counts every generated token *including*
the one sampled at prefill, so ``max_new_tokens=N`` yields exactly N new
tokens (``N-1`` decode steps) — a request satisfied at admission never
occupies a slot.

Accounting: ``EngineStats`` distinguishes **rejections** (pre-prefill
screening: ``prompt_too_long``, ``oom:block_pool`` — the request never
held cache state) from **evictions** (a resident request lost its slot:
hard fault, ``oom:kv_blocks`` growth failure).  ``cache_stats()`` reports
paged ``utilization`` against *allocated* tokens (``blocks_used *
block_size``), so internal fragmentation is visible as its complement
rather than hidden by the total-pool denominator, plus ``fragmentation``,
``blocks_shared``, and ``prefix_hit_rate``.

Telemetry (``telemetry=EngineTelemetry(...)``, repro/obs/)
----------------------------------------------------------
An attached ``EngineTelemetry`` exports the engine's internals without
changing them: every ``EngineStats`` counter is mirrored into the
metrics registry after each ``admit()``/``step()`` (monotonic
``inc_to`` — the exported counters equal the stats fields exactly, by
construction), per-step deltas feed the rolling ``FaultRateMonitor``
(the observed detection/retry-rate surface ROADMAP 5b's adaptive
protection consumes), and — when tracing is enabled — the scheduler's
phases are recorded as Chrome-trace spans (``admit``, ``prefill``,
``prefill_chunk``, ``decode_step``, ``abft_check``, ``abft_retry``,
``cow_copy``) fenced with ``jax.block_until_ready`` so asynchronous
device work is attributed to the right span, plus instant events for
fault detections, evictions/rejections, intensity-guided
``scheme_flip``s carrying {intensity, scheme, decode, prefill,
model_parallel}, and one ``plan_row`` instant per protection-plan entry
at attach time (the per-shard plan surface).  Telemetry is passive:
greedy token streams are byte-identical with it enabled or disabled
(fencing orders host timestamps, never values), and with no telemetry
attached the instrumented paths reduce to no-op spans.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ErrorAdaptivePolicy
from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model
from repro.obs.trace import Tracer
from repro.serve.executor import LocalExecutor, MeshExecutor
from repro.serve.paged_cache import (
    BlockPool,
    PrefixIndex,
    pytree_bytes,
)
from repro.serve.runner import ModelRunner
from repro.serve.scheduler import (
    PRE_PREFILL_ERRORS,
    ChunkCursor,
    EngineStats,
    RecoveryPolicy,
    Request,
    Scheduler,
    _pad_len,
    _pad_rows,
)
from repro.serve.spec_decode import (
    greedy_accept,
    make_proposer,
    rejection_sample,
    target_probs,
)

__all__ = [
    "ServeEngine", "Request", "RecoveryPolicy", "EngineStats",
    "ChunkCursor", "PRE_PREFILL_ERRORS",
]

# shared no-op tracer for engines without telemetry: instrumented paths
# cost one disabled-flag check, and hand out a singleton null span
_NULL_TRACER = Tracer(enabled=False)


def _pytrees_equal(a, b) -> bool:
    """Exact leaf-wise equality of two pytrees (the shadow-stream state
    comparison — bit-identical or not, no tolerance)."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 abft: ABFTConfig = ABFTConfig(), dtype=jnp.bfloat16,
                 hints=None, mesh=None,
                 policy: RecoveryPolicy = RecoveryPolicy(),
                 cache_kind: str = "dense", block_size: int = 16,
                 num_blocks: int | None = None,
                 prefix_sharing: bool = False, admit_lookahead: int = 8,
                 chunk_tokens: int | str | None = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 telemetry=None, fault_model=None,
                 classify_injections: bool | None = None,
                 spec_decode=None, draft_len: int | str | None = None,
                 draft_window: int = 8, draft_units: int = 1):
        assert slots >= 1
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.abft = abft
        self.policy = policy
        # campaign injection (core/faults.FaultModel): polled once per
        # step() for this step's fault; every injected fault — campaign
        # or hand-armed — is placement-recorded, and when classification
        # is on (default: whenever a fault model is attached) undetected
        # faults are shadow-checked for silent corruption
        self.fault_model = fault_model
        self.classify_injections = bool(
            classify_injections if classify_injections is not None
            else fault_model is not None)
        self._injection_meta: dict | None = None
        self.cache_kind = cache_kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.admit_lookahead = int(admit_lookahead)
        # --- executor layer: device residency (params/cache/keys) and
        # the hardware-aware per-shard protection plan.  mesh=None is
        # the single-device monolith behavior; mesh=k (or a prebuilt
        # Mesh) shards params + paged KV over the 'model' axis.
        if mesh is None:
            self.executor = LocalExecutor(model, params, dtype=dtype,
                                          hints=hints)
        else:
            self.executor = MeshExecutor(model, params, mesh=mesh,
                                         dtype=dtype, hints=hints)
        # --- error-rate-adaptive protection (ErrorAdaptivePolicy):
        # schemes resolve at TRACE time from the LayerCtx's config, so a
        # runtime level change cannot ride one mutable policy inside one
        # runner — the engine compiles BOTH levels up front (immutable
        # per-level config/ctx/plan/runner) and swaps the active set when
        # update() crosses a threshold (_set_protection_level)
        eff = abft.effective_policy()
        self.adaptive = eff if isinstance(eff, ErrorAdaptivePolicy) \
            else None
        if self.adaptive is not None:
            level_cfgs = (
                dataclasses.replace(abft, policy=self.adaptive.base),
                dataclasses.replace(abft, policy=self.adaptive.escalated))
        else:
            level_cfgs = (abft,)
        self._level_abft = level_cfgs
        self._level_ctx = tuple(
            LayerCtx(abft=c, hints=self.executor.hints)
            for c in level_cfgs)
        self.protection_level = 0
        self.ctx = self._level_ctx[0]
        self._dtype_bytes = self.executor.dtype_bytes
        # observability (repro/obs): optional EngineTelemetry — metrics
        # mirroring + fault-rate monitor + span tracer.  _tr is always a
        # Tracer so instrumented paths need no None checks; _last_scheme
        # tracks the per-step selection for scheme_flip instant events.
        # The adaptive policy consumes the fault-rate monitor, so an
        # adaptive engine gets a (trace-off) telemetry object implicitly.
        if telemetry is None and self.adaptive is not None:
            from repro.obs.telemetry import EngineTelemetry

            telemetry = EngineTelemetry()
        self.telemetry = telemetry
        self._tr = telemetry.tracer if telemetry is not None \
            else _NULL_TRACER
        self._last_scheme: str | None = None
        # compiled protection plan for this (model, hardware, serving,
        # shard) tuple: per-device GEMM shapes under the executor's
        # model_parallel width drive the intensity-guided selection —
        # the per-step fast path step() consults plus the roofline
        # chunk-budget autotuner (core/policy.py).  One plan per
        # protection level (they differ exactly when escalation does).
        self._level_plans = tuple(
            self.executor.protection_plan(c, slots=slots)
            for c in level_cfgs)
        self.plan = self._level_plans[0]
        # chunked-prefill scheduler: per-step token budget + chunk cursors.
        # chunk_tokens="auto" asks the plan for the smallest budget whose
        # mixed-step arithmetic intensity clears the device CMR (ROADMAP
        # autotuning item); the budget re-tunes as slot occupancy drifts
        # (_retune_chunk_budget).
        self.chunk_auto = chunk_tokens == "auto"
        if self.chunk_auto:
            chunk_tokens = self.plan.tune_chunk_budget(lo=8, hi=max_len)
        if chunk_tokens is not None:
            if not isinstance(chunk_tokens, int):
                raise ValueError(
                    f"chunk_tokens must be an int or 'auto', got "
                    f"{chunk_tokens!r}")
            if chunk_tokens < 1:
                raise ValueError("chunk_tokens must be >= 1")
            if not model.supports_chunked_prefill:
                raise ValueError(
                    "chunk_tokens requires an attention-only decoder "
                    "(SSM / cross-attention state cannot resume a prompt "
                    "mid-sequence)")
        self.chunk_tokens = chunk_tokens
        # pre-escalation budget, restored on de-escalation (the adaptive
        # policy's shrink_chunk scales it while escalated)
        self._chunk_tokens_base = chunk_tokens \
            if isinstance(chunk_tokens, int) else None
        # admission-campaign fault awaiting the target's first chunk
        self._pending_prefill_fault: tuple | None = None

        if cache_kind == "paged":
            width = -(-max_len // block_size)         # blocks covering max_len
            if num_blocks is None:
                num_blocks = slots * width            # dense-equivalent pool
            pool: BlockPool | None = BlockPool(
                num_blocks, block_size, slots, width)
            self.executor.init_paged_cache(slots, num_blocks, block_size)
        elif cache_kind == "dense":
            pool = None
            self.executor.init_dense_cache(slots, max_len)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        if prefix_sharing:
            if pool is None:
                raise ValueError("prefix_sharing requires cache_kind='paged'")
            if not model.supports_prefix_sharing:
                raise ValueError(
                    "prefix_sharing requires an attention-only decoder "
                    "(no SSM / cross-attention state outside the block "
                    "pool)")
            index: PrefixIndex | None = PrefixIndex(block_size)
        else:
            index = None

        # --- scheduler layer: host-side slot/block/request bookkeeping
        self.scheduler = Scheduler(
            slots=slots, max_len=max_len, admit_lookahead=admit_lookahead,
            stats=EngineStats(), tracer=self._tr, pool=pool, index=index)
        # --- runner layer: the jitted device entry points, one runner
        # per protection level (jit compilation is lazy, so the inactive
        # level costs nothing until first escalation)
        self._level_runners = tuple(
            ModelRunner(model, ctx, temperature=temperature, top_k=top_k)
            for ctx in self._level_ctx)
        self.runner = self._level_runners[0]
        # the audit (analysis/audit.py) and the equivalence tests trace
        # these attributes by name; they alias the runner's compiled fns
        self._decode = self.runner.decode
        self._prefill = self.runner.prefill
        self._prefill_prefix = self.runner.prefill_prefix
        self._prefill_chunk = self.runner.prefill_chunk
        self._verify = self.runner.verify

        # --- speculative decoding (serve/spec_decode.py): a draft
        # proposer plus the per-step draft length K.  Verification is
        # the integrity boundary — drafts run unprotected (a wrong or
        # corrupted draft costs throughput, never correctness), while
        # the K+1-token verify call goes through the same ABFT-checked
        # jitted path and detect->retry window as decode.  draft_len
        # "auto"/None picks K from the SAME roofline that selects
        # schemes (plan.tune_draft_len) and re-tunes as occupancy
        # drifts; a fixed int is shrunk by the adaptive policy's
        # shrink_draft while escalated.
        self.spec = None
        self.draft_len = 0
        self.draft_auto = draft_len in (None, "auto")
        self._draft_len_base: int | None = None
        self._last_decode_tokens = 0
        if spec_decode is not None:
            if not model.supports_chunked_prefill:
                raise ValueError(
                    "spec_decode requires an attention-only decoder "
                    "(SSM recurrence cannot roll back to the last "
                    "accepted position)")
            if abft.flash_attention:
                raise ValueError(
                    "spec_decode requires the XLA attention path: the "
                    "fused flash_decode kernel cannot reproduce the "
                    "multi-token verify stream bit-for-bit (the greedy "
                    "byte-equality gate)")
            if self.draft_auto:
                self.draft_len = max(1, self.plan.tune_draft_len(
                    batch=slots))
            else:
                if not isinstance(draft_len, int) or draft_len < 1:
                    raise ValueError(
                        f"draft_len must be a positive int or 'auto', "
                        f"got {draft_len!r}")
                self.draft_len = draft_len
                self._draft_len_base = draft_len
            self.spec = make_proposer(
                spec_decode, model, self._level_ctx[0],
                lambda: self.params, units=draft_units,
                window=draft_window)

        self.executor.init_keys(seed, slots)
        self._emit_plan_rows()

    # ------------------------------------------- component state facade
    # The monolith's attribute surface is preserved verbatim: tests,
    # benchmarks, and the coverage audit read (and some write) these.
    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.executor.cache

    @cache.setter
    def cache(self, value):
        self.executor.cache = value

    @property
    def keys(self):
        return self.executor.keys

    @keys.setter
    def keys(self, value):
        self.executor.keys = value

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def model_parallel(self) -> int:
        return self.executor.model_parallel

    @property
    def stats(self) -> EngineStats:
        return self.scheduler.stats

    @stats.setter
    def stats(self, value: EngineStats) -> None:
        self.scheduler.stats = value

    @property
    def pos(self):
        return self.scheduler.pos

    @property
    def active(self) -> dict:
        return self.scheduler.active

    @property
    def pool(self):
        return self.scheduler.pool

    @property
    def index(self):
        return self.scheduler.index

    @index.setter
    def index(self, value) -> None:
        self.scheduler.index = value

    @property
    def _prefill_cursors(self) -> dict:
        return self.scheduler.prefill_cursors

    # ----------------------------------------------------------- telemetry
    def attach_telemetry(self, telemetry) -> None:
        """Attach (or replace) an ``EngineTelemetry`` mid-lifecycle —
        e.g. after a warm-up run whose stats were reset, so the mirrored
        counters start from the fresh ``EngineStats``.  The telemetry
        object must be fresh too (counter mirroring is monotonic)."""
        self.telemetry = telemetry
        self._tr = telemetry.tracer if telemetry is not None \
            else _NULL_TRACER
        self.scheduler.tracer = self._tr
        self._emit_plan_rows()

    def _emit_plan_rows(self) -> None:
        """Export the compiled (per-shard) protection plan as one
        ``plan_row`` instant per entry — a tracing consumer sees WHICH
        scheme each GEMM site runs under this executor's model_parallel
        width (the sharded-plan surface ISSUE 8 asks for)."""
        if not self._tr.enabled:
            return
        for row in self.plan.report_rows():
            args = {"model_parallel": self.model_parallel,
                    "protection_level": self.protection_level}
            if getattr(self, "spec", None) is not None:
                args["draft_len"] = self.draft_len
            args.update(row)
            self._tr.instant("plan_row", args)

    # ------------------------------------------- adaptive protection
    def _set_protection_level(self, level: int, evidence: dict) -> None:
        """Swap the active (ctx, plan, runner) set to ``level`` — the
        runtime half of ErrorAdaptivePolicy.  Emits a
        ``protection_escalation`` instant carrying the rate evidence,
        re-emits plan rows at the new level, optionally shrinks the
        chunk budget while escalated, and re-baselines the fault-rate
        monitor so the new regime is judged on fresh observations."""
        self.protection_level = level
        self.ctx = self._level_ctx[level]
        self.plan = self._level_plans[level]
        self.runner = self._level_runners[level]
        self._decode = self.runner.decode
        self._prefill = self.runner.prefill
        self._prefill_prefix = self.runner.prefill_prefix
        self._prefill_chunk = self.runner.prefill_chunk
        self._verify = self.runner.verify
        if level:
            self.stats.protection_escalations += 1
        else:
            self.stats.protection_deescalations += 1
        if self._chunk_tokens_base is not None and not self.chunk_auto \
                and self.adaptive is not None:
            if level and self.adaptive.shrink_chunk < 1.0:
                self.chunk_tokens = max(8, (int(
                    self._chunk_tokens_base * self.adaptive.shrink_chunk)
                    // 8) * 8)
            else:
                self.chunk_tokens = self._chunk_tokens_base
        # fixed draft lengths shrink under escalation like the chunk
        # budget: a shorter draft window is a smaller verify-retry blast
        # radius (auto draft lengths re-tune per step and apply the
        # shrink there)
        if self._draft_len_base is not None and self.adaptive is not None:
            if level and self.adaptive.shrink_draft < 1.0:
                self.draft_len = max(1, int(
                    self._draft_len_base * self.adaptive.shrink_draft))
            else:
                self.draft_len = self._draft_len_base
        args = {"level": level,
                "direction": "escalate" if level else "deescalate"}
        for k in ("window_detection_rate", "window_hard_fault_rate",
                  "ewma_detections_per_step",
                  "ewma_hard_faults_per_step"):
            if k in evidence:
                args[k] = evidence[k]
        self._tr.instant("protection_escalation", args)
        self._emit_plan_rows()
        if self.telemetry is not None:
            # keep lifetime totals; clear window + EWMA (the audit trail
            # survives — FaultRateMonitor.reset's contract)
            self.telemetry.faults.reset()

    def _maybe_adapt(self) -> None:
        """Per-step adaptation decision: feed the observed fault-rate
        snapshot to the ErrorAdaptivePolicy and swap protection levels
        when it says so.  No-op for non-adaptive engines."""
        if self.adaptive is None or self.telemetry is None:
            return
        snap = self.telemetry.faults.snapshot()
        if self.adaptive.update(snap):
            self._set_protection_level(self.adaptive.level, snap)

    # ------------------------------------------- injection bookkeeping
    def _take_injection_meta(self, default_source: str) -> dict:
        """Claim the pending injection metadata (set by step()/run() for
        campaign and fault_at injections) or synthesize one for a
        directly-passed fault."""
        meta = self._injection_meta
        self._injection_meta = None
        if meta is None:
            meta = {"source": default_source, "kind": "manual"}
        return meta

    def _record_injection(self, meta: dict, phase: str, outcome: str,
                          **extra) -> None:
        """Ground truth for one executed injection: where it landed
        (engine step + phase) and how it resolved (corrected /
        uncorrected / sdc / masked / undetected)."""
        entry = dict(meta)
        entry["engine_step"] = self.stats.steps
        entry["phase"] = phase
        entry["outcome"] = outcome
        entry.update(extra)
        self.stats.record_injection(entry)
        self._tr.instant("fault_injected", {
            "phase": phase, "outcome": outcome,
            "kind": entry.get("kind"), "source": entry.get("source")})

    def _shadow_outcome(self, emitted, state, shadow) -> tuple:
        """Classify an UNDETECTED injection by shadow comparison: re-run
        the same jitted call clean from the pre-step state and compare.
        SDC means the emitted tokens differ (user-visible silent
        corruption); tokens-equal is 'masked' (the fault landed out of
        range or perturbed state below the detection threshold — the
        entry still records whether internal state matched)."""
        s_emitted, s_state = shadow
        tokens_match = bool(jnp.array_equal(emitted, s_emitted))
        state_match = _pytrees_equal(state, s_state)
        outcome = "masked" if tokens_match else "sdc"
        return outcome, {"tokens_match": tokens_match,
                         "state_match": state_match}

    def _sync_telemetry(self) -> None:
        """Mirror EngineStats into the registry + feed the fault-rate
        monitor (one observation per admit/step)."""
        if self.telemetry is None:
            return
        self.telemetry.sync(
            self.stats,
            active_slots=len(self.active),
            prefill_cursors=len(self._prefill_cursors),
            blocks_used=(self.pool.blocks_used
                         if self.pool is not None else None),
            blocks_free=(self.pool.blocks_free
                         if self.pool is not None else None),
            chunk_budget=(self.chunk_tokens
                          if isinstance(self.chunk_tokens, int)
                          else None),
            draft_len=(self.draft_len
                       if self.spec is not None else None))

    # ------------------------------------------------------------ admission
    def free_slots(self) -> list:
        return self.scheduler.free_slots()

    def _release(self, slot: int) -> None:
        self.scheduler.release(slot)

    def _finish(self, req: Request, error: str | None = None, *,
                reject: bool = False, evict: bool = False) -> None:
        self.scheduler.finish(req, error, reject=reject, evict=evict)

    def _drain_finished(self) -> list:
        return self.scheduler.drain_finished()

    def _copy_cow_blocks(self, cow_pairs: list) -> None:
        """Commit COW payload moves BEFORE any jitted attempt so the
        detect->retry window sees stable tables and block contents
        (plain data movement, not an ABFT-protected GEMM)."""
        if not cow_pairs:
            return
        with self._tr.span("cow_copy", {"pairs": len(cow_pairs)}) as sp:
            self.cache = self.model.copy_paged_blocks(
                self.cache, [s for s, _ in cow_pairs],
                [d for _, d in cow_pairs])
            sp.fence(self.cache)
        self.stats.cow_copies += len(cow_pairs)

    def admit(self, pending: list, fault: ModelFault | None = None,
              fault_uid: int | None = None) -> list:
        """Batched admission (see module docstring).  Consumes up to
        ``len(free_slots())`` requests from ``pending`` — IN PLACE — and
        returns the consumed requests: every one ends up active, done, or
        rejected/evicted with ``error`` set, so the caller always
        progresses.  Consumption is FIFO except for the bounded lookahead
        past a transiently-deferred head (see module docstring).
        ``fault``/``fault_uid``: campaign injection applied only when the
        targeted request actually reaches prefill."""
        with self._tr.span("admit") as sp:
            consumed = self._admit_impl(pending, fault, fault_uid)
            sp.set_args(consumed=len(consumed),
                        admitted=len([r for r in consumed
                                      if r.error is None]))
        self._sync_telemetry()
        return consumed

    def _admit_impl(self, pending: list, fault: ModelFault | None = None,
                    fault_uid: int | None = None) -> list:
        batch = self.scheduler.select_admission(pending)
        admitted, slot_list = batch.admitted, batch.slot_list
        if not admitted:
            return batch.consumed
        if fault is not None and fault_uid is not None and not any(
                r.uid == fault_uid for r in admitted):
            fault = None    # campaign target never reached prefill

        if self.chunk_tokens is not None:
            # chunked-prefill admission: allocation only — NO model call,
            # so a 32k prompt costs the decode path nothing here.  The
            # prompt becomes a chunk cursor; step() co-schedules its
            # chunks against resident decodes under the token budget.
            self._copy_cow_blocks(batch.cow_pairs)
            self.scheduler.park_prefill(batch)
            if fault is not None and fault_uid is not None:
                # campaign injection fires at the target's first chunk
                self._pending_prefill_fault = (fault_uid, fault)
            return batch.consumed

        slot_ids = np.asarray(slot_list, np.int32)
        full_lens = np.asarray([len(r.prompt) for r in admitted], np.int32)
        prefix = np.asarray(
            [p.match_len if p is not None else 0
             for p in batch.prefix_plans], np.int32)
        lengths = full_lens - prefix         # valid SUFFIX tokens per row
        # admissible prompts always fit (budget check above), so clamping
        # the bucketed pad to max_len keeps the scatter in bounds
        Lpad = min(_pad_len(int(lengths.max())), self.max_len)
        toks = np.zeros((len(admitted), Lpad), np.int32)
        for i, r in enumerate(admitted):
            toks[i, : lengths[i]] = r.prompt[prefix[i]:]

        # COW payload moves are committed BEFORE the attempt so the
        # detect->retry window sees stable tables and block contents
        self._copy_cow_blocks(batch.cow_pairs)

        tables = (self.pool.device_tables(slot_ids)
                  if self.pool is not None else None)
        keys = self.keys[jnp.asarray(slot_ids)]
        use_prefix = bool(prefix.any())
        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths))
        prefix_dev = jnp.asarray(prefix)
        prev_cache = self.cache        # pre-admission state, kept for retry

        def attempt(fa):
            if use_prefix:
                return self._prefill_prefix(
                    args[0], args[1], prev_cache, args[2], args[3], keys,
                    tables, prefix_dev, fa)
            return self._prefill(
                args[0], args[1], prev_cache, args[2], args[3], keys,
                tables, fa)

        f = fault if fault is not None else ModelFault.none()
        meta = self._take_injection_meta("admit_fault") \
            if fault is not None else None
        with self._tr.span("prefill", {"rows": len(admitted),
                                       "tokens": int(lengths.sum())}) as sp:
            first, new_cache, flag, nkeys = attempt(f)
            sp.fence(first, flag)
        with self._tr.span("abft_check", {"phase": "prefill"}):
            faulted = bool(flag)
        if faulted:
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "prefill"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                # clean retry from the PRE-admission cache — never from the
                # possibly-corrupted attempt (mirrors decode's prev_cache);
                # same keys, so the retry resamples the same token
                with self._tr.span("abft_retry",
                                   {"phase": "prefill"}) as sp:
                    first, new_cache, flag, nkeys = attempt(
                        ModelFault.none())
                    sp.fence(first, flag)
                if not bool(flag):
                    break
            if meta is not None:
                self._record_injection(
                    meta, "prefill",
                    "uncorrected" if bool(flag) else "corrected")
            if bool(flag):
                # persistent fault: evict the admission batch with recorded
                # errors instead of retrying it forever (livelock fix).
                # _release drops refcounts only — a shared prefix block a
                # LIVE request still references stays resident
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault", {"phase": "prefill"})
                for slot, r in zip(slot_ids, admitted):
                    self._finish(r, "hard_fault:prefill", evict=True)
                    self._release(int(slot))
                return batch.consumed
        elif meta is not None:
            outcome, extra = ("undetected", {})
            if self.classify_injections:
                s_first, s_cache, _, _ = attempt(ModelFault.none())
                outcome, extra = self._shadow_outcome(
                    first, new_cache, (s_first, s_cache))
            self._record_injection(meta, "prefill", outcome, **extra)

        self.cache = new_cache
        self.keys = self.keys.at[jnp.asarray(slot_ids)].set(nkeys)
        # admit-time monolithic prefill is a prefill-only "step" in the
        # selection trace: the whole-prompt token mass lands in one call
        # (exactly the composition the chunked scheduler bounds)
        self._observe_step_mix(0, int(lengths.sum()))
        first = np.asarray(first)
        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slot_ids, admitted)):
            req.generated.append(int(first[i]))
            req.times.append(now)
            self.stats.tokens += 1
            self.stats.prompt_tokens_total += int(full_lens[i])
            self.stats.prefix_tokens_shared += int(prefix[i])
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)           # budget met at prefill: the
                self._release(int(slot))    # request never occupies a slot
                continue
            self.active[int(slot)] = req
            self.pos[int(slot)] = int(full_lens[i])
            if self.index is not None:
                # register only AFTER the flag read back clean: the index
                # must never name blocks holding a faulty attempt's data
                self.index.add(req.prompt, self.pool.tables[int(slot)])
        return batch.consumed

    # ------------------------------------------------------------ decoding
    def step(self, fault: ModelFault | None = None) -> dict:
        """One engine step.  Returns {uid: token} for decoded slots.

        Unchunked: one decode step for all active slots (admission
        already prefilled them whole).  Chunked (``chunk_tokens`` set):
        one *budgeted* step — all resident decode tokens first, then the
        leftover budget is filled with prefill chunks from the cursor
        queue (see module docstring).

        With a ``fault_model`` attached and no explicit ``fault``, the
        campaign process is polled for this step's injection (an
        explicit fault takes precedence and leaves the campaign clock
        untouched).  An adaptive policy re-evaluates the protection
        level from the observed fault rates BEFORE the step executes."""
        before = self.stats.steps
        t0 = time.perf_counter()
        self._maybe_adapt()
        if fault is None and self.fault_model is not None:
            ev = self.fault_model.poll()
            if ev is not None:
                fault = ev.model_fault
                self._injection_meta = {"source": "campaign",
                                        **ev.describe()}
        if self.chunk_tokens is not None:
            out = self._step_chunked(fault)
        else:
            out = self._serve_core(fault)
            if self.stats.steps > before:
                self._observe_step_mix(self._last_decode_tokens, 0)
        # a fault that found no executing call this step (idle engine)
        # corrupted nothing — drop its unclaimed metadata
        self._injection_meta = None
        if self.telemetry is not None:
            if self.stats.steps > before:
                self.telemetry.observe_step_latency(
                    time.perf_counter() - t0)
            self._sync_telemetry()
        return out

    def _observe_step_mix(self, decode_tokens: int,
                          prefill_tokens: int) -> None:
        """Record THIS step's intensity-guided (composition, intensity,
        scheme) decision via the plan's cached per-step fast path
        (``plan.for_step``).  The representative dims are the widest
        per-token projection (d_model x d_ff — per-shard under TP); the
        jitted calls re-resolve the scheme per GEMM shape at trace time
        anyway — this records the step-level decision those shapes
        imply."""
        if decode_tokens + prefill_tokens == 0:
            return
        sel = self.plan.for_step(decode_tokens, prefill_tokens)
        self.stats.observe_selection(decode_tokens, prefill_tokens,
                                     sel.arithmetic_intensity,
                                     sel.scheme_name)
        if self._last_scheme is not None and \
                sel.scheme_name != self._last_scheme:
            # the paper's §5.3 decision changed regime between steps —
            # exported as an instant event so a Perfetto timeline shows
            # WHERE the serving mix crossed the CMR boundary
            self.stats.scheme_flips += 1
            self._tr.instant("scheme_flip", {
                "intensity": sel.arithmetic_intensity,
                "scheme": sel.scheme_name,
                "decode": decode_tokens, "prefill": prefill_tokens,
                "model_parallel": self.model_parallel,
            })
        self._last_scheme = sel.scheme_name

    def _retune_chunk_budget(self) -> None:
        """Auto-budget re-tuning as slot occupancy drifts: the budget
        floor tracks resident decode tokens (decode packs first — the
        floor guarantees prefill a quantum of progress every step),
        while the CMR target keeps full mixed steps compute-bound
        whenever the step geometry can reach it."""
        budget = self.plan.tune_chunk_budget(
            decode_tokens=len(self.active), lo=8, hi=self.max_len)
        if budget != self.chunk_tokens:
            self.chunk_tokens = budget
            self.stats.chunk_budget_retunes += 1

    def _plan_chunks(self, budget: int) -> list:
        return self.scheduler.plan_chunks(budget)

    def _step_chunked(self, fault: ModelFault | None = None) -> dict:
        """One budgeted mixed step: decode tokens are packed first (every
        resident stream advances every step — the starvation guarantee),
        then prefill chunks fill ``chunk_tokens - n_decode``.  An injected
        step fault lands on the prefill chunk when one is scheduled, else
        on the decode call — each call retries independently, so a chunk
        fault re-executes ONLY that chunk."""
        if self.chunk_auto:
            self._retune_chunk_budget()
        n_decode = len(self.active)
        rows = self.scheduler.plan_chunks(
            max(0, self.chunk_tokens - n_decode))
        prefill_tokens = sum(take for _, _, take, _ in rows)
        chunk_fault = fault if rows else None
        decode_fault = fault if not rows else None

        out = {}
        steps_before = self.stats.steps
        self._last_decode_tokens = 0
        if self.active:
            out = self._serve_core(decode_fault)
        if rows:
            committed = self._run_prefill_chunk(rows, chunk_fault)
            if not committed:
                prefill_tokens = 0     # discarded: never actually served
            if self.stats.steps == steps_before:
                # the chunk ran even if decode didn't (no actives, or the
                # growth guard evicted them all before executing) — count
                # the step so run()'s fault_at disarm check sees it and
                # never re-injects a fault this chunk already consumed
                self.stats.steps += 1
        if self.stats.steps > steps_before:
            self._observe_step_mix(self._last_decode_tokens,
                                   prefill_tokens)
        return out

    def _run_prefill_chunk(self, rows: list,
                           fault: ModelFault | None) -> bool:
        """Execute one co-scheduled prefill-chunk batch (host side of the
        chunk state machine).  Cursor/table state mutates only outside
        the attempt/retry window; a detected fault re-executes the chunk
        from the pre-chunk cache — earlier chunks and this step's decode
        are never re-run.  Returns True when the chunk committed, False
        when a persistent fault discarded it (the batch was evicted and
        its tokens were never served)."""
        A = len(rows)
        slot_list = [s for s, _, _, _ in rows]
        # pending admission-campaign fault: consumed by the first chunk
        # batch containing the target (one fault per jitted call — if a
        # step fault is already routed here, the campaign entry is
        # retired rather than left to linger past the target's prefill)
        pending_src = False
        if self._pending_prefill_fault is not None:
            uid, pf = self._pending_prefill_fault
            if any(cur.req.uid == uid for _, cur, _, _ in rows):
                if fault is None:
                    fault = pf
                    pending_src = True
                self._pending_prefill_fault = None
        meta = None
        if fault is not None:
            meta = self._take_injection_meta(
                "admit_fault" if pending_src else "manual")

        Apad = _pad_rows(A, self.slots)
        Lpad = min(_pad_len(max(take for _, _, take, _ in rows)),
                   self.max_len)
        toks = np.zeros((Apad, Lpad), np.int32)
        slot_ids = np.full((Apad,), slot_list[0], np.int32)
        lengths = np.zeros((Apad,), np.int32)
        starts = np.zeros((Apad,), np.int32)
        final = np.zeros((Apad,), bool)
        for i, (slot, cur, take, fin) in enumerate(rows):
            toks[i, :take] = cur.req.prompt[cur.filled:cur.filled + take]
            slot_ids[i] = slot
            lengths[i] = take
            starts[i] = cur.filled
            final[i] = fin
        # padding rows alias row 0's slot with lengths == 0: their cache
        # writes route to the drop sentinel and their sampled token / key
        # advance are masked by ``final`` — pure shape ballast so the jit
        # cache is keyed by (row bucket, length bucket) only

        tables = (self.pool.device_tables(slot_ids)
                  if self.pool is not None else None)
        keys = self.keys[jnp.asarray(slot_ids)]
        prev_cache = self.cache        # pre-chunk state, kept for retry
        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths), jnp.asarray(starts),
                jnp.asarray(final))

        def attempt(fa):
            return self._prefill_chunk(
                args[0], args[1], prev_cache, args[2], args[3], keys,
                tables, args[4], args[5], fa)

        f = fault if fault is not None else ModelFault.none()
        retry_f = f if (meta is not None
                        and meta.get("kind") == "permanent") \
            else ModelFault.none()
        with self._tr.span(
                "prefill_chunk",
                {"rows": A,
                 "tokens": int(sum(t for _, _, t, _ in rows))}) as sp:
            first, new_cache, flag, nkeys = attempt(f)
            sp.fence(first, flag)
        with self._tr.span("abft_check", {"phase": "prefill_chunk"}):
            faulted = bool(flag)
        if faulted:
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "prefill_chunk"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                self.stats.chunk_retries += 1
                with self._tr.span("abft_retry",
                                   {"phase": "prefill_chunk"}) as sp:
                    first, new_cache, flag, nkeys = attempt(retry_f)
                    sp.fence(first, flag)
                if not bool(flag):
                    break
            if meta is not None:
                self._record_injection(
                    meta, "prefill_chunk",
                    "uncorrected" if bool(flag) else "corrected")
            if bool(flag):
                # persistent chunk fault: evict ONLY this chunk batch's
                # requests (their earlier chunks die with their blocks —
                # refcounts protect any shared prefix a live sharer
                # holds); the committed cache stays pre-chunk
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault",
                                 {"phase": "prefill_chunk"})
                for slot, cur, _, _ in rows:
                    self._finish(cur.req, "hard_fault:prefill", evict=True)
                    del self._prefill_cursors[slot]
                    self._release(slot)
                    if self._pending_prefill_fault is not None and \
                            self._pending_prefill_fault[0] == cur.req.uid:
                        self._pending_prefill_fault = None  # target gone
                return False
        elif meta is not None:
            outcome, extra = ("undetected", {})
            if self.classify_injections:
                s_first, s_cache, _, _ = attempt(ModelFault.none())
                outcome, extra = self._shadow_outcome(
                    first, new_cache, (s_first, s_cache))
            self._record_injection(meta, "prefill_chunk", outcome,
                                   **extra)

        self.cache = new_cache
        self.keys = self.keys.at[jnp.asarray(slot_list)].set(
            jnp.asarray(nkeys)[:A])
        self.stats.prefill_chunks += A
        first = np.asarray(first)
        now = time.perf_counter()
        for i, (slot, cur, take, fin) in enumerate(rows):
            cur.filled += take
            self.pos[slot] = cur.filled
            if not fin:
                continue
            req = cur.req
            req.generated.append(int(first[i]))
            req.times.append(now)
            self.stats.tokens += 1
            self.stats.prompt_tokens_total += cur.total
            self.stats.prefix_tokens_shared += cur.prefix
            del self._prefill_cursors[slot]
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)          # budget met at prefill
                self._release(slot)
                continue
            self.active[slot] = req
            if self.index is not None:
                self.index.add(req.prompt, self.pool.tables[slot])
        return True

    def _decode_core(self, fault: ModelFault | None = None) -> dict:
        """One decode step for all active slots.  Returns {uid: token}."""
        # paged growth/COW guard runs on the scheduler BEFORE the jitted
        # step (tables stable across the attempt/retry window); the COW
        # payload moves it plans are committed here on device
        self._copy_cow_blocks(self.scheduler.grow_for_decode())
        if not self.active:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for s, req in self.active.items():
            toks[s, 0] = req.generated[-1]
            mask[s] = True
        pos = jnp.asarray(self.pos)            # (slots,) vectorized cursor
        tables = (self.pool.device_tables()
                  if self.pool is not None else None)
        f = fault if fault is not None else ModelFault.none()
        meta = self._take_injection_meta("manual") \
            if fault is not None else None
        # a sticky permanent fault models a faulty UNIT: it corrupts the
        # retry exactly like the attempt (retry cannot clear it — the
        # detect->recompute loop's transient-fault assumption breaks,
        # which is the 2205.12177 detection gap this campaign mode
        # exercises); transient/manual faults retry clean as before
        retry_f = f if (meta is not None
                        and meta.get("kind") == "permanent") \
            else ModelFault.none()

        prev_cache = self.cache
        prev_keys = self.keys
        with self._tr.span("decode_step",
                           {"tokens": len(self.active)}) as sp:
            nxt, new_cache, flag, nkeys = self._decode(
                self.params, jnp.asarray(toks), prev_cache, pos,
                jnp.asarray(mask), prev_keys, tables, f)
            sp.fence(nxt, flag)
        self.stats.steps += 1
        if self.pool is not None:
            # per-step occupancy samples: benchmarks report mean/median/
            # peak blocks_used (the paged capacity win) without poking
            # mid-run
            self.stats.observe_blocks_used(self.pool.blocks_used)
            self.stats.blocks_shared_peak = max(
                self.stats.blocks_shared_peak, self.pool.blocks_shared)
        with self._tr.span("abft_check", {"phase": "decode"}):
            faulted = bool(flag)
        if faulted:
            # ABFT detection -> recompute from pre-step state (clean run,
            # same per-slot keys: the retry resamples the same token)
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "decode"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                with self._tr.span("abft_retry",
                                   {"phase": "decode"}) as sp:
                    nxt, new_cache, flag, nkeys = self._decode(
                        self.params, jnp.asarray(toks), prev_cache, pos,
                        jnp.asarray(mask), prev_keys, tables, retry_f)
                    sp.fence(nxt, flag)
                if not bool(flag):
                    break
            if meta is not None:
                self._record_injection(
                    meta, "decode",
                    "uncorrected" if bool(flag) else "corrected")
            if bool(flag):
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault", {"phase": "decode"})
                if not self.policy.evict_on_hard_fault:
                    raise RuntimeError("persistent fault after retry")
                # the flag is step-global: every in-flight request may be
                # corrupted, so evict them all with recorded errors and
                # keep the engine alive for subsequent admissions (shared
                # blocks survive as long as ANY sharer was admitted later
                # with live references — refcounts gate the free list)
                for s, req in list(self.active.items()):
                    self._finish(req, "hard_fault:decode", evict=True)
                    del self.active[s]
                    self._release(s)
                return {}
        elif meta is not None:
            # UNDETECTED injection: shadow-stream comparison — re-run
            # the same call clean from the pre-step state and compare.
            # The faulted result stays committed (realistic propagation);
            # only the classification consumes the shadow.
            outcome, extra = ("undetected", {})
            if self.classify_injections:
                s_nxt, s_cache, _, _ = self._decode(
                    self.params, jnp.asarray(toks), prev_cache, pos,
                    jnp.asarray(mask), prev_keys, tables,
                    ModelFault.none())
                outcome, extra = self._shadow_outcome(
                    nxt, new_cache, (s_nxt, s_cache))
            self._record_injection(meta, "decode", outcome, **extra)
        self.cache = new_cache
        self.keys = nkeys

        out = {}
        nxt = np.asarray(nxt)
        finished = []
        now = time.perf_counter()
        for s, req in list(self.active.items()):
            t = int(nxt[s])
            req.generated.append(t)
            req.times.append(now)
            self.pos[s] += 1
            out[req.uid] = t
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)
                finished.append(s)
        for s in finished:
            del self.active[s]
            self._release(s)
        self._last_decode_tokens = len(out)
        return out

    # ------------------------------------------------- speculative decoding
    def _serve_core(self, fault: ModelFault | None = None) -> dict:
        """Route one resident-slot step: the speculative verify core
        when a proposer is attached, else plain decode.  Leaves
        ``_last_decode_tokens`` holding the step's actual decode-side
        token count (window tokens for verify) for the intensity
        observation — with speculation on, a verify step scores K+1
        tokens per slot and the per-step scheme selection must see that
        multiplied intensity."""
        self._last_decode_tokens = 0
        if self.spec is not None:
            return self._verify_core(fault)
        return self._decode_core(fault)

    def _retune_draft_len(self) -> None:
        """Auto draft-length re-tuning as slot occupancy drifts: the
        roofline K depends on how many slots share the verify step
        (batch multiplies its token count), so the knob re-tunes from
        live occupancy exactly like the chunk budget.  While escalated,
        the adaptive policy's ``shrink_draft`` tightens it further."""
        k = max(1, self.plan.tune_draft_len(
            batch=max(1, len(self.active))))
        if self.adaptive is not None and self.protection_level \
                and self.adaptive.shrink_draft < 1.0:
            k = max(1, int(k * self.adaptive.shrink_draft))
        self.draft_len = k

    def _verify_core(self, fault: ModelFault | None = None) -> dict:
        """One speculative verify step for all active slots: propose up
        to ``draft_len`` tokens per slot (clamped so a window never
        overruns the slot's remaining token budget), score all K_s+1
        positions in ONE jitted ``verify`` call through the same
        ABFT-checked path as decode, then accept host-side — greedy:
        longest draft prefix matching the per-position argmax targets
        plus one bonus target (provably the unsped engine's stream,
        byte for byte); sampling: the rejection rule (exact in law).

        Fault handling is the chunk-retry machinery in verify flavor: a
        detected fault re-executes ONLY this draft window from the
        pre-step cache/keys — the per-slot cursors never moved, so
        rollback to the last accepted position is simply "don't
        advance" — and a sticky permanent exhausts the retry budget and
        evicts as decode does.  Returns {uid: last emitted token}."""
        if self.draft_auto:
            self._retune_draft_len()
        proposals: dict = {}
        for s, req in sorted(self.active.items()):
            budget = min(self.draft_len,
                         req.max_new_tokens - len(req.generated) - 1)
            d = (np.asarray(self.spec.propose(req, budget), np.int32)
                 if budget > 0 else np.zeros((0,), np.int32))
            proposals[s] = d[:max(0, budget)]
            self.stats.draft_proposed += len(proposals[s])
        # paged growth/COW guard over the WHOLE window (tables frozen
        # across the attempt/retry window, same as decode)
        self._copy_cow_blocks(self.scheduler.grow_for_verify(
            {s: len(d) for s, d in proposals.items()}))
        if not self.active:
            return {}
        T = self.draft_len + 1
        toks = np.zeros((self.slots, T), np.int32)
        mask = np.zeros((self.slots,), bool)
        valid = np.zeros((self.slots,), np.int32)
        for s, req in self.active.items():
            d = proposals[s]
            toks[s, 0] = req.generated[-1]
            toks[s, 1:1 + len(d)] = d
            mask[s] = True
            valid[s] = len(d) + 1
        window_tokens = int(valid.sum())
        pos = jnp.asarray(self.pos)
        tables = (self.pool.device_tables()
                  if self.pool is not None else None)
        f = fault if fault is not None else ModelFault.none()
        meta = self._take_injection_meta("manual") \
            if fault is not None else None
        retry_f = f if (meta is not None
                        and meta.get("kind") == "permanent") \
            else ModelFault.none()

        prev_cache = self.cache
        prev_keys = self.keys
        dev = (jnp.asarray(toks), jnp.asarray(mask), jnp.asarray(valid))

        def attempt(fa):
            return self._verify(self.params, dev[0], prev_cache, pos,
                                dev[1], dev[2], prev_keys, tables, fa)

        with self._tr.span("verify_step",
                           {"tokens": window_tokens,
                            "draft_len": self.draft_len}) as sp:
            logits, new_cache, flag, nkeys = attempt(f)
            sp.fence(logits, flag)
        self.stats.steps += 1
        if self.pool is not None:
            self.stats.observe_blocks_used(self.pool.blocks_used)
            self.stats.blocks_shared_peak = max(
                self.stats.blocks_shared_peak, self.pool.blocks_shared)
        with self._tr.span("abft_check", {"phase": "verify"}):
            faulted = bool(flag)
        if faulted:
            self.stats.faults_detected += 1
            self._tr.instant("fault_detected", {"phase": "verify"})
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                self.stats.verify_retries += 1
                with self._tr.span("abft_retry",
                                   {"phase": "verify"}) as sp:
                    logits, new_cache, flag, nkeys = attempt(retry_f)
                    sp.fence(logits, flag)
                if not bool(flag):
                    break
            if meta is not None:
                self._record_injection(
                    meta, "verify",
                    "uncorrected" if bool(flag) else "corrected")
            if bool(flag):
                self.stats.hard_faults += 1
                self._tr.instant("hard_fault", {"phase": "verify"})
                if not self.policy.evict_on_hard_fault:
                    raise RuntimeError("persistent fault after retry")
                for s, req in list(self.active.items()):
                    self._finish(req, "hard_fault:verify", evict=True)
                    del self.active[s]
                    self._release(s)
                return {}
        elif meta is not None:
            outcome, extra = ("undetected", {})
            if self.classify_injections:
                s_logits, s_cache, _, _ = attempt(ModelFault.none())
                outcome, extra = self._shadow_outcome(
                    logits, new_cache, (s_logits, s_cache))
            self._record_injection(meta, "verify", outcome, **extra)
        self.cache = new_cache
        self.keys = nkeys

        out = {}
        logits = np.asarray(logits)
        finished = []
        now = time.perf_counter()
        for s, req in list(self.active.items()):
            d = proposals[s]
            rows = logits[s, :len(d) + 1]
            if self.temperature <= 0.0:
                targets = np.argmax(rows, axis=-1).astype(np.int32)
                emitted = greedy_accept(d, targets)
            else:
                emitted = rejection_sample(
                    d, target_probs(rows, self.temperature, self.top_k),
                    prev_keys[s])
            self.stats.draft_accepted += len(emitted) - 1
            for t in emitted:
                req.generated.append(int(t))
                req.times.append(now)
                self.stats.tokens += 1
            self.pos[s] += len(emitted)
            out[req.uid] = int(emitted[-1])
            if len(req.generated) >= req.max_new_tokens:
                self._finish(req)
                finished.append(s)
        for s in finished:
            del self.active[s]
            self._release(s)
        self._last_decode_tokens = window_tokens
        return out

    def run(self, requests: list, fault_at: tuple | None = None,
            admit_fault_at: tuple | None = None) -> dict:
        """Drive admission + decode to completion (continuous batching).

        ``fault_at``: (step_idx, ModelFault) decode-step injection —
        armed from that step index on, it fires at the first step that
        actually decodes (a step with no active slots re-arms the
        injection for the next real step instead of silently dropping
        it); ``admit_fault_at``: (uid, ModelFault) injected into the
        admission batch that contains that request uid (campaign hooks).
        Where an armed fault actually LANDED — the executed engine step
        and phase (decode / prefill_chunk / prefill), plus its detection
        outcome — is recorded in ``stats.injection_log`` (one entry per
        executed injection, ``source="fault_at"`` with the armed step
        index) instead of being consumed silently.

        Results are collected from the engine's finished-event queue —
        O(1) amortized per request — instead of rescanning every request
        each step (the seed's O(requests x steps) done-scan)."""
        pending = list(requests)
        results = {
            r.uid: r.generated for r in requests if r.done}  # pre-done edge
        self._drain_finished()
        step_i = 0
        step_fault_armed = fault_at is not None
        while pending or self.active or self._prefill_cursors:
            if pending and self.free_slots():
                if admit_fault_at is not None:
                    uid, afault = admit_fault_at
                    consumed = self.admit(pending, fault=afault,
                                          fault_uid=uid)
                    # consumed exactly once: only when the target actually
                    # went through prefill (not filtered out beforehand)
                    if any(r.uid == uid
                           and r.error not in PRE_PREFILL_ERRORS
                           and r.max_new_tokens > 0
                           for r in consumed):
                        admit_fault_at = None
                else:
                    self.admit(pending)
            fault = None
            if step_fault_armed and step_i >= fault_at[0]:
                fault = fault_at[1]
                # placement ground truth: the landing site records the
                # executed step + phase in stats.injection_log
                self._injection_meta = {
                    "source": "fault_at", "kind": "manual",
                    "armed_step": fault_at[0], "run_step": step_i}
            steps_before = self.stats.steps
            self.step(fault)
            if fault is not None and self.stats.steps > steps_before:
                step_fault_armed = False     # injection hit a real step
            step_i += 1
            for req in self._drain_finished():
                if req.uid not in results:
                    results[req.uid] = req.generated
        return results

    # ------------------------------------------------------------ stats
    def cache_stats(self) -> dict:
        """Cache geometry + occupancy, without poking at private pytrees.

        Common keys: ``kind``, ``slots``, ``max_len``, ``bytes_total``
        (allocated cache bytes across all layers), ``tokens_capacity``
        (cache entries the allocation can hold), ``active_tokens`` (sum
        of live cursors), ``utilization``, ``fragmentation``,
        ``blocks_shared``, and ``prefix_hit_rate``.

        Paged ``utilization`` divides live logical tokens by *allocated*
        tokens (``blocks_used * block_size``) — NOT total pool capacity,
        which hid internal fragmentation behind an always-small ratio.
        ``fragmentation`` is its complement: the allocated-but-unfilled
        share (partial last blocks).  Under prefix sharing, logical
        tokens can exceed allocated tokens (several slots count the same
        shared block), so utilization may exceed 1.0 — that excess IS the
        sharing win.  Paged engines also report ``block_size`` /
        ``blocks_total`` / ``blocks_used`` / ``blocks_free`` /
        ``tokens_allocated``."""
        stats = {
            "kind": self.cache_kind,
            "slots": self.slots,
            "max_len": self.max_len,
            "bytes_total": pytree_bytes(self.cache),
            "active_tokens": int(
                sum(int(self.pos[s]) for s in self.active)
                + sum(int(self.pos[s]) for s in self._prefill_cursors)),
        }
        if self.pool is not None:
            allocated = self.pool.blocks_used * self.pool.block_size
            stats.update(
                block_size=self.pool.block_size,
                blocks_total=self.pool.num_blocks,
                blocks_used=self.pool.blocks_used,
                blocks_free=self.pool.blocks_free,
                blocks_shared=self.pool.blocks_shared,
                tokens_capacity=self.pool.num_blocks
                * self.pool.block_size,
                tokens_allocated=allocated,
            )
        else:
            stats["tokens_capacity"] = self.slots * self.max_len
            stats["tokens_allocated"] = stats["tokens_capacity"]
            stats["blocks_shared"] = 0
        alloc = stats["tokens_allocated"]
        stats["utilization"] = stats["active_tokens"] / alloc if alloc else 0.0
        stats["fragmentation"] = (
            max(0.0, 1.0 - stats["utilization"]) if alloc else 0.0)
        stats["prefix_hit_rate"] = self.stats.prefix_hit_rate
        return stats
