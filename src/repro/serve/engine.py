"""Serving engine: continuous-batched decode with ABFT detect->recompute
recovery, built around a **vectorized per-slot position cursor**.

The engine owns a fixed-capacity slot table (the batch dimension of the KV
cache).  Every slot carries its own write cursor ``pos[s]``; the decode
step passes the full ``(slots,)`` cursor vector to ``model.decode`` so each
slot writes its new KV entry at its *own* offset and attends only its own
valid prefix.  This is what makes mixed-length traffic correct: two
requests with different prompt lengths share a batch without ever touching
each other's cache rows (the seed engine collapsed cursors to a scalar
``max(pos)`` and corrupted exactly this case).

Engine API
----------
``admit(pending)``
    Batched admission: up to ``len(free_slots())`` requests are prefetched
    from the front of ``pending``, padded to a common length, and prefilled
    in ONE model call **directly into their engine cache rows** (per-slot
    scatter + per-row length masking — no 1-deep temp cache or splice).
    Each consumed request is admitted, finished (``max_new_tokens`` already
    satisfied by the prefill-sampled token), or evicted with ``error`` set
    (over-long prompt, persistent prefill fault).  Returns the number of
    requests consumed so the caller can always make progress (no livelock
    on a hard-faulting head request).

``step(fault=None)``
    One decode step for all active slots.  Tokens are chosen by a
    slot-masked argmax inside the jitted step, so inactive slots never
    contribute a sampled token; their cache rows are dead until the next
    admission overwrites them.

``run(requests, fault_at=None, admit_fault_at=None)``
    Drives admission + decode to completion.  ``fault_at=(step, fault)``
    injects a campaign fault into one decode step; ``admit_fault_at=
    (uid, fault)`` injects into the admission batch containing that uid.

Recovery policy
---------------
``RecoveryPolicy`` makes the paper's detect->recompute loop explicit:

  * a detected fault re-executes the step from the pre-step cache state
    (``prev_cache`` is held until the flag is read back) up to
    ``max_retries`` times — prefill retries likewise restart from the
    pre-admission cache, never from the possibly-corrupted attempt;
  * if the flag persists, the fault is *hard*: with
    ``evict_on_hard_fault`` (default) the affected requests are evicted
    with ``error`` recorded and the engine keeps serving, otherwise a
    ``RuntimeError`` is raised (the seed behavior).

Token budget: ``max_new_tokens`` counts every generated token *including*
the one sampled at prefill, so ``max_new_tokens=N`` yields exactly N new
tokens (``N-1`` decode steps) — a request satisfied at admission never
occupies a slot.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int           # budget of generated tokens (incl. the
                                  # prefill-sampled first token)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None      # set when evicted (hard fault, too long)


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """ABFT detect->recompute policy (see module docstring)."""

    max_retries: int = 1           # clean re-executions after a detection
    evict_on_hard_fault: bool = True   # evict + record error vs raise


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    faults_detected: int = 0
    retries: int = 0
    hard_faults: int = 0
    evictions: int = 0


def _pad_len(n: int) -> int:
    """Bucket prefill lengths to multiples of 8 to bound jit recompiles."""
    return max(8, -(-n // 8) * 8)


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 abft: ABFTConfig = ABFTConfig(), dtype=jnp.bfloat16,
                 greedy: bool = True, hints=None,
                 policy: RecoveryPolicy = RecoveryPolicy()):
        assert slots >= 1
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.abft = abft
        self.ctx = LayerCtx(abft=abft, hints=hints)
        self.policy = policy
        self.stats = EngineStats()
        self.cache = model.init_cache(slots, max_len, dtype=dtype)
        self.pos = np.zeros((slots,), np.int32)      # per-slot write cursor
        self.active: dict = {}                        # slot -> Request
        self.greedy = greedy

        def _decode_step(p, tok, cache, pos, mask, fault):
            logits, new_cache, flag = model.decode(
                p, tok, cache, pos,
                dataclasses.replace(self.ctx, fault=fault))
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            # slot-masked argmax: inactive slots never emit a token
            nxt = jnp.where(mask, nxt, jnp.int32(-1))
            return nxt, new_cache, flag

        def _prefill_step(p, toks, cache, slot_ids, lengths, fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths)
            first = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return first, new_cache, flag

        self._decode = jax.jit(_decode_step)
        self._prefill = jax.jit(_prefill_step)

    # ------------------------------------------------------------ admission
    def free_slots(self) -> list:
        return [s for s in range(self.slots) if s not in self.active]

    def admit(self, pending: list, fault: ModelFault | None = None,
              fault_uid: int | None = None) -> int:
        """Batched admission (see module docstring).  Consumes up to
        ``len(free_slots())`` requests from the front of ``pending`` and
        returns how many were consumed — every consumed request ends up
        active, done, or evicted with ``error`` set, so the caller always
        progresses.  ``fault``/``fault_uid``: campaign injection applied
        only when the targeted request actually reaches prefill."""
        free = self.free_slots()
        batch = pending[:min(len(free), len(pending))]
        if not batch:
            return 0

        admitted = []
        for req in batch:
            if req.max_new_tokens <= 0:
                req.done = True              # zero budget: nothing to do
            # the prompt plus the decode budget must fit in the cache rows
            elif len(req.prompt) + max(req.max_new_tokens - 1, 0) > \
                    self.max_len:
                req.error = "prompt_too_long"
                req.done = True
                self.stats.evictions += 1
            else:
                admitted.append(req)
        if not admitted:
            return len(batch)
        if fault is not None and fault_uid is not None and not any(
                r.uid == fault_uid for r in admitted):
            fault = None    # campaign target never reached prefill

        slot_ids = np.asarray(free[:len(admitted)], np.int32)
        lengths = np.asarray([len(r.prompt) for r in admitted], np.int32)
        # admissible prompts always fit (budget check above), so clamping
        # the bucketed pad to max_len keeps the scatter in bounds
        Lpad = min(_pad_len(int(lengths.max())), self.max_len)
        toks = np.zeros((len(admitted), Lpad), np.int32)
        for i, r in enumerate(admitted):
            toks[i, : len(r.prompt)] = r.prompt

        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths))
        prev_cache = self.cache        # pre-admission state, kept for retry
        f = fault if fault is not None else ModelFault.none()
        first, new_cache, flag = self._prefill(
            args[0], args[1], prev_cache, args[2], args[3], f)
        if bool(flag):
            self.stats.faults_detected += 1
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                # clean retry from the PRE-admission cache — never from the
                # possibly-corrupted attempt (mirrors decode's prev_cache)
                first, new_cache, flag = self._prefill(
                    args[0], args[1], prev_cache, args[2], args[3],
                    ModelFault.none())
                if not bool(flag):
                    break
            if bool(flag):
                # persistent fault: evict the admission batch with recorded
                # errors instead of retrying it forever (livelock fix)
                self.stats.hard_faults += 1
                for r in admitted:
                    r.error = "hard_fault:prefill"
                    r.done = True
                    self.stats.evictions += 1
                return len(batch)

        self.cache = new_cache
        first = np.asarray(first)
        for i, (slot, req) in enumerate(zip(slot_ids, admitted)):
            req.generated.append(int(first[i]))
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True             # budget met at prefill: the
                continue                    # request never occupies a slot
            self.active[int(slot)] = req
            self.pos[int(slot)] = int(lengths[i])
        return len(batch)

    # ------------------------------------------------------------ decoding
    def step(self, fault: ModelFault | None = None) -> dict:
        """One decode step for all active slots.  Returns {uid: token}."""
        if not self.active:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for s, req in self.active.items():
            toks[s, 0] = req.generated[-1]
            mask[s] = True
        pos = jnp.asarray(self.pos)            # (slots,) vectorized cursor
        f = fault if fault is not None else ModelFault.none()

        prev_cache = self.cache
        nxt, new_cache, flag = self._decode(
            self.params, jnp.asarray(toks), prev_cache, pos,
            jnp.asarray(mask), f)
        self.stats.steps += 1
        if bool(flag):
            # ABFT detection -> recompute from pre-step state (clean run)
            self.stats.faults_detected += 1
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                nxt, new_cache, flag = self._decode(
                    self.params, jnp.asarray(toks), prev_cache, pos,
                    jnp.asarray(mask), ModelFault.none())
                if not bool(flag):
                    break
            if bool(flag):
                self.stats.hard_faults += 1
                if not self.policy.evict_on_hard_fault:
                    raise RuntimeError("persistent fault after retry")
                # the flag is step-global: every in-flight request may be
                # corrupted, so evict them all with recorded errors and
                # keep the engine alive for subsequent admissions
                for s, req in list(self.active.items()):
                    req.error = "hard_fault:decode"
                    req.done = True
                    self.stats.evictions += 1
                    del self.active[s]
                    self.pos[s] = 0
                return {}
        self.cache = new_cache

        out = {}
        nxt = np.asarray(nxt)
        finished = []
        for s, req in list(self.active.items()):
            t = int(nxt[s])
            req.generated.append(t)
            self.pos[s] += 1
            out[req.uid] = t
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished.append(s)
        for s in finished:
            del self.active[s]
            self.pos[s] = 0
        return out

    def run(self, requests: list, fault_at: tuple | None = None,
            admit_fault_at: tuple | None = None) -> dict:
        """Drive admission + decode to completion (continuous batching).

        ``fault_at``: (step_idx, ModelFault) decode-step injection;
        ``admit_fault_at``: (uid, ModelFault) injected into the admission
        batch that contains that request uid (campaign hooks)."""
        pending = list(requests)
        results = {}
        step_i = 0
        while pending or self.active:
            if pending and self.free_slots():
                if admit_fault_at is not None:
                    uid, afault = admit_fault_at
                    n = self.admit(pending, fault=afault, fault_uid=uid)
                    # consumed exactly once: only when the target actually
                    # went through prefill (not filtered out beforehand)
                    if any(r.uid == uid and r.error != "prompt_too_long"
                           and r.max_new_tokens > 0
                           for r in pending[:n]):
                        admit_fault_at = None
                else:
                    n = self.admit(pending)
                del pending[:n]
            fault = None
            if fault_at is not None and step_i == fault_at[0]:
                fault = fault_at[1]
            self.step(fault)
            step_i += 1
            for req in requests:
                if req.done and req.uid not in results:
                    results[req.uid] = req.generated
        return results
