"""Serving engine: continuous-batched decode with ABFT detect->recompute
recovery, built around a **vectorized per-slot position cursor** and an
optional **paged KV cache** (block-table memory manager).

The engine owns a fixed-capacity slot table (the batch dimension of the KV
cache).  Every slot carries its own write cursor ``pos[s]``; the decode
step passes the full ``(slots,)`` cursor vector to ``model.decode`` so each
slot writes its new KV entry at its *own* offset and attends only its own
valid prefix.  This is what makes mixed-length traffic correct: two
requests with different prompt lengths share a batch without ever touching
each other's cache rows (the seed engine collapsed cursors to a scalar
``max(pos)`` and corrupted exactly this case).

Cache kinds
-----------
``cache_kind="dense"`` (default): every slot owns a dense ``(max_len,)``
cache row — one long request makes the whole batch pay max-length memory.

``cache_kind="paged"``: attention KV lives in fixed-size blocks drawn from
a shared pool (serve/paged_cache.py).  Blocks are allocated at admission
(prompt length only), grown one block at a time as decode crosses block
boundaries, and returned to the free list when a request finishes or is
evicted — including hard-fault eviction under ``RecoveryPolicy``.  Pool
exhaustion never crashes: a request that could NEVER fit is rejected with
``error="oom:block_pool"``; one that merely hit transient pressure
(blocks held by in-flight requests) is deferred at the head of the queue
until decode frees blocks; a slot whose mid-decode growth cannot be
covered is evicted with ``error="oom:kv_blocks"``.
Token streams are identical to the dense engine under greedy decoding
(block-size divides max_len => identical attention shapes); the allocation
is what changes: ``cache_stats()`` reports pool bytes ≪ slots × max_len
when prompt lengths are skewed.

Engine API
----------
``admit(pending)``
    Batched admission: up to ``len(free_slots())`` requests are prefetched
    from the front of ``pending``, padded to a common length, and prefilled
    in ONE model call **directly into their engine cache rows** (per-slot
    scatter + per-row length masking — no 1-deep temp cache or splice).
    Each consumed request is admitted, finished (``max_new_tokens`` already
    satisfied by the prefill-sampled token), or evicted with ``error`` set
    (over-long prompt, pool exhaustion, persistent prefill fault).
    Returns the number of requests consumed so the caller can always make
    progress (no livelock on a hard-faulting head request).

``step(fault=None)``
    One decode step for all active slots.  Tokens are chosen by a
    slot-masked sampler inside the jitted step — greedy argmax by default,
    or temperature/top-k sampling driven by a ``(slots,)`` per-slot PRNG
    key vector (each slot owns an independent key stream, advanced only
    on *accepted* steps so a fault retry resamples the same token).

``run(requests, fault_at=None, admit_fault_at=None)``
    Drives admission + decode to completion.  ``fault_at=(step, fault)``
    injects a campaign fault into one decode step; ``admit_fault_at=
    (uid, fault)`` injects into the admission batch containing that uid.

``cache_stats()``
    Cache geometry/occupancy introspection (kind, bytes, block pool
    usage) so benchmarks and tests never poke at private pytrees.

Recovery policy
---------------
``RecoveryPolicy`` makes the paper's detect->recompute loop explicit:

  * a detected fault re-executes the step from the pre-step cache state
    (``prev_cache`` is held until the flag is read back) up to
    ``max_retries`` times — prefill retries likewise restart from the
    pre-admission cache, never from the possibly-corrupted attempt.
    Under paging this stays sound because pool updates are functional
    and the host block tables are mutated only *outside* the
    attempt/retry window (alloc/growth before the step, frees after);
  * if the flag persists, the fault is *hard*: with
    ``evict_on_hard_fault`` (default) the affected requests are evicted
    with ``error`` recorded (their blocks returned to the free list) and
    the engine keeps serving, otherwise a ``RuntimeError`` is raised
    (the seed behavior).

Token budget: ``max_new_tokens`` counts every generated token *including*
the one sampled at prefill, so ``max_new_tokens=N`` yields exactly N new
tokens (``N-1`` decode steps) — a request satisfied at admission never
occupies a slot.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protected import ABFTConfig
from repro.models.layers import LayerCtx, ModelFault
from repro.models.model import Model
from repro.serve.paged_cache import BlockPool, pytree_bytes


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int           # budget of generated tokens (incl. the
                                  # prefill-sampled first token)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None      # set when evicted (hard fault, too long,
                                  # block-pool exhaustion)


# errors set before a request ever reaches prefill (admission screening)
PRE_PREFILL_ERRORS = ("prompt_too_long", "oom:block_pool")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """ABFT detect->recompute policy (see module docstring)."""

    max_retries: int = 1           # clean re-executions after a detection
    evict_on_hard_fault: bool = True   # evict + record error vs raise


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    faults_detected: int = 0
    retries: int = 0
    hard_faults: int = 0
    evictions: int = 0


def _pad_len(n: int) -> int:
    """Bucket prefill lengths to multiples of 8 to bound jit recompiles."""
    return max(8, -(-n // 8) * 8)


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 abft: ABFTConfig = ABFTConfig(), dtype=jnp.bfloat16,
                 hints=None,
                 policy: RecoveryPolicy = RecoveryPolicy(),
                 cache_kind: str = "dense", block_size: int = 16,
                 num_blocks: int | None = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        assert slots >= 1
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.abft = abft
        self.ctx = LayerCtx(abft=abft, hints=hints)
        self.policy = policy
        self.stats = EngineStats()
        self.pos = np.zeros((slots,), np.int32)      # per-slot write cursor
        self.active: dict = {}                        # slot -> Request
        self.cache_kind = cache_kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        # per-slot PRNG key vector: each slot samples from its own stream
        self.keys = jax.random.split(jax.random.PRNGKey(seed), slots)

        if cache_kind == "paged":
            width = -(-max_len // block_size)         # blocks covering max_len
            if num_blocks is None:
                num_blocks = slots * width            # dense-equivalent pool
            self.pool: BlockPool | None = BlockPool(
                num_blocks, block_size, slots, width)
            self.cache = model.init_paged_cache(
                slots, num_blocks, block_size, dtype=dtype)
        elif cache_kind == "dense":
            self.pool = None
            self.cache = model.init_cache(slots, max_len, dtype=dtype)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        def _advance(keys):
            """Split each slot key into (sample, next) — a no-op pair in
            greedy mode so the jitted graph stays key-free."""
            if self.temperature <= 0.0:
                return keys, keys
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            return ks[:, 0], ks[:, 1]

        def _sample(logits, keys):
            """logits: (n, V) -> (n,) int32 token ids."""
            if self.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            lg = logits.astype(jnp.float32) / self.temperature
            if self.top_k > 0:
                # clamp to the vocab: an oversized --top-k is "no cutoff",
                # not a crash inside the jitted step
                k = min(self.top_k, lg.shape[-1])
                kth = jax.lax.top_k(lg, k)[0][..., -1:]
                lg = jnp.where(lg < kth, jnp.float32(-1e30), lg)
            return jax.vmap(jax.random.categorical)(keys, lg).astype(
                jnp.int32)

        def _decode_step(p, tok, cache, pos, mask, keys, tables, fault):
            logits, new_cache, flag = model.decode(
                p, tok, cache, pos,
                dataclasses.replace(self.ctx, fault=fault),
                block_tables=tables)
            sub, nkeys = _advance(keys)
            nxt = _sample(logits[:, 0, :], sub)
            # slot-masked sampling: inactive slots never emit a token,
            # and their key streams stay untouched — a slot's sampling
            # sequence depends only on its own accepted steps, never on
            # unrelated engine activity
            nxt = jnp.where(mask, nxt, jnp.int32(-1))
            nkeys = jnp.where(mask[:, None], nkeys, keys)
            return nxt, new_cache, flag, nkeys

        def _prefill_step(p, toks, cache, slot_ids, lengths, keys, tables,
                          fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            return first, new_cache, flag, nkeys

        self._decode = jax.jit(_decode_step)
        self._prefill = jax.jit(_prefill_step)

    # ------------------------------------------------------------ admission
    def free_slots(self) -> list:
        return [s for s in range(self.slots) if s not in self.active]

    def _release(self, slot: int) -> None:
        """Return a slot's cache memory (paged: blocks to the free list)."""
        if self.pool is not None:
            self.pool.free_slot(slot)
        self.pos[slot] = 0

    def admit(self, pending: list, fault: ModelFault | None = None,
              fault_uid: int | None = None) -> int:
        """Batched admission (see module docstring).  Consumes up to
        ``len(free_slots())`` requests from the front of ``pending`` and
        returns how many were consumed — every consumed request ends up
        active, done, or evicted with ``error`` set, so the caller always
        progresses.  ``fault``/``fault_uid``: campaign injection applied
        only when the targeted request actually reaches prefill."""
        from repro.serve.paged_cache import blocks_for

        free = self.free_slots()
        batch = pending[:min(len(free), len(pending))]
        if not batch:
            return 0

        admitted, slot_list = [], []
        consumed = 0
        for req in batch:
            if req.max_new_tokens <= 0:
                req.done = True              # zero budget: nothing to do
                consumed += 1
                continue
            # the prompt plus the decode budget must fit in the cache rows
            if len(req.prompt) + max(req.max_new_tokens - 1, 0) > \
                    self.max_len:
                req.error = "prompt_too_long"
                req.done = True
                self.stats.evictions += 1
                consumed += 1
                continue
            slot = free[len(slot_list)]
            if self.pool is not None:
                # paged admission: blocks for the prompt are claimed up
                # front (decode growth is on-demand).  A request that can
                # NEVER fit is rejected with a recorded error; a request
                # that merely hit transient pressure (blocks held by
                # in-flight requests) is DEFERRED — left at the head of
                # ``pending`` to admit once decode frees blocks.  No
                # livelock: deferral with an empty engine is impossible
                # (a full free list that still cannot cover the prompt
                # means never-fits), so something is always decoding and
                # eventually freeing.
                if not self.pool.try_alloc(slot, len(req.prompt)):
                    if blocks_for(len(req.prompt), self.pool.block_size) \
                            > self.pool.num_blocks:
                        req.error = "oom:block_pool"
                        req.done = True
                        self.stats.evictions += 1
                        consumed += 1
                        continue
                    break                    # transient: defer the rest
            admitted.append(req)
            slot_list.append(slot)
            consumed += 1
        if not admitted:
            return consumed
        if fault is not None and fault_uid is not None and not any(
                r.uid == fault_uid for r in admitted):
            fault = None    # campaign target never reached prefill

        slot_ids = np.asarray(slot_list, np.int32)
        lengths = np.asarray([len(r.prompt) for r in admitted], np.int32)
        # admissible prompts always fit (budget check above), so clamping
        # the bucketed pad to max_len keeps the scatter in bounds
        Lpad = min(_pad_len(int(lengths.max())), self.max_len)
        toks = np.zeros((len(admitted), Lpad), np.int32)
        for i, r in enumerate(admitted):
            toks[i, : len(r.prompt)] = r.prompt

        tables = (self.pool.device_tables(slot_ids)
                  if self.pool is not None else None)
        keys = self.keys[jnp.asarray(slot_ids)]
        args = (self.params, jnp.asarray(toks), jnp.asarray(slot_ids),
                jnp.asarray(lengths))
        prev_cache = self.cache        # pre-admission state, kept for retry
        f = fault if fault is not None else ModelFault.none()
        first, new_cache, flag, nkeys = self._prefill(
            args[0], args[1], prev_cache, args[2], args[3], keys, tables, f)
        if bool(flag):
            self.stats.faults_detected += 1
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                # clean retry from the PRE-admission cache — never from the
                # possibly-corrupted attempt (mirrors decode's prev_cache);
                # same keys, so the retry resamples the same token
                first, new_cache, flag, nkeys = self._prefill(
                    args[0], args[1], prev_cache, args[2], args[3], keys,
                    tables, ModelFault.none())
                if not bool(flag):
                    break
            if bool(flag):
                # persistent fault: evict the admission batch with recorded
                # errors instead of retrying it forever (livelock fix)
                self.stats.hard_faults += 1
                for slot, r in zip(slot_ids, admitted):
                    r.error = "hard_fault:prefill"
                    r.done = True
                    self.stats.evictions += 1
                    self._release(int(slot))
                return consumed

        self.cache = new_cache
        self.keys = self.keys.at[jnp.asarray(slot_ids)].set(nkeys)
        first = np.asarray(first)
        for i, (slot, req) in enumerate(zip(slot_ids, admitted)):
            req.generated.append(int(first[i]))
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True             # budget met at prefill: the
                self._release(int(slot))    # request never occupies a slot
                continue
            self.active[int(slot)] = req
            self.pos[int(slot)] = int(lengths[i])
        return consumed

    # ------------------------------------------------------------ decoding
    def step(self, fault: ModelFault | None = None) -> dict:
        """One decode step for all active slots.  Returns {uid: token}."""
        if self.pool is not None:
            # on-demand growth: claim the block the cursor is about to
            # enter BEFORE the jitted step (tables must be stable across
            # the attempt/retry window); a slot that cannot grow is
            # evicted with a recorded error, freeing blocks for the rest
            for s in sorted(self.active):
                if not self.pool.try_grow(s, int(self.pos[s]) + 1):
                    req = self.active.pop(s)
                    req.error = "oom:kv_blocks"
                    req.done = True
                    self.stats.evictions += 1
                    self._release(s)
        if not self.active:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        mask = np.zeros((self.slots,), bool)
        for s, req in self.active.items():
            toks[s, 0] = req.generated[-1]
            mask[s] = True
        pos = jnp.asarray(self.pos)            # (slots,) vectorized cursor
        tables = (self.pool.device_tables()
                  if self.pool is not None else None)
        f = fault if fault is not None else ModelFault.none()

        prev_cache = self.cache
        prev_keys = self.keys
        nxt, new_cache, flag, nkeys = self._decode(
            self.params, jnp.asarray(toks), prev_cache, pos,
            jnp.asarray(mask), prev_keys, tables, f)
        self.stats.steps += 1
        if bool(flag):
            # ABFT detection -> recompute from pre-step state (clean run,
            # same per-slot keys: the retry resamples the same token)
            self.stats.faults_detected += 1
            for _ in range(self.policy.max_retries):
                self.stats.retries += 1
                nxt, new_cache, flag, nkeys = self._decode(
                    self.params, jnp.asarray(toks), prev_cache, pos,
                    jnp.asarray(mask), prev_keys, tables, ModelFault.none())
                if not bool(flag):
                    break
            if bool(flag):
                self.stats.hard_faults += 1
                if not self.policy.evict_on_hard_fault:
                    raise RuntimeError("persistent fault after retry")
                # the flag is step-global: every in-flight request may be
                # corrupted, so evict them all with recorded errors and
                # keep the engine alive for subsequent admissions
                for s, req in list(self.active.items()):
                    req.error = "hard_fault:decode"
                    req.done = True
                    self.stats.evictions += 1
                    del self.active[s]
                    self._release(s)
                return {}
        self.cache = new_cache
        self.keys = nkeys

        out = {}
        nxt = np.asarray(nxt)
        finished = []
        for s, req in list(self.active.items()):
            t = int(nxt[s])
            req.generated.append(t)
            self.pos[s] += 1
            out[req.uid] = t
            self.stats.tokens += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished.append(s)
        for s in finished:
            del self.active[s]
            self._release(s)
        return out

    def run(self, requests: list, fault_at: tuple | None = None,
            admit_fault_at: tuple | None = None) -> dict:
        """Drive admission + decode to completion (continuous batching).

        ``fault_at``: (step_idx, ModelFault) decode-step injection;
        ``admit_fault_at``: (uid, ModelFault) injected into the admission
        batch that contains that request uid (campaign hooks)."""
        pending = list(requests)
        results = {}
        step_i = 0
        while pending or self.active:
            if pending and self.free_slots():
                if admit_fault_at is not None:
                    uid, afault = admit_fault_at
                    n = self.admit(pending, fault=afault, fault_uid=uid)
                    # consumed exactly once: only when the target actually
                    # went through prefill (not filtered out beforehand)
                    if any(r.uid == uid
                           and r.error not in PRE_PREFILL_ERRORS
                           and r.max_new_tokens > 0
                           for r in pending[:n]):
                        admit_fault_at = None
                else:
                    n = self.admit(pending)
                del pending[:n]
            fault = None
            if fault_at is not None and step_i == fault_at[0]:
                fault = fault_at[1]
            self.step(fault)
            step_i += 1
            for req in requests:
                if req.done and req.uid not in results:
                    results[req.uid] = req.generated
        return results

    # ------------------------------------------------------------ stats
    def cache_stats(self) -> dict:
        """Cache geometry + occupancy, without poking at private pytrees.

        Common keys: ``kind``, ``slots``, ``max_len``, ``bytes_total``
        (allocated cache bytes across all layers), ``tokens_capacity``
        (cache entries the allocation can hold), ``active_tokens`` (sum
        of live cursors) and ``utilization``.  Paged engines add
        ``block_size`` / ``blocks_total`` / ``blocks_used`` /
        ``blocks_free``."""
        stats = {
            "kind": self.cache_kind,
            "slots": self.slots,
            "max_len": self.max_len,
            "bytes_total": pytree_bytes(self.cache),
            "active_tokens": int(sum(
                int(self.pos[s]) for s in self.active)),
        }
        if self.pool is not None:
            stats.update(
                block_size=self.pool.block_size,
                blocks_total=self.pool.num_blocks,
                blocks_used=self.pool.blocks_used,
                blocks_free=self.pool.blocks_free,
                tokens_capacity=self.pool.num_blocks
                * self.pool.block_size,
            )
        else:
            stats["tokens_capacity"] = self.slots * self.max_len
        stats["utilization"] = (
            stats["active_tokens"] / max(stats["tokens_capacity"], 1))
        return stats
