"""Speculative-decoding subsystem: draft proposers + acceptance rules.

The serving engine speculates K tokens per slot per step, then scores
all K+1 positions in ONE jitted ``verify`` call through the existing
dense/paged cache paths (``ModelRunner.verify`` -> ``Model.verify``).
This module owns everything around that call that is NOT device glue:

* ``DraftProposer`` — the protocol the engine drives.  Two
  dependency-free implementations ship:

  - ``NGramProposer``: prompt-lookup drafting.  Match the longest
    trailing n-gram of ``prompt + generated`` against its own history
    and propose the K tokens that followed the most recent earlier
    occurrence.  Pure numpy, zero model cost — the classic
    "prompt-lookup decoding" baseline.
  - ``SelfDraftProposer``: self-draft via truncated decode.  Greedy
    continuation from a depth-truncated copy of the SAME weights (the
    first ``units`` scan units) over a fixed trailing context window —
    no draft KV cache, no second parameter set.

* Acceptance — ``greedy_accept`` (longest matching prefix + bonus
  token; provably reproduces the unsped greedy stream byte-for-byte,
  see the invariant below) and ``rejection_sample`` (standard
  speculative sampling against a point-mass draft distribution; exact
  in law w.r.t. the target distribution).

Correctness invariant (greedy).  Verify row j of a slot scores input
token x_j at logical position pos+j, where x_0 is the last committed
token and x_{j+1} = drafts[j]; its argmax t_j is EXACTLY the token the
unsped engine would emit at that position PROVIDED x_1..x_j each
matched the preceding target — which is precisely the acceptance
condition.  Induction over the accepted prefix gives byte-identical
streams.  Draft quality therefore affects THROUGHPUT only, never
output: a bad proposer degenerates to plain decode (one emitted token
per step), which is also why proposers run unprotected — the
ABFT-checked verify step is the integrity boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import LayerCtx, norm
from repro.models.model import Model, run_stack

_EMPTY = np.zeros((0,), np.int32)


@runtime_checkable
class DraftProposer(Protocol):
    """Anything the engine can ask for draft tokens.

    ``propose`` may return FEWER than ``k`` tokens (including zero — the
    slot then degenerates to a plain single-token verify); it must never
    return more."""

    name: str

    def propose(self, req, k: int) -> np.ndarray:  # (<= k,) int32
        ...


# ------------------------------------------------------------- proposers

class NGramProposer:
    """Prompt-lookup drafting: longest-suffix n-gram match over the
    request's own token history (prompt + generated), newest occurrence
    wins, proposing the K tokens that followed it."""

    name = "ngram"

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError("need 1 <= min_n <= max_n")
        self.max_n = int(max_n)
        self.min_n = int(min_n)

    def propose(self, req, k: int) -> np.ndarray:
        if k <= 0:
            return _EMPTY
        hist = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.generated, np.int32)])
        for n in range(self.max_n, self.min_n - 1, -1):
            if len(hist) <= n:
                continue
            tail = hist[-n:]
            windows = np.lib.stride_tricks.sliding_window_view(hist, n)
            # exclude the trailing window (it IS the tail)
            hits = np.nonzero((windows[:-1] == tail).all(axis=1))[0]
            if hits.size:
                # newest occurrence wins, but prefer one with a full
                # K-token continuation in history: a periodic tail
                # otherwise matches itself near the end and strands the
                # proposal at a single token
                full = hits[hits + n + k <= len(hist)]
                i = int(full[-1] if full.size else hits[-1]) + n
                return hist[i:i + k].astype(np.int32)
        return _EMPTY


class SelfDraftProposer:
    """Self-draft via truncated decode: greedy K-step continuation using
    only the first ``units`` scan units of the SAME weights over a fixed
    ``window`` of trailing context.  Stateless — no draft KV cache to
    keep coherent across rollbacks, at the price of re-reading the
    window each draft step.  ``params_fn`` defers to the engine's live
    (possibly sharded) parameters."""

    name = "self_draft"

    def __init__(self, model: Model, ctx: LayerCtx, params_fn, *,
                 units: int = 1, window: int = 8):
        self.model = model
        self.window = int(window)
        self._params_fn = params_fn
        take = max(1, int(units))
        plan = []
        for seg in model.plan:
            if take <= 0:
                break
            reps = min(seg.repeats, take)
            plan.append(dataclasses.replace(seg, repeats=reps))
            take -= reps
        self._plan = plan
        cfg = model.cfg

        def _draft(params, toks, positions, k):
            segs = [
                jax.tree_util.tree_map(lambda a, r=seg.repeats: a[:r], sp)
                for seg, sp in zip(self._plan, params["segments"])
            ]

            def one(carry, _):
                t, p = carry
                x = params["embed"][t][None]          # (1, W, D)
                h, _, _, _ = run_stack(
                    x, segs, self._plan, cfg, ctx, p[None], "full",
                    None, None, None)
                h = norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
                logits, _ = model._head(params, h[:, -1:, :], ctx)
                nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
                return (jnp.concatenate([t[1:], nxt[None]]), p + 1), nxt

            (_, _), drafts = jax.lax.scan(
                one, (toks, positions), None, length=k)
            return drafts

        self._draft = jax.jit(_draft, static_argnums=3)

    def propose(self, req, k: int) -> np.ndarray:
        if k <= 0:
            return _EMPTY
        hist = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.generated, np.int32)])
        w = self.window
        toks = np.zeros((w,), np.int32)
        n = min(w, len(hist))
        toks[w - n:] = hist[-n:]
        start = len(hist) - w
        positions = np.maximum(np.arange(start, start + w), 0)
        out = self._draft(
            self._params_fn(), jnp.asarray(toks),
            jnp.asarray(positions, jnp.int32), int(k))
        return np.asarray(out, np.int32)


# ------------------------------------------------------------ acceptance

def greedy_accept(drafts: np.ndarray, targets: np.ndarray) -> list:
    """Greedy acceptance: ``targets[j]`` is the argmax of verify row j
    (= the token the unsped engine emits after x_0..x_j), ``drafts`` the
    proposed window.  Accept the longest prefix where each draft equals
    the preceding target, then emit one bonus target — a+1 tokens for a
    accepted drafts, K+1 when everything matched."""
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(targets[a]):
        a += 1
    return [int(t) for t in targets[:a + 1]]


def target_probs(logits: np.ndarray, temperature: float,
                 top_k: int = 0) -> np.ndarray:
    """Rows of verify logits -> the engine's sampling distribution
    (temperature + optional top-k cutoff), f64 normalized."""
    lg = np.asarray(logits, np.float64) / max(float(temperature), 1e-8)
    if top_k > 0:
        k = min(int(top_k), lg.shape[-1])
        kth = np.sort(lg, axis=-1)[..., -k][..., None]
        lg = np.where(lg < kth, -np.inf, lg)
    lg -= lg.max(axis=-1, keepdims=True)
    p = np.exp(lg)
    return p / p.sum(axis=-1, keepdims=True)


def rejection_sample(drafts: np.ndarray, probs: np.ndarray,
                     key) -> list:
    """Speculative sampling against a deterministic (point-mass) draft
    distribution: accept draft d at row j with probability p_j(d); on
    rejection emit a sample from p_j with d removed and renormalized
    (the residual of the standard rejection rule when q is a point
    mass); after a fully accepted window emit a bonus token from the
    last row.  Exact in law: each emitted token is distributed as its
    row's target distribution.  ``key`` is the slot's PRNG key; draws
    are ``fold_in``-derived so the verify retry path redraws nothing."""
    emitted = []
    for j in range(len(drafts)):
        d = int(drafts[j])
        pj = probs[j]
        u = float(jax.random.uniform(jax.random.fold_in(key, 2 * j)))
        if u < float(pj[d]):
            emitted.append(d)
            continue
        resid = np.array(pj)
        resid[d] = 0.0
        tot = float(resid.sum())
        if tot <= 0.0:                       # p was a point mass at d
            emitted.append(int(np.argmax(pj)))
        else:
            emitted.append(int(jax.random.choice(
                jax.random.fold_in(key, 2 * j + 1),
                pj.shape[-1], p=jnp.asarray(resid / tot))))
        return emitted
    pj = probs[len(drafts)]
    emitted.append(int(jax.random.choice(
        jax.random.fold_in(key, 2 * len(drafts) + 1),
        pj.shape[-1], p=jnp.asarray(pj))))
    return emitted


def make_proposer(spec, model: Model, ctx: LayerCtx, params_fn,
                  *, units: int = 1, window: int = 8) -> DraftProposer:
    """Engine-facing factory: a string ("ngram" | "self_draft") or an
    already-built proposer instance."""
    if isinstance(spec, str):
        name = spec.replace("-", "_")
        if name in ("ngram", "prompt_lookup"):
            return NGramProposer()
        if name == "self_draft":
            return SelfDraftProposer(model, ctx, params_fn,
                                     units=units, window=window)
        raise ValueError(f"unknown draft proposer {spec!r} "
                         "(want 'ngram' or 'self_draft')")
    if not hasattr(spec, "propose"):
        raise TypeError("spec_decode must be a proposer name or an "
                        "object with a .propose(req, k) method")
    return spec
