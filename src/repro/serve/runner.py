"""Model-runner layer of the serving engine (executor-hierarchy
refactor).

One ``ModelRunner`` owns the five jitted device entry points the
engine drives — ``decode``, ``prefill``, ``prefill_prefix``,
``prefill_chunk``, ``verify`` — plus the slot-masked sampler they
share.  The
runner is pure device-side glue: it holds no request state, no slot
table, and no cache (the executor owns params/cache/keys; the
scheduler owns the host bookkeeping).  Under a ``MeshExecutor`` the
SAME jitted functions run SPMD: the committed shardings of the params
and cache arguments drive GSPMD propagation, so the runner needs no
mesh awareness at all — that is the point of the layering.

Sampling contract (unchanged from the monolith): greedy argmax keeps
the jitted graph key-free; with ``temperature > 0`` each slot owns an
independent PRNG key stream advanced only on *accepted* steps, so a
fault retry resamples the same token and inactive slots never consume
entropy.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import LayerCtx
from repro.models.model import Model


class ModelRunner:
    """Jitted prefill/decode entry points for one model + layer context.

    Attributes ``decode`` / ``prefill`` / ``prefill_prefix`` /
    ``prefill_chunk`` / ``verify`` are the compiled callables; their
    signatures are exactly the old engine closures' (params first,
    fault last)."""

    def __init__(self, model: Model, ctx: LayerCtx, *,
                 temperature: float = 0.0, top_k: int = 0):
        self.model = model
        self.ctx = ctx
        self.temperature = float(temperature)
        self.top_k = int(top_k)

        def _advance(keys):
            """Split each slot key into (sample, next) — a no-op pair in
            greedy mode so the jitted graph stays key-free."""
            if self.temperature <= 0.0:
                return keys, keys
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            return ks[:, 0], ks[:, 1]

        def _sample(logits, keys):
            """logits: (n, V) -> (n,) int32 token ids."""
            if self.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            lg = logits.astype(jnp.float32) / self.temperature
            if self.top_k > 0:
                # clamp to the vocab: an oversized --top-k is "no cutoff",
                # not a crash inside the jitted step
                k = min(self.top_k, lg.shape[-1])
                kth = jax.lax.top_k(lg, k)[0][..., -1:]
                lg = jnp.where(lg < kth, jnp.float32(-1e30), lg)
            return jax.vmap(jax.random.categorical)(keys, lg).astype(
                jnp.int32)

        def _decode_step(p, tok, cache, pos, mask, keys, tables, fault):
            logits, new_cache, flag = model.decode(
                p, tok, cache, pos,
                dataclasses.replace(self.ctx, fault=fault),
                block_tables=tables)
            sub, nkeys = _advance(keys)
            nxt = _sample(logits[:, 0, :], sub)
            # slot-masked sampling: inactive slots never emit a token,
            # and their key streams stay untouched — a slot's sampling
            # sequence depends only on its own accepted steps, never on
            # unrelated engine activity
            nxt = jnp.where(mask, nxt, jnp.int32(-1))
            nkeys = jnp.where(mask[:, None], nkeys, keys)
            return nxt, new_cache, flag, nkeys

        def _prefill_step(p, toks, cache, slot_ids, lengths, keys, tables,
                          fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            return first, new_cache, flag, nkeys

        def _prefill_prefix_step(p, toks, cache, slot_ids, lengths, keys,
                                 tables, prefix_lens, fault):
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables,
                prefix_lens=prefix_lens)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            return first, new_cache, flag, nkeys

        def _prefill_chunk_step(p, toks, cache, slot_ids, lengths, keys,
                                tables, starts, final_mask, fault):
            """One co-scheduled prefill chunk: rows are mid-prompt chunks
            whose logical positions begin at ``starts``.  Only rows whose
            chunk COMPLETES the prompt (``final_mask``) emit their first
            sampled token and advance their key stream — so a prompt's
            sampling sequence is identical however it was chunked."""
            logits, new_cache, flag = model.prefill(
                p, {"tokens": toks}, cache,
                dataclasses.replace(self.ctx, fault=fault),
                slots=slot_ids, lengths=lengths, block_tables=tables,
                prefix_lens=starts)
            sub, nkeys = _advance(keys)
            first = _sample(logits[:, 0, :], sub)
            first = jnp.where(final_mask, first, jnp.int32(-1))
            nkeys = jnp.where(final_mask[:, None], nkeys, keys)
            return first, new_cache, flag, nkeys

        def _verify_step(p, toks, cache, pos, mask, valid, keys, tables,
                         fault):
            """Speculative batched verify: score T = K+1 positions per
            slot in ONE call.  ``toks`` (B, T) holds each row's last
            committed token followed by its padded draft window;
            ``valid`` (B,) is the usable window size per row (K_slot+1).
            Returns ALL T logits rows (f32) — greedy targets and
            rejection-sampling probabilities are derived host-side by
            the acceptance loop, so the device graph stays sampling-free
            and the greedy byte-equality contract reduces to per-row
            logits bit-equality with the unsped decode step.  Key
            streams advance once per ACCEPTED verify step (masked rows
            keep theirs), mirroring ``_decode_step``; a fault retry
            therefore redraws nothing."""
            logits, new_cache, flag = model.verify(
                p, toks, cache, pos,
                dataclasses.replace(self.ctx, fault=fault),
                valid, block_tables=tables)
            _, nkeys = _advance(keys)
            nkeys = jnp.where(mask[:, None], nkeys, keys)
            return logits, new_cache, flag, nkeys

        self.decode = jax.jit(_decode_step)
        self.prefill = jax.jit(_prefill_step)
        self.prefill_prefix = jax.jit(_prefill_prefix_step)
        self.prefill_chunk = jax.jit(_prefill_chunk_step)
        self.verify = jax.jit(_verify_step)
