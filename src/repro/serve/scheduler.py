"""Scheduler layer of the serving engine (executor-hierarchy refactor).

Host-side request/slot/block bookkeeping, split out of the old
``ServeEngine`` monolith:

  * the ``Request`` / ``ChunkCursor`` lifecycle records and the
    ``EngineStats`` counters;
  * the fixed-capacity slot table with its per-slot position cursors;
  * admission screening — budget/length checks, paged block allocation,
    prefix-index matching + COW planning, and the bounded head-of-line
    lookahead — as one pure-host pass (``select_admission``) that never
    touches the model;
  * the chunked-prefill cursor queue (``park_prefill`` /
    ``plan_chunks``);
  * the paged decode-step growth guard (``grow_for_decode``): claim the
    next block / COW a shared block BEFORE the jitted step so tables are
    stable across the attempt/retry window, evicting slots that cannot
    grow.

Everything here is host state, mutated strictly outside the jitted
attempt/retry window — the same discipline the block tables always had.
Device work (jitted entry points, sharded params/cache) lives in
``serve/runner.py`` and ``serve/executor.py``; the ``ServeEngine``
facade (serve/engine.py) orchestrates the three layers and carries the
retry policy across them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.paged_cache import BlockPool, PrefixIndex, blocks_for


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int           # budget of generated tokens (incl. the
                                  # prefill-sampled first token)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None      # set when evicted (hard fault, too long,
                                  # block-pool exhaustion)
    # wall-clock perf_counter() stamp per generated token (benchmarks
    # derive TTFT / inter-token-latency percentiles from these)
    times: list = dataclasses.field(default_factory=list, repr=False)


@dataclasses.dataclass
class ChunkCursor:
    """Resumable prefill state of one admitted-but-not-yet-decoding
    request under the chunked-prefill scheduler: ``prompt[:filled]`` is
    resident in the cache (including any shared prefix), the rest still
    has to be prefilled in token-budgeted chunks.  Host-only state —
    mutated strictly outside the jitted attempt/retry window, like the
    block tables."""

    req: Request
    total: int                    # len(prompt)
    filled: int                   # logical tokens already resident
    prefix: int                   # shared-prefix tokens (stats accounting)


# errors set before a request ever reaches prefill (admission screening)
PRE_PREFILL_ERRORS = ("prompt_too_long", "oom:block_pool")


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """ABFT detect->recompute policy (see serve/engine.py docstring)."""

    max_retries: int = 1           # clean re-executions after a detection
    evict_on_hard_fault: bool = True   # evict + record error vs raise


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    faults_detected: int = 0
    retries: int = 0
    hard_faults: int = 0
    evictions: int = 0         # resident requests that lost their slot
    rejections: int = 0        # screened out before prefill (never resident)
    # prefix sharing
    prompt_tokens_total: int = 0
    prefix_tokens_shared: int = 0
    cow_copies: int = 0
    # chunked prefill
    prefill_chunks: int = 0    # prompt-chunks executed (one per row per step)
    chunk_retries: int = 0     # clean re-executions of a faulted chunk only
    chunk_budget_retunes: int = 0  # auto-budget changes as occupancy drifts
    mixed_steps: int = 0       # steps carrying decode AND prefill tokens
    decode_only_steps: int = 0
    prefill_only_steps: int = 0
    # speculative decoding
    draft_proposed: int = 0    # draft tokens offered to verify steps
    draft_accepted: int = 0    # draft tokens the verify step accepted
    verify_retries: int = 0    # clean re-executions of a faulted verify
    #                            window only (subset of ``retries``)
    # per-step intensity-guided selection trace: one entry per executed
    # step, {"step", "decode", "prefill", "intensity", "scheme"} — the
    # serving-time record of the paper's §5.3 decision re-made from each
    # step's ACTUAL token composition.  Bounded by the same deterministic
    # stride decimation as the occupancy samples.
    selection_trace: list = dataclasses.field(default_factory=list)
    selection_count: int = 0
    selection_stride: int = 1
    # steps whose intensity-guided selection differs from the previous
    # step's (the regime crossings telemetry emits as instant events)
    scheme_flips: int = 0
    # fault-campaign classification (shadow-stream harness): every
    # injected fault — campaign OR hand-armed — is classified by outcome.
    # faults_injected = corrected + uncorrected + sdc + masked once the
    # step resolves; sdc (silent data corruption: undetected AND the
    # shadow clean re-execution disagrees) is the number the protection
    # stack exists to hold at zero.
    faults_injected: int = 0
    faults_corrected: int = 0      # detected, retry re-executed clean
    faults_uncorrected: int = 0    # detected, persisted through retries
    sdc_faults: int = 0            # undetected, outputs provably corrupt
    masked_faults: int = 0         # undetected, outputs provably clean
    # adaptive protection (ErrorAdaptivePolicy) level changes
    protection_escalations: int = 0
    protection_deescalations: int = 0
    # ground truth on injection placement: one entry per injected fault,
    # {"engine_step", "phase", "source", "kind", "layer", "site", "row",
    #  "col", "bit", "outcome"} — what run()'s fault_at disarm used to
    # consume silently.  Bounded like the occupancy samples.
    injection_log: list = dataclasses.field(default_factory=list)
    injections_dropped: int = 0    # log entries lost to the bound
    # per-step pool occupancy aggregates (one observation per executed
    # decode step on a paged engine).  The mean is exact (sum/count); the
    # median comes from a BOUNDED sample list kept small by deterministic
    # stride decimation, so a long-lived serving engine never accumulates
    # unbounded per-step state
    blocks_used_sum: int = 0
    blocks_used_count: int = 0
    blocks_used_samples: list = dataclasses.field(default_factory=list)
    blocks_used_stride: int = 1
    blocks_used_peak: int = 0
    blocks_shared_peak: int = 0

    MAX_OCCUPANCY_SAMPLES = 4096

    def observe_blocks_used(self, used: int) -> None:
        self.blocks_used_sum += used
        self.blocks_used_count += 1
        self.blocks_used_peak = max(self.blocks_used_peak, used)
        if self.blocks_used_count % self.blocks_used_stride == 0:
            self.blocks_used_samples.append(used)
            if len(self.blocks_used_samples) > self.MAX_OCCUPANCY_SAMPLES:
                # halve the sampling rate.  Keep the ODD indices: entry k
                # was recorded at observation (k+1)*stride, so [1::2]
                # retains exactly the even multiples of the old stride —
                # the multiples of the DOUBLED stride — and the
                # "entry k <=> observation (k+1)*stride" alignment
                # survives every decimation round ([::2] kept the odd
                # multiples, which the new stride can never produce)
                self.blocks_used_samples = self.blocks_used_samples[1::2]
                self.blocks_used_stride *= 2

    def observe_selection(self, decode: int, prefill: int,
                          intensity: float, scheme: str) -> None:
        """Record one step's (composition, intensity, scheme) decision."""
        if decode and prefill:
            self.mixed_steps += 1
        elif prefill:
            self.prefill_only_steps += 1
        else:
            self.decode_only_steps += 1
        self.selection_count += 1
        if self.selection_count % self.selection_stride == 0:
            self.selection_trace.append({
                "step": self.steps, "decode": decode, "prefill": prefill,
                "intensity": intensity, "scheme": scheme,
            })
            if len(self.selection_trace) > self.MAX_OCCUPANCY_SAMPLES:
                # decimation keeps the ODD indices (see
                # observe_blocks_used): trace[k] stays the observation
                # numbered (k+1)*selection_stride after ANY number of
                # rounds, so downstream consumers can reconstruct true
                # observation indices from (k, stride) alone
                self.selection_trace = self.selection_trace[1::2]
                self.selection_stride *= 2

    _OUTCOME_COUNTER = {
        "corrected": "faults_corrected",
        "uncorrected": "faults_uncorrected",
        "sdc": "sdc_faults",
        "masked": "masked_faults",
    }

    def record_injection(self, entry: dict) -> None:
        """Classify one injected fault (see ``injection_log``).  The
        outcome counters are the telemetry-facing aggregate; the log is
        the per-fault ground truth campaigns replay-check against."""
        self.faults_injected += 1
        attr = self._OUTCOME_COUNTER.get(entry.get("outcome"))
        if attr is not None:
            setattr(self, attr, getattr(self, attr) + 1)
        if len(self.injection_log) < self.MAX_OCCUPANCY_SAMPLES:
            self.injection_log.append(entry)
        else:
            self.injections_dropped += 1

    @property
    def blocks_used_mean(self) -> float:
        return self.blocks_used_sum / max(self.blocks_used_count, 1)

    @property
    def blocks_used_median(self) -> float:
        """Steady-state resident blocks: the median is robust to the
        cold-start wave, whose requests cannot share (nothing is cached
        yet) and briefly hold unshared copies of a common template."""
        s = sorted(self.blocks_used_samples)
        n = len(s)
        if not n:
            return 0.0
        return (s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_tokens_shared / max(self.prompt_tokens_total, 1)


def _pad_len(n: int) -> int:
    """Bucket prefill lengths to multiples of 8 to bound jit recompiles."""
    return max(8, -(-n // 8) * 8)


def _pad_rows(n: int, cap: int) -> int:
    """Bucket a prefill batch's ROW count to the next power of two (capped
    at the engine's slot count).  Chunk batches vary in both row count and
    chunk length step to step; bucketing both dims bounds the number of
    jitted ``_prefill_chunk`` variants at O(log2(slots) x chunk/8) for an
    entire run instead of one compile per composition."""
    r = 1
    while r < n:
        r *= 2
    return min(r, cap)


@dataclasses.dataclass
class AdmissionBatch:
    """Result of one host-side admission screening pass: the requests
    that will prefill this round (with their assigned slots, prefix
    plans, and pending COW payload moves) plus everything consumed from
    the pending queue (admitted OR finished/rejected during
    screening)."""

    admitted: list
    slot_list: list
    prefix_plans: list
    cow_pairs: list
    consumed: list


class Scheduler:
    """Host-side slot/block/request bookkeeping (see module docstring).

    The ``stats`` and ``tracer`` attributes are deliberately mutable:
    the engine facade rebinds them on warm-up resets and telemetry
    attachment and keeps its own references in sync."""

    def __init__(self, *, slots: int, max_len: int, admit_lookahead: int,
                 stats: EngineStats, tracer,
                 pool: BlockPool | None = None,
                 index: PrefixIndex | None = None):
        self.slots = slots
        self.max_len = max_len
        self.admit_lookahead = int(admit_lookahead)
        self.stats = stats
        self.tracer = tracer
        self.pool = pool
        self.index = index
        self.pos = np.zeros((slots,), np.int32)      # per-slot write cursor
        self.active: dict = {}                        # slot -> Request
        self.prefill_cursors: dict = {}      # slot -> ChunkCursor (FIFO)
        # requests that turned done inside admit()/step(), awaiting run()'s
        # result collection (replaces the O(requests x steps) done-scan)
        self.done_events: list = []
        # head-of-line state: (uid of the deferred head, bypasses spent)
        self.hol_uid: int | None = None
        self.hol_bypassed = 0

    # ------------------------------------------------------------- slots
    def free_slots(self) -> list:
        return [s for s in range(self.slots)
                if s not in self.active and s not in self.prefill_cursors]

    def release(self, slot: int) -> None:
        """Drop a slot's cache references (paged: refcount decrements;
        blocks whose last reference dropped return to the free list and
        their prefix-index entries are purged)."""
        if self.pool is not None:
            freed = self.pool.free_slot(slot)
            if self.index is not None and freed:
                self.index.purge(freed)
        self.pos[slot] = 0

    def finish(self, req: Request, error: str | None = None, *,
               reject: bool = False, evict: bool = False) -> None:
        """Mark a request done and queue it for run()'s result collection.
        ``reject``: screened out before prefill (never held cache state);
        ``evict``: a resident request lost its slot."""
        if error is not None:
            req.error = error
        req.done = True
        if reject:
            self.stats.rejections += 1
            self.tracer.instant("reject", {"uid": req.uid, "error": error})
        if evict:
            self.stats.evictions += 1
            self.tracer.instant("evict", {"uid": req.uid, "error": error})
        self.done_events.append(req)

    def drain_finished(self) -> list:
        done, self.done_events = self.done_events, []
        return done

    # --------------------------------------------------------- admission
    def select_admission(self, pending: list) -> AdmissionBatch:
        """One admission screening pass over ``pending`` (consumed
        requests are removed IN PLACE): budget/length checks, paged block
        claims, prefix matching + COW planning, bounded head-of-line
        lookahead.  Pure host work — the returned batch still has to be
        prefilled (or parked as chunk cursors) by the engine."""
        free = self.free_slots()
        batch = AdmissionBatch([], [], [], [], [])
        if not pending or not free:
            return batch
        admitted, slot_list = batch.admitted, batch.slot_list
        consumed, consumed_idx = batch.consumed, []
        head_deferred = False
        scanned_past_head = 0
        for i, req in enumerate(pending):
            if len(slot_list) >= len(free):
                break
            if head_deferred:
                # bounded lookahead: examine at most admit_lookahead
                # requests past the deferred head
                if scanned_past_head >= self.admit_lookahead:
                    break
                scanned_past_head += 1
            if req.max_new_tokens <= 0:
                self.finish(req)             # zero budget: nothing to do
                consumed.append(req)
                consumed_idx.append(i)
                continue
            # the prompt plus the decode budget must fit in the cache rows
            if len(req.prompt) + max(req.max_new_tokens - 1, 0) > \
                    self.max_len:
                self.finish(req, "prompt_too_long", reject=True)
                consumed.append(req)
                consumed_idx.append(i)
                continue
            slot = free[len(slot_list)]
            plan = None
            if self.pool is not None:
                # paged admission: blocks for the prompt are claimed up
                # front (decode growth is on-demand).  A request that can
                # NEVER fit is rejected with a recorded error; a request
                # that merely hit transient pressure (blocks held by
                # in-flight requests) is DEFERRED until decode frees
                # blocks.  No livelock: deferral with an empty engine is
                # impossible (a full free list that still cannot cover
                # the prompt means never-fits), so something is always
                # decoding and eventually freeing.
                need = blocks_for(len(req.prompt), self.pool.block_size)
                if need > self.pool.num_blocks or \
                        need > self.pool.table_width:
                    self.finish(req, "oom:block_pool", reject=True)
                    consumed.append(req)
                    consumed_idx.append(i)
                    continue
                if self.index is not None:
                    plan = self.index.match(req.prompt)
                    if not plan.shared_ids:
                        plan = None
                # a shared full block costs no free-list draw; the COW
                # copy of a partial tail does (need counts its index)
                fresh = need - (plan.full_blocks if plan else 0)
                if fresh > self.pool.blocks_free:
                    if not head_deferred:
                        head_deferred = True
                        if self.hol_uid != req.uid:
                            self.hol_uid = req.uid
                            self.hol_bypassed = 0
                    continue                 # deferred, keep scanning
                if head_deferred:
                    # admitting past the deferred head spends its bypass
                    # budget; once exhausted admission is strict FIFO and
                    # every freed block is reserved for the head
                    if self.hol_bypassed >= self.admit_lookahead:
                        break
                    self.hol_bypassed += 1
                if plan is not None:
                    ok = self.pool.try_admit_prefix(
                        slot, len(req.prompt), plan.shared_ids)
                else:
                    ok = self.pool.try_alloc(slot, len(req.prompt))
                assert ok, "alloc failed after fresh <= blocks_free check"
                if plan is not None and plan.partial:
                    # the suffix will write into the shared partial tail:
                    # copy-on-write it now, before any jitted step
                    pair = self.pool.try_cow(
                        slot, len(plan.shared_ids) - 1)
                    assert pair is not None, "partial tail was unshared"
                    batch.cow_pairs.append(pair)
            admitted.append(req)
            slot_list.append(slot)
            batch.prefix_plans.append(plan)
            consumed.append(req)
            consumed_idx.append(i)
        for i in reversed(consumed_idx):
            pending.pop(i)
        if self.hol_uid is not None and any(
                r.uid == self.hol_uid for r in consumed):
            self.hol_uid, self.hol_bypassed = None, 0      # head unblocked
        return batch

    def park_prefill(self, batch: AdmissionBatch) -> None:
        """Chunked-prefill admission: the allocated requests become chunk
        cursors (NO model call) and their cursors start past any shared
        prefix; step() co-schedules the chunks against resident decodes."""
        for slot, req, plan in zip(batch.slot_list, batch.admitted,
                                   batch.prefix_plans):
            start = plan.match_len if plan is not None else 0
            self.prefill_cursors[slot] = ChunkCursor(
                req=req, total=len(req.prompt), filled=start,
                prefix=start)
            self.pos[slot] = start

    def plan_chunks(self, budget: int) -> list:
        """Pick this step's prefill chunks: cursors in admission (FIFO)
        order, each taking ``min(budget left, tokens left)``.  Returns
        [(slot, cursor, take, final)]."""
        rows = []
        for slot, cur in self.prefill_cursors.items():
            if budget <= 0:
                break
            take = min(budget, cur.total - cur.filled)
            rows.append((slot, cur, take, cur.filled + take == cur.total))
            budget -= take
        return rows

    # ------------------------------------------------------------ decode
    def grow_for_decode(self) -> list:
        """Paged decode-step guard: claim the block each cursor is about
        to enter BEFORE the jitted step (tables must be stable across the
        attempt/retry window) and COW any block another slot still
        references; a slot that cannot grow is evicted with a recorded
        error, freeing blocks for the rest.  Returns the COW (src, dst)
        pairs whose payload the engine must copy on device.  A decode
        step is exactly a zero-draft verify window."""
        return self.grow_for_verify({})

    def grow_for_verify(self, window: dict) -> list:
        """Paged verify-step guard: ``window[slot]`` is the slot's draft
        length K_s, so the step writes K_s + 1 rows at
        cursor..cursor+K_s (K_s = 0, the default, is a plain decode
        step).  Claims blocks through the window's LAST write and COWs
        EVERY shared block the window touches — the whole window must be
        writable before the jitted attempt because tables stay frozen
        across the attempt/retry window.  Admission COWs the shared
        partial tail eagerly, so the COW guard only fires on exotic
        lifecycles — but scribbling on a sharer's block is silent
        corruption, so it is unconditional.  A slot that cannot grow is
        evicted with a recorded error, freeing blocks for the rest.
        Returns the COW (src, dst) pairs whose payload the engine must
        copy on device."""
        cow_pairs: list = []
        if self.pool is None:
            return cow_pairs
        for s in sorted(self.active):
            k_s = int(window.get(s, 0))
            first = int(self.pos[s]) // self.pool.block_size
            last = (int(self.pos[s]) + k_s) // self.pool.block_size
            last = min(last, self.pool.slot_blocks(s) - 1)
            evicted = False
            for idx in range(first, last + 1):
                if self.pool.refcount[self.pool.tables[s, idx]] > 1:
                    if self.pool.blocks_free == 0:
                        req = self.active.pop(s)
                        self.finish(req, "oom:kv_blocks", evict=True)
                        self.release(s)
                        evicted = True
                        break
                    cow_pairs.append(self.pool.try_cow(s, idx))
            if evicted:
                continue
            if not self.pool.try_grow(s, int(self.pos[s]) + k_s + 1):
                req = self.active.pop(s)
                self.finish(req, "oom:kv_blocks", evict=True)
                self.release(s)
        return cow_pairs
        for s in sorted(self.active):
            # copy-on-write guard: if this step's write lands in a
            # block another slot still references, redirect to a
            # fresh copy first.  Admission COWs the shared partial
            # tail eagerly, so this only fires on exotic lifecycles —
            # but scribbling on a sharer's block is silent corruption,
            # so the guard is unconditional.
            idx = int(self.pos[s]) // self.pool.block_size
            if idx < self.pool.slot_blocks(s) and \
                    self.pool.refcount[self.pool.tables[s, idx]] > 1:
                if self.pool.blocks_free == 0:
                    req = self.active.pop(s)
                    self.finish(req, "oom:kv_blocks", evict=True)
                    self.release(s)
                    continue
                cow_pairs.append(self.pool.try_cow(s, idx))
            if not self.pool.try_grow(s, int(self.pos[s]) + 1):
                req = self.active.pop(s)
                self.finish(req, "oom:kv_blocks", evict=True)
                self.release(s)
        return cow_pairs
