"""Paper Figs. 8-11: per-network execution-time overhead of global ABFT vs
thread(block)-level ABFT vs intensity-guided ABFT — the paper's primary
result (1.09-5.3x overhead reduction).

Network time = sum over GEMM sites of the roofline-modeled layer time
(paper §6.2 aggregates per-layer times the same way).  For each arch x
shape we report the three overheads and the reduction factor
global/intensity-guided, mirroring Fig. 8's summary plus per-domain detail:
  * decode shapes ~ the paper's DLRM/batch-1 regime (bandwidth bound),
  * train/prefill ~ the paper's HD-CNN regime (mostly compute bound).
"""

from __future__ import annotations

from benchmarks.common import row
from repro.configs import ALL_ARCHS, get_config
from repro.core import Scheme, TPU_V5E
from repro.core.selector import modeled_layer_time, select_scheme
from repro.models.counting import layer_gemms

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}


def network_time(cfg, toks, scheme: Scheme | None) -> float:
    """Modeled total linear-layer time under one scheme (None = select
    per layer — intensity-guided)."""
    total = 0.0
    for site, (dims, count) in layer_gemms(cfg, toks).items():
        if scheme is None:
            s = select_scheme(dims, TPU_V5E).scheme
        else:
            s = scheme
        total += count * modeled_layer_time(dims, s, TPU_V5E)
    return total


def run() -> list:
    rows = []
    reductions = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape, toks in SHAPE_TOKENS.items():
            t_none = network_time(cfg, toks, Scheme.NONE)
            t_global = network_time(cfg, toks, Scheme.GLOBAL)
            t_block = network_time(cfg, toks, Scheme.BLOCK_1S)
            t_guided = network_time(cfg, toks, None)
            def ovh(t):
                return (t - t_none) / t_none * 100.0
            red = (ovh(t_global) / max(ovh(t_guided), 1e-9)
                   if ovh(t_guided) > 1e-9 else float("inf"))
            reductions.append(min(red, 100.0))
            rows.append(row(
                f"fig8/{arch}/{shape}", 0.0,
                ovh_global_pct=ovh(t_global),
                ovh_block_pct=ovh(t_block),
                ovh_guided_pct=ovh(t_guided),
                reduction_x=red,
                guided_never_worse=(
                    ovh(t_guided) <= ovh(t_global) + 1e-9
                    and ovh(t_guided) <= ovh(t_block) + 1e-9),
            ))
    rows.append(row(
        "fig8/summary", 0.0,
        n_cells=len(reductions),
        reduction_min=min(reductions),
        reduction_max=max(reductions),
        paper_band="1.09-5.3x",
    ))
    return rows
