"""Paper Fig. 5 analog: per-layer arithmetic intensity within one network.

The paper shows ResNet-50's conv/fc layers spanning AI 1-511 — the
heterogeneity that motivates per-layer scheme selection.  We report the
per-GEMM-site AI of each architecture under its assigned shapes, and the
scheme the intensity-guided selector picks per site.
"""

from __future__ import annotations

from benchmarks.common import row
from repro.configs import ALL_ARCHS, get_config
from repro.core import TPU_V5E, select_scheme
from repro.models.counting import layer_gemms

PHASES = {"train_4k": 256 * 4096, "decode_32k": 128}


def run() -> list:
    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape, toks in PHASES.items():
            sites = layer_gemms(cfg, toks)
            ais = []
            for site, (dims, count) in sites.items():
                sel = select_scheme(dims, TPU_V5E)
                ais.append(dims.arithmetic_intensity)
                rows.append(row(
                    f"fig5/{arch}/{shape}/{site}", 0.0,
                    m=dims.m, k=dims.k, n=dims.n, count=count,
                    ai=dims.arithmetic_intensity,
                    scheme=sel.scheme.value,
                ))
            if ais:
                rows.append(row(
                    f"fig5/{arch}/{shape}/_range", 0.0,
                    ai_min=min(ais), ai_max=max(ais),
                    heterogeneous=(max(ais) / max(min(ais), 1e-9) > 4),
                ))
    return rows
