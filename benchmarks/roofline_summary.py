"""Roofline summary: folds results/dryrun/*.json (produced by
repro.launch.dryrun) into benchmark rows — one per (arch x shape x mesh)
cell with the three roofline terms and the dominant bottleneck."""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import row

DRYRUN_DIR = pathlib.Path("results/dryrun")


def run() -> list:
    rows = []
    if not DRYRUN_DIR.exists():
        return [row("roofline/missing", 0.0,
                    note="run repro.launch.dryrun first")]
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(row(name, 0.0, status="skipped",
                            reason=rec.get("reason", "")))
            continue
        if rec.get("status") != "ok":
            rows.append(row(name, 0.0, status=rec.get("status", "?")))
            continue
        rows.append(row(
            name, rec.get("t_bound_s", 0.0) * 1e6,
            compute_s=rec["compute_s"],
            memory_s=rec["memory_s"],
            collective_s=rec["collective_s"],
            bottleneck=rec["bottleneck"],
            hbm_gib=round(rec.get("hbm_per_device_gib", 0.0), 2),
            useful_flops_ratio=round(rec.get("useful_flops_ratio", 0.0), 4),
        ))
    return rows
