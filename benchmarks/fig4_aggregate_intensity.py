"""Paper Fig. 4 analog: aggregate arithmetic intensity per network.

The paper reports FP16 aggregate AI for eight torchvision CNNs (range
71-220 on HD inputs) plus DLRM MLPs (~7 at batch 1).  Our assigned pool is
LM-family architectures; we report each arch's aggregate AI across the four
assigned shapes, plus the paper's DLRM MLPs computed with the same formula
as a direct validation anchor.
"""

from __future__ import annotations

from benchmarks.common import row
from repro.configs import ALL_ARCHS, get_config
from repro.core import GemmDims, TPU_V5E, aggregate_intensity
from repro.models.counting import aggregate_ai

SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def run() -> list:
    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape, toks in SHAPE_TOKENS.items():
            ai = aggregate_ai(cfg, toks)
            rows.append(row(
                f"fig4/{arch}/{shape}", 0.0,
                aggregate_ai=ai,
                cmr=TPU_V5E.cmr,
                regime="bandwidth" if ai < TPU_V5E.cmr else "compute",
            ))

    # validation anchor: paper's DLRM MLP-Bottom/Top at batch 1 and 256
    # (paper §3.2: AI 7 at batch 1 -> 70-109 at batch 256; our byte model
    # also counts activation traffic so batch-1 values are lower, but the
    # ~2-orders-of-magnitude batch scaling must reproduce)
    def mlp_bottom(b):
        return [GemmDims(m=b, k=13, n=512), GemmDims(m=b, k=512, n=256),
                GemmDims(m=b, k=256, n=64)]

    def mlp_top(b):
        return [GemmDims(m=b, k=479, n=512), GemmDims(m=b, k=512, n=256),
                GemmDims(m=b, k=256, n=1)]
    for name, f in (("mlp_bottom", mlp_bottom), ("mlp_top", mlp_top)):
        ai1 = aggregate_intensity(f(1))
        ai256 = aggregate_intensity(f(256))
        rows.append(row(
            f"fig4/paper_dlrm/{name}", 0.0,
            ai_batch1=ai1, ai_batch256=ai256,
            batch_scaling=ai256 / max(ai1, 1e-9),
            paper_band_ok=(ai1 < 10 and 20 < ai256 < 200),
        ))
    return rows
