"""Shared benchmark utilities: timing + CSV row helpers.

Rows follow the contract ``name,us_per_call,derived`` where ``derived``
packs the analysis values (JSON-ish key=value pairs).  Wall-clock numbers
are CPU-measured (this container); roofline-model numbers target TPU v5e
and are labeled ``modeled_*``.
"""

from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of fn(*args) in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def row(name: str, us_per_call: float, **derived) -> str:
    d = ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())
    return f"{name},{us_per_call:.2f},{d}"


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
