"""Serving throughput sweep: tokens/s under continuous batching, over
slots x prompt-length mix x ABFT scheme x cache kind (ROADMAP open item,
paper §6 deployment scenario).

For each cell the engine serves a fixed request set end to end and we
report wall-clock tokens/s plus ``cache_stats()`` — the paged cells size
their pool to the traffic's peak *working set* (not slots × max_len), so
a skewed prompt mix shows the paged cache allocating a fraction of the
dense bytes while producing the identical greedy token streams.

The ``templated`` mix models system-prompt traffic: every request opens
with the same template and differs only in a short tail.  Its cells add
a ``paged_shared`` engine (refcounted prefix sharing + copy-on-write):
streams must stay byte-identical to dense AND unshared-paged while the
per-step mean ``blocks_used`` drops ≥2x (the shared template is resident
ONCE, chained through overlapping sharers, instead of once per slot).
Every cell reports the fixed occupancy accounting — ``utilization``
against allocated tokens, ``fragmentation``, ``blocks_shared``,
``prefix_hit_rate`` — plus the ``rejections`` / ``evictions`` split.

  PYTHONPATH=src python benchmarks/serve_throughput.py \
      [--quick] [--out results.json] [--slots 2,4] [--new-tokens 8]

Wall-clock numbers are CPU-measured (this container); they order schemes
by redundant-work cost, not by TPU speed — see benchmarks/common.py.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, Scheme
from repro.models import build_model
from repro.serve.engine import EngineStats, Request, ServeEngine
from repro.serve.paged_cache import blocks_for

SCHEMES = {
    # none: protection off; traditional: one global checksum for every
    # layer (Hari et al.); guided: the paper's intensity-guided selector
    "none": ABFTConfig.off(),
    "traditional": ABFTConfig(scheme=Scheme.GLOBAL, use_pallas=False),
    "intensity_guided": ABFTConfig(scheme=Scheme.AUTO, use_pallas=False),
}

MIXES = {
    # (length, weight) pairs; lengths are fractions of max_len
    "uniform_short": [(0.15, 1.0)],
    "skewed": [(0.08, 3.0), (0.75, 1.0)],   # mostly short + one long tail
    # system-prompt traffic: shared template + short unique tail (the
    # prefix-sharing best case; worst case for unshared paging)
    "templated": "templated",
}

# template length as a fraction of max_len; 0.75 keeps the default
# geometry block-aligned (48 tokens = 3 x 16-token blocks), so sharers
# alias whole template blocks and own only their tail/decode block
TEMPLATE_FRAC = 0.75


def _requests(mix, n: int, max_len: int, new_tokens: int) -> tuple:
    rng = np.random.default_rng(0)
    if mix == "templated":
        # one fixed template, per-request tails of 1-4 tokens, and
        # staggered decode budgets — overlap is what lets later requests
        # share the template blocks a live sharer keeps resident
        tpl_len = max(2, int(TEMPLATE_FRAC * max_len))
        template = 1 + np.arange(tpl_len, dtype=np.int32) % 250
        reqs, lens = [], []
        for i in range(n):
            tail = 1 + (50 + 13 * i + np.arange(1 + i % 4,
                                                dtype=np.int32)) % 250
            prompt = np.concatenate([template, tail])
            budget = max(2, new_tokens - 2 + (i * 3) % 5)
            reqs.append(Request(uid=i, prompt=prompt,
                                max_new_tokens=budget))
            lens.append(len(prompt))
        return reqs, lens
    fracs, weights = zip(*mix)
    w = np.asarray(weights) / sum(weights)
    lens = [int(max(2, rng.choice(fracs, p=w) * max_len)) for _ in range(n)]
    return [
        Request(uid=i, prompt=(1 + np.arange(L, dtype=np.int32) % 250),
                max_new_tokens=new_tokens)
        for i, L in enumerate(lens)
    ], lens


def _pool_blocks(lens, slots, new_tokens, block_size) -> int:
    """Blocks covering the peak per-slot working set of this traffic:
    the ``slots`` largest requests resident at once, each grown to
    prompt + decode budget."""
    need = sorted((blocks_for(L + new_tokens, block_size) for L in lens),
                  reverse=True)
    return max(1, sum(need[:slots]))


def run_cell(model, params, reqs, *, slots, max_len, abft, cache_kind,
             num_blocks=None, block_size=16,
             prefix_sharing=False) -> dict:
    eng = ServeEngine(
        model, params, slots=slots, max_len=max_len, abft=abft,
        dtype=jnp.float32, cache_kind=cache_kind, block_size=block_size,
        num_blocks=num_blocks, prefix_sharing=prefix_sharing)
    # warm-up pass: serve a throwaway copy of the same traffic so jit
    # compilation (which dominates cold wall time on CPU) is excluded
    # from the reported tokens/s; shapes repeat, so the timed run below
    # hits the compile cache
    eng.run([Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs])
    if eng.pool is not None:
        eng.pool.reset()            # warm-up must not seed the shared run
    if eng.index is not None:
        from repro.serve.paged_cache import PrefixIndex

        eng.index = PrefixIndex(block_size)
    eng.stats = EngineStats()
    t0 = time.perf_counter()
    results = eng.run([r for r in reqs])
    dt = time.perf_counter() - t0
    stats = eng.cache_stats()
    return {
        "tokens": eng.stats.tokens,
        "tokens_per_s": eng.stats.tokens / dt,
        "wall_s": dt,
        "errors": sum(1 for r in reqs if r.error),
        "rejections": eng.stats.rejections,
        "evictions": eng.stats.evictions,
        "cache_bytes": stats["bytes_total"],
        "tokens_capacity": stats["tokens_capacity"],
        "utilization": stats["utilization"],
        "fragmentation": stats["fragmentation"],
        "blocks_shared": stats["blocks_shared"],
        "prefix_hit_rate": stats["prefix_hit_rate"],
        "blocks_used_mean": eng.stats.blocks_used_mean,
        "blocks_used_median": eng.stats.blocks_used_median,
        "blocks_used_peak": eng.stats.blocks_used_peak,
        "blocks_shared_peak": eng.stats.blocks_shared_peak,
        "cow_copies": eng.stats.cow_copies,
        "streams": {r.uid: r.generated for r in reqs},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--slots", default="2,4")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="one slot count, two schemes")
    ap.add_argument("--out", default=None, help="write JSON here (else stdout)")
    args = ap.parse_args(argv)

    cfg = scaled_down(get_config(args.arch), n_layers=args.n_layers)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)

    slot_counts = [int(s) for s in str(args.slots).split(",")]
    schemes = dict(SCHEMES)
    if args.quick:
        slot_counts = slot_counts[:1]
        schemes = {k: schemes[k] for k in ("none", "intensity_guided")}

    share_ok = model.supports_prefix_sharing
    cells = []
    for slots in slot_counts:
        for mix_name, mix in MIXES.items():
            n_reqs = args.requests
            if mix_name == "templated":
                # enough waves that the steady state (one resident
                # template chained through overlapping sharers) dominates
                # the cold-start wave of unshared copies
                n_reqs = max(args.requests, 6 * slots)
            reqs_proto, lens = _requests(
                mix, n_reqs, args.max_len, args.new_tokens)
            peak_new = max(r.max_new_tokens for r in reqs_proto)
            nb = _pool_blocks(lens, slots, peak_new, args.block_size)
            kinds = ["dense", "paged"]
            if share_ok:
                kinds.append("paged_shared")
            for scheme_name, abft in schemes.items():
                row = {"slots": slots, "mix": mix_name,
                       "scheme": scheme_name,
                       "prompt_lens": lens}
                streams = {}
                for kind in kinds:
                    reqs = [Request(uid=r.uid, prompt=r.prompt,
                                    max_new_tokens=r.max_new_tokens)
                            for r in reqs_proto]
                    cell = run_cell(
                        model, params, reqs, slots=slots,
                        max_len=args.max_len, abft=abft,
                        cache_kind="dense" if kind == "dense" else "paged",
                        block_size=args.block_size,
                        num_blocks=None if kind == "dense" else nb,
                        prefix_sharing=(kind == "paged_shared"))
                    streams[kind] = cell.pop("streams")
                    row[kind] = cell
                row["paged_matches_dense"] = (
                    streams["dense"] == streams["paged"])
                row["paged_bytes_frac"] = (
                    row["paged"]["cache_bytes"]
                    / max(row["dense"]["cache_bytes"], 1))
                shared_note = ""
                if share_ok:
                    row["shared_matches_dense"] = (
                        streams["dense"] == streams["paged_shared"])
                    # the acceptance metric: steady-state resident blocks
                    # at equal throughput, shared vs unshared paging (the
                    # median discounts the cold-start wave, which by
                    # construction cannot share — nothing is cached yet)
                    row["shared_blocks_frac"] = (
                        row["paged_shared"]["blocks_used_median"]
                        / max(row["paged"]["blocks_used_median"], 1e-9))
                    shared_note = (
                        f" shared_blocks={row['shared_blocks_frac']:.2f}x "
                        f"hit={row['paged_shared']['prefix_hit_rate']:.2f} "
                        f"match={row['shared_matches_dense']}")
                cells.append(row)
                print(f"slots={slots} mix={mix_name:13s} "
                      f"scheme={scheme_name:16s} "
                      f"dense={row['dense']['tokens_per_s']:8.1f} tok/s "
                      f"paged={row['paged']['tokens_per_s']:8.1f} tok/s "
                      f"bytes={row['paged_bytes_frac']:.2f}x "
                      f"match={row['paged_matches_dense']}"
                      + shared_note)

    summary = {
        "arch": args.arch, "n_layers": args.n_layers,
        "max_len": args.max_len, "requests": args.requests,
        "new_tokens": args.new_tokens, "block_size": args.block_size,
        "backend": jax.default_backend(),
        "cells": cells,
    }
    payload = json.dumps(summary, indent=2)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(payload)
        print(f"wrote {args.out}")
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
